"""Repo tooling: CI gates (`check_bench`, `check_docs`) and the static
invariant analyzers (`tools.analysis`).  Package so the gates are importable
from tests and the analyzers runnable as ``python -m tools.analysis``."""
