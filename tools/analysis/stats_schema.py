"""Stats-schema drift checker.

The bench gates (tools/check_bench.py) and the paper-parity experiments
compare engine wall-clock runs against the deterministic simulator, and the
serving layer forwards a subset of the same counters.  Those comparisons are
only meaningful while the three producers keep emitting the same keys — and
docs/METRICS.md is the operator contract for all of them.  This checker
extracts the produced key sets *statically* and cross-checks:

1. **engine/simulator parity** — every key in ``PARITY_KEYS`` (the fields
   check_bench invariants and the experiments join on) is produced by BOTH
   `OffloadEngine.stats()` and `OffloadSimulator.run()`;
2. **no silent divergence** — a new `StagingEngine.stats()` counter must
   either be mirrored by the simulator or explicitly allowlisted in
   ``STAGING_LOCAL_KEYS`` here (the allowlist is the reviewed record of
   engine-only metrics);
3. **SLO family parity** — the live `BatchingServer.stats()` and the
   virtual-clock `ServingTimeline.run()` report the same attainment
   counters (``SLO_PARITY_KEYS``), and the cache stats keep the
   fleet-informed counters (``CACHE_REQUIRED_KEYS``) — the PR-9 policy
   search compares live vs simulated on exactly these;
4. **docs coverage** — every produced public key appears backticked in
   docs/METRICS.md, and every field named in a METRICS.md table's first
   column is actually produced by something.

Key extraction understands return-dict literals, ``s = {...}`` +
``s.update(...)`` + ``s[k] = v`` flows, and resolves
``self.<attr>.stats()`` merges through ``ATTR_STATS_SOURCES``.
"""

from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.analysis.astutil import (CodeIndex, FuncInfo, SourceFile,
                                    Violation, dict_literal_keys,
                                    load_source, missing_file_violation)

CHECKER = "stats-schema"

ENGINE_FILE = "src/repro/core/engine.py"
LOADER_FILE = "src/repro/core/loader.py"
CACHE_FILE = "src/repro/core/cache.py"
SIM_FILE = "src/repro/core/simulator.py"
SERVER_FILE = "src/repro/serving/batching.py"
KV_FILE = "src/repro/models/kv_pages.py"
METRICS_DOC = "docs/METRICS.md"

DEFAULT_FILES = (ENGINE_FILE, LOADER_FILE, CACHE_FILE, SIM_FILE,
                 SERVER_FILE, KV_FILE)

# fields the bench gates / experiments join the engine and simulator on
PARITY_KEYS = {
    "cache", "load_stall_s", "overlap_fraction", "per_stream_bytes",
    "issue_reorders", "precision_downgrades", "upgrades", "upgrade_bytes",
    "served_lo_expert_steps", "link_utilization",
}
# StagingEngine counters with no simulator analogue (reviewed allowlist:
# extend it deliberately when adding an engine-only metric)
STAGING_LOCAL_KEYS = {
    "copy_s", "overlap_s", "prefetch_jobs", "dropped_prefetch", "streams",
    "link_gbps",
}
# the PR-9 SLO family: policy search happens on the virtual-clock
# ServingTimeline and the winner serves live traffic, so the live server and
# the timeline must keep reporting the same attainment counters
SLO_PARITY_KEYS = {"slo_attainment", "p99_ttft_s", "preemptions"}
# fleet-informed caching counters the cache stats must keep emitting
CACHE_REQUIRED_KEYS = {"fleet_heat_hits"}
# produced keys that hold nested objects rather than documented scalars
DOC_EXEMPT = {"backend", "stats"}

# how `s.update(self.<attr>.stats())` merges resolve: attr -> (file, class)
ATTR_STATS_SOURCES = {
    "kv_pool": (KV_FILE, "PagedKVPool"),
    "scheduler": (LOADER_FILE, "StagingEngine"),
    "cache": (CACHE_FILE, "MultidimensionalCache"),
}

_BACKTICK_RE = re.compile(r"`([^`]+)`")
_FIELD_RE = re.compile(r"^[a-z][a-z0-9_]*(\.\*)?$")


def _producer(idx: CodeIndex, cls: str, meth: str) -> Optional[FuncInfo]:
    return idx.resolve_method(cls, meth)


def extract_keys(idx: CodeIndex, info: FuncInfo,
                 depth: int = 0) -> Set[str]:
    """Statically collect the string keys `info` can return in its dict."""
    if depth > 3:
        return set()
    keys: Set[str] = set()
    var_keys: Dict[str, Set[str]] = {}

    def value_keys(expr: ast.AST) -> Set[str]:
        if isinstance(expr, ast.Dict):
            out = set(dict_literal_keys(expr))
            # {**other, "k": v} spreads: follow dict-literal spreads only
            for k, v in zip(expr.keys, expr.values):
                if k is None and isinstance(v, ast.Dict):
                    out |= dict_literal_keys(v)
            return out
        if isinstance(expr, ast.Call):
            fn = expr.func
            # dict(expr) wrapper
            if (isinstance(fn, ast.Name) and fn.id == "dict" and expr.args):
                return value_keys(expr.args[0])
            # self.<attr>.stats() merge
            if (isinstance(fn, ast.Attribute) and fn.attr == "stats"
                    and isinstance(fn.value, ast.Attribute)
                    and fn.value.attr in ATTR_STATS_SOURCES):
                _, cls = ATTR_STATS_SOURCES[fn.value.attr]
                src = _producer(idx, cls, "stats")
                if src is not None:
                    return extract_keys(idx, src, depth + 1)
            # self.cache.stats.to_dict() style
            if (isinstance(fn, ast.Attribute) and fn.attr == "to_dict"):
                src = None
                for c in idx.classes:
                    cand = idx.resolve_method(c, "to_dict")
                    if cand is not None:
                        src = cand
                if src is not None:
                    return extract_keys(idx, src, depth + 1)
        if isinstance(expr, ast.Name):
            return set(var_keys.get(expr.id, set()))
        return set()

    # two passes: ast.walk is breadth-first, so a trailing `return s` would
    # otherwise be seen before the nested `s.update(...)` calls that feed it
    for node in ast.walk(info.node):
        if isinstance(node, ast.Assign):
            ks = value_keys(node.value)
            for t in node.targets:
                if isinstance(t, ast.Name) and ks:
                    var_keys.setdefault(t.id, set()).update(ks)
                elif (isinstance(t, ast.Subscript)
                      and isinstance(t.value, ast.Name)
                      and isinstance(t.slice, ast.Constant)
                      and isinstance(t.slice.value, str)):
                    var_keys.setdefault(t.value.id, set()).add(t.slice.value)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr == "update"
              and isinstance(node.func.value, ast.Name) and node.args):
            var_keys.setdefault(node.func.value.id, set()).update(
                value_keys(node.args[0]))
    for node in ast.walk(info.node):
        if isinstance(node, ast.Return) and node.value is not None:
            keys |= value_keys(node.value)
    return keys


def _doc_tokens(text: str) -> Tuple[Set[str], Set[str]]:
    """(all backticked field-like tokens, first-column table field tokens),
    both with a trailing ``.*`` stripped."""
    def norm(tok: str) -> Optional[str]:
        tok = tok.strip()
        if tok.endswith(".*"):
            tok = tok[:-2]
        return tok if _FIELD_RE.match(tok) else None

    everywhere: Set[str] = set()
    for tok in _BACKTICK_RE.findall(text):
        n = norm(tok)
        if n:
            everywhere.add(n)
    first_col: Set[str] = set()
    for line in text.splitlines():
        if not line.lstrip().startswith("|"):
            continue
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if not cells or set(cells[0]) <= {"-", ":", " "}:
            continue
        m = _BACKTICK_RE.search(cells[0])
        if m:
            n = norm(m.group(1))
            if n:
                first_col.add(n)
    return everywhere, first_col


def run(root: pathlib.Path,
        rel_files: Sequence[str] = DEFAULT_FILES) -> List[Violation]:
    """Cross-check stats producers against each other and METRICS.md."""
    violations: List[Violation] = []
    files: List[SourceFile] = []
    for rel in rel_files:
        sf = load_source(root, rel)
        if sf is None:
            violations.append(missing_file_violation(CHECKER, rel))
        else:
            files.append(sf)
    if not files:
        return violations
    idx = CodeIndex(files)

    producers = {
        "engine": ("OffloadEngine", "stats", ENGINE_FILE),
        "staging": ("StagingEngine", "stats", LOADER_FILE),
        "simulator": ("OffloadSimulator", "run", SIM_FILE),
        "server": ("BatchingServer", "stats", SERVER_FILE),
        "cache": ("CacheStats", "to_dict", CACHE_FILE),
        "kv": ("PagedKVPool", "stats", KV_FILE),
        # virtual-clock SLO policy search (stays out of the doc-coverage
        # loop: its dict is a per-policy report, not operator counters)
        "timeline": ("ServingTimeline", "run", SIM_FILE),
    }
    loaded_rels = {sf.rel for sf in files}
    keys: Dict[str, Set[str]] = {}
    sites: Dict[str, Tuple[str, int]] = {}
    for name, (cls, meth, rel) in producers.items():
        if rel not in loaded_rels:
            keys[name] = set()
            continue
        info = _producer(idx, cls, meth)
        if info is None:
            violations.append(Violation(
                CHECKER, "config-drift", rel, 1,
                f"stats producer {cls}.{meth} not found; update "
                "tools/analysis/stats_schema.py if it was renamed"))
            keys[name] = set()
            continue
        keys[name] = extract_keys(idx, info)
        sites[name] = (rel, info.node.lineno)

    engine_keys = keys["engine"]
    sim_keys = keys["simulator"]
    staging_keys = keys["staging"]

    # 1. parity: the joined-on fields exist on both sides
    for side, got in (("engine", engine_keys), ("simulator", sim_keys)):
        if side not in sites:
            continue
        rel, line = sites[side]
        for k in sorted(PARITY_KEYS - got):
            violations.append(Violation(
                CHECKER, "engine-sim-parity", rel, line,
                f"parity key '{k}' is not produced by the {side} stats — "
                "check_bench invariants and the experiments join on it"))

    # 2. staging counters must be mirrored or deliberately allowlisted
    if "staging" in sites and "simulator" in sites:
        rel, line = sites["staging"]
        for k in sorted(staging_keys - sim_keys - STAGING_LOCAL_KEYS):
            violations.append(Violation(
                CHECKER, "staging-sim-drift", rel, line,
                f"StagingEngine.stats() key '{k}' has no simulator "
                "counterpart; mirror it in OffloadSimulator.run() or add it "
                "to STAGING_LOCAL_KEYS in tools/analysis/stats_schema.py"))

    # 3. SLO family parity: live server and virtual-clock timeline must both
    # report the attainment counters the policy search compares on, and the
    # cache stats must keep the fleet-informed counters
    for side in ("server", "timeline"):
        if side not in sites:
            continue
        rel, line = sites[side]
        for k in sorted(SLO_PARITY_KEYS - keys[side]):
            violations.append(Violation(
                CHECKER, "slo-sim-parity", rel, line,
                f"SLO key '{k}' is not produced by the {side} stats — the "
                "policy search compares the live server and the timeline "
                "on it"))
    if "cache" in sites:
        rel, line = sites["cache"]
        for k in sorted(CACHE_REQUIRED_KEYS - keys["cache"]):
            violations.append(Violation(
                CHECKER, "slo-sim-parity", rel, line,
                f"fleet-informed cache key '{k}' disappeared from the cache "
                "stats — the fleet-caching experiments read it"))

    # 4. docs coverage both ways
    doc = load_source(root, METRICS_DOC)
    if doc is None:
        violations.append(missing_file_violation(CHECKER, METRICS_DOC))
        return violations
    documented, table_fields = _doc_tokens(doc.text)
    public = {}
    for name in ("engine", "staging", "server", "cache", "kv"):
        for k in keys[name]:
            public.setdefault(k, name)
    for k in sorted(set(public) - documented - DOC_EXEMPT):
        rel, line = sites.get(public[k], (METRICS_DOC, 1))
        violations.append(Violation(
            CHECKER, "undocumented-stat", rel, line,
            f"stats key '{k}' (produced by the {public[k]} stats) is not "
            f"documented in {METRICS_DOC}"))
    produced_all = set().union(*keys.values()) if keys else set()
    for k in sorted(table_fields - produced_all):
        violations.append(Violation(
            CHECKER, "stale-doc-field", METRICS_DOC, 1,
            f"{METRICS_DOC} documents field '{k}' that no stats producer "
            "emits — stale docs or a renamed counter"))
    return violations
