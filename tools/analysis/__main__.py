"""``python -m tools.analysis`` entry point."""

import sys

from tools.analysis.cli import main

sys.exit(main())
