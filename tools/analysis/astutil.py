"""Shared AST infrastructure for the repo-specific invariant checkers.

Everything in `tools.analysis` works on plain `ast` trees plus the raw
source lines (the `# owner: main-thread` annotations live in comments, which
the AST does not carry).  The helpers here are deliberately conservative:
call resolution only follows edges it can prove (`self.method`, bare module
functions, import aliases, constructor-bound callbacks), and every checker
treats "could not resolve" as "do not flag" — the known-bad fixtures under
``tests/fixtures/analysis/`` pin the resolution power we depend on.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

# trailing or preceding-line marker claiming a def / attribute for a thread
OWNER_RE = re.compile(r"#\s*owner:\s*(?P<owner>[A-Za-z][\w-]*)")
# inline suppression: `# analysis: ignore` or `# analysis: ignore[name,...]`
SUPPRESS_RE = re.compile(r"#\s*analysis:\s*ignore(?:\[(?P<names>[^\]]*)\])?")

MAIN_THREAD = "main-thread"


@dataclasses.dataclass
class Violation:
    """One invariant violation, printable as ``file:line: [checker] ...``."""
    checker: str
    invariant: str
    file: str
    line: int
    message: str

    def render(self) -> str:
        return (f"{self.file}:{self.line}: [{self.checker}] "
                f"{self.invariant} — {self.message}")


@dataclasses.dataclass
class SourceFile:
    """A parsed module: path, repo-relative name, raw lines, AST (None for
    non-Python files such as markdown docs)."""
    path: pathlib.Path
    rel: str
    text: str
    lines: List[str]
    tree: Optional[ast.Module]


def load_source(root: pathlib.Path, rel: str) -> Optional[SourceFile]:
    """Load (and, for ``.py``, parse) ``root/rel``; None when missing."""
    path = pathlib.Path(root) / rel
    if not path.is_file():
        return None
    text = path.read_text()
    tree = ast.parse(text) if path.suffix == ".py" else None
    return SourceFile(path=path, rel=rel, text=text,
                      lines=text.splitlines(), tree=tree)


def missing_file_violation(checker: str, rel: str) -> Violation:
    """Config-drift guard: a checker's default input file vanished (likely a
    rename) — fail loudly instead of silently checking nothing."""
    return Violation(checker, "config-drift", rel, 1,
                     "expected source file is missing; update the checker's "
                     "file list in tools/analysis/ if it moved")


@dataclasses.dataclass
class FuncInfo:
    """A function or method definition with its defining context."""
    qualname: str                 # "Class.method" or "function"
    cls: Optional[str]            # enclosing class name, if a method
    name: str
    node: ast.AST                 # FunctionDef / AsyncFunctionDef
    sf: SourceFile


class CodeIndex:
    """Classes, functions and import aliases across a set of source files."""

    def __init__(self, files: Iterable[SourceFile]) -> None:
        self.files: List[SourceFile] = list(files)
        self.classes: Dict[str, ast.ClassDef] = {}
        self.class_sf: Dict[str, SourceFile] = {}
        self.class_bases: Dict[str, List[str]] = {}
        self.functions: Dict[str, FuncInfo] = {}          # qualname -> info
        self.module_functions: Dict[str, FuncInfo] = {}   # bare name -> info
        self.methods_by_name: Dict[str, List[FuncInfo]] = {}
        # per-file import aliases: local name -> dotted module path
        self.aliases: Dict[str, Dict[str, str]] = {}
        for sf in self.files:
            self._index_file(sf)

    def _index_file(self, sf: SourceFile) -> None:
        amap = self.aliases.setdefault(sf.rel, {})
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    amap[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    amap[a.asname or a.name] = f"{node.module}.{a.name}"
        for node in sf.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = FuncInfo(node.name, None, node.name, node, sf)
                self.functions[node.name] = info
                self.module_functions[node.name] = info
            elif isinstance(node, ast.ClassDef):
                self.classes[node.name] = node
                self.class_sf[node.name] = sf
                self.class_bases[node.name] = [
                    b.id for b in node.bases if isinstance(b, ast.Name)]
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        info = FuncInfo(f"{node.name}.{item.name}",
                                        node.name, item.name, item, sf)
                        self.functions[info.qualname] = info
                        self.methods_by_name.setdefault(
                            item.name, []).append(info)

    def resolve_method(self, cls: Optional[str],
                       name: str) -> Optional[FuncInfo]:
        """Look up ``cls.name`` walking single-inheritance bases by name."""
        seen = set()
        while cls and cls not in seen:
            seen.add(cls)
            info = self.functions.get(f"{cls}.{name}")
            if info is not None:
                return info
            bases = self.class_bases.get(cls, [])
            cls = bases[0] if bases else None
        return None

    def file_for_module(self, dotted: str) -> Optional[SourceFile]:
        """Map a dotted module path to a loaded file (suffix match)."""
        tail = dotted.replace(".", "/") + ".py"
        for sf in self.files:
            if sf.rel.endswith(tail):
                return sf
        return None


def _code_line_after(sf: SourceFile, lineno: int) -> Optional[int]:
    """First non-comment, non-blank line number strictly after `lineno`."""
    for i in range(lineno, len(sf.lines)):
        stripped = sf.lines[i].strip()
        if stripped and not stripped.startswith("#"):
            return i + 1
    return None


def owner_annotations(files: Iterable[SourceFile],
                      owner: str = MAIN_THREAD
                      ) -> Tuple[Dict[str, Tuple[str, int]],
                                 Dict[str, Tuple[str, int]]]:
    """Collect ``# owner: <owner>`` markers.

    Returns (methods, attrs): maps from the *name* of an owned method /
    ``self.<attr>`` assignment target to its (file, line) definition site.
    Markers may trail the annotated line or sit on the line directly above
    it (comment-only lines between the marker and the code are allowed).
    """
    methods: Dict[str, Tuple[str, int]] = {}
    attrs: Dict[str, Tuple[str, int]] = {}
    for sf in files:
        marked: Set[int] = set()
        for i, line in enumerate(sf.lines):
            m = OWNER_RE.search(line)
            if not m or m.group("owner") != owner:
                continue
            stripped = line.strip()
            if stripped.startswith("#"):
                nxt = _code_line_after(sf, i + 1)
                if nxt is not None:
                    marked.add(nxt)
            else:
                marked.add(i + 1)
        if not marked:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.lineno in marked:
                    methods[node.name] = (sf.rel, node.lineno)
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                if node.lineno not in marked:
                    continue
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        attrs[t.attr] = (sf.rel, node.lineno)
    return methods, attrs


def suppressed(root: pathlib.Path, v: Violation,
               _cache: Optional[dict] = None) -> bool:
    """True when the flagged source line carries a matching
    ``# analysis: ignore[...]`` marker (bare ``ignore`` matches anything)."""
    path = pathlib.Path(root) / v.file
    if not path.is_file():
        return False
    try:
        line = path.read_text().splitlines()[v.line - 1]
    except IndexError:
        return False
    m = SUPPRESS_RE.search(line)
    if not m:
        return False
    names = m.group("names")
    if not names:
        return True
    return v.invariant in {n.strip() for n in names.split(",")}


def attr_chain(node: ast.AST) -> List[str]:
    """Attribute chain names, innermost first: ``a.b.c`` -> ["a","b","c"]
    (Name/Attribute chains only; anything else truncates the chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return list(reversed(parts))


def call_name(call: ast.Call) -> Optional[str]:
    """The called attribute/function name of a Call, if syntactic."""
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None


def dict_literal_keys(node: ast.Dict) -> Set[str]:
    """String keys of a dict literal (non-constant keys are skipped)."""
    out: Set[str] = set()
    for k in node.keys:
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            out.add(k.value)
    return out
