"""InferenceBackend protocol-conformance checker.

`serving/api.py` defines the `InferenceBackend` Protocol that the batching
server, benchmarks and examples program against.  Python Protocols are
structural and unchecked at runtime on the happy path — a backend missing
``release`` or accepting ``(self, toks)`` instead of ``(self, tokens)``
only explodes when that exact seam is exercised.  This checker verifies,
for every class named ``*Backend`` under ``src/repro/``:

* each protocol method exists (own or single-inheritance base);
* positional parameter names match the protocol's, in order;
* parameters the protocol defaults must be defaulted by the implementation,
  and any extra implementation parameters must carry defaults (callers
  programming against the protocol will never pass them);
* the ``model`` protocol attribute is assigned somewhere on the class.

``**kwargs``-style escape hatches are honored (a method with ``*args`` /
``**kwargs`` accepts any protocol call).
"""

from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional, Sequence

from tools.analysis.astutil import (CodeIndex, SourceFile, Violation,
                                    load_source, missing_file_violation)

CHECKER = "protocol-conformance"

PROTOCOL_FILE = "src/repro/serving/api.py"
PROTOCOL_CLASS = "InferenceBackend"

DEFAULT_FILES = (PROTOCOL_FILE,)


def _method_sigs(cls: ast.ClassDef) -> Dict[str, ast.arguments]:
    return {n.name: n.args for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _positional(args: ast.arguments) -> List[str]:
    return ([a.arg for a in args.posonlyargs]
            + [a.arg for a in args.args])[1:]       # drop self


def _defaulted(args: ast.arguments) -> set:
    """Names of parameters that carry defaults (positional or kw-only)."""
    pos = [a.arg for a in args.posonlyargs] + [a.arg for a in args.args]
    out = set(pos[len(pos) - len(args.defaults):]) if args.defaults else set()
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            out.add(a.arg)
    return out


def _protocol_attrs(cls: ast.ClassDef) -> List[str]:
    """Annotated class-level attributes (the Protocol's data surface)."""
    return [n.target.id for n in cls.body
            if isinstance(n, ast.AnnAssign) and isinstance(n.target,
                                                           ast.Name)]


def _assigns_attr(idx: CodeIndex, cls_name: str, attr: str) -> bool:
    seen = set()
    while cls_name and cls_name not in seen:
        seen.add(cls_name)
        cls = idx.classes.get(cls_name)
        if cls is None:
            return False
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self" and t.attr == attr):
                        return True
        bases = idx.class_bases.get(cls_name, [])
        cls_name = bases[0] if bases else None
    return False


def _merged_methods(idx: CodeIndex,
                    cls_name: str) -> Dict[str, ast.arguments]:
    """Own + inherited (by-name, single-chain) method signatures."""
    out: Dict[str, ast.arguments] = {}
    seen = set()
    while cls_name and cls_name not in seen:
        seen.add(cls_name)
        cls = idx.classes.get(cls_name)
        if cls is None:
            break
        for name, args in _method_sigs(cls).items():
            out.setdefault(name, args)
        bases = idx.class_bases.get(cls_name, [])
        cls_name = bases[0] if bases else None
    return out


def _wildcard(args: ast.arguments) -> bool:
    return args.vararg is not None or args.kwarg is not None


def default_files(root: pathlib.Path) -> List[str]:
    """The protocol module plus every src/repro module defining a
    ``*Backend`` class (cheap text pre-filter)."""
    rels = [PROTOCOL_FILE]
    base = pathlib.Path(root) / "src" / "repro"
    if base.is_dir():
        for p in sorted(base.rglob("*.py")):
            rel = str(p.relative_to(root))
            if rel not in rels and "Backend" in p.read_text():
                rels.append(rel)
    return rels


def run(root: pathlib.Path,
        rel_files: Optional[Sequence[str]] = None) -> List[Violation]:
    """Check every *Backend class against the InferenceBackend protocol."""
    if rel_files is None:
        rel_files = default_files(root)
    violations: List[Violation] = []
    files: List[SourceFile] = []
    for rel in rel_files:
        sf = load_source(root, rel)
        if sf is None:
            violations.append(missing_file_violation(CHECKER, rel))
        else:
            files.append(sf)
    if not files:
        return violations
    idx = CodeIndex(files)

    proto = idx.classes.get(PROTOCOL_CLASS)
    if proto is None:
        violations.append(Violation(
            CHECKER, "config-drift", PROTOCOL_FILE, 1,
            f"protocol class {PROTOCOL_CLASS} not found; update "
            "tools/analysis/protocol_conformance.py if it was renamed"))
        return violations
    proto_methods = _method_sigs(proto)
    proto_attrs = _protocol_attrs(proto)

    impls = [name for name in idx.classes
             if name.endswith("Backend") and name != PROTOCOL_CLASS]
    for name in sorted(impls):
        cls = idx.classes[name]
        sf = idx.class_sf[name]
        methods = _merged_methods(idx, name)
        for mname, pargs in sorted(proto_methods.items()):
            iargs = methods.get(mname)
            if iargs is None:
                violations.append(Violation(
                    CHECKER, "missing-protocol-method", sf.rel, cls.lineno,
                    f"{name} does not define {PROTOCOL_CLASS}.{mname}()"))
                continue
            if _wildcard(iargs):
                continue
            ppos, ipos = _positional(pargs), _positional(iargs)
            pdef, idef = _defaulted(pargs), _defaulted(iargs)
            impl_line = next(
                (n.lineno for n in ast.walk(cls)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and n.name == mname), cls.lineno)
            if ipos[:len(ppos)] != ppos:
                violations.append(Violation(
                    CHECKER, "signature-mismatch", sf.rel, impl_line,
                    f"{name}.{mname}({', '.join(ipos)}) does not match the "
                    f"protocol's positional parameters ({', '.join(ppos)})"))
                continue
            for extra in ipos[len(ppos):]:
                if extra not in idef:
                    violations.append(Violation(
                        CHECKER, "signature-mismatch", sf.rel, impl_line,
                        f"{name}.{mname}: extra required parameter "
                        f"'{extra}' — protocol callers will never pass it"))
            for d in sorted(pdef):
                if d in ipos or d in {a.arg for a in iargs.kwonlyargs}:
                    if d not in idef:
                        violations.append(Violation(
                            CHECKER, "signature-mismatch", sf.rel, impl_line,
                            f"{name}.{mname}: parameter '{d}' is optional "
                            "in the protocol but required here"))
                else:
                    violations.append(Violation(
                        CHECKER, "signature-mismatch", sf.rel, impl_line,
                        f"{name}.{mname}: protocol parameter '{d}' is not "
                        "accepted"))
        for attr in proto_attrs:
            if not _assigns_attr(idx, name, attr):
                violations.append(Violation(
                    CHECKER, "missing-protocol-attr", sf.rel, cls.lineno,
                    f"{name} never assigns protocol attribute "
                    f"'self.{attr}'"))
    return violations
