"""Trace-time jaxpr auditor for the serving hot path.

Traces every registered entry point (tools/analysis/entrypoints.py) with
tiny abstract inputs via ``jax.make_jaxpr`` / ``jax.jit(...).lower`` under
both ``REPRO_KERNEL_MODE`` values and applies five rules:

* **no-host-sync** — no callback / infeed / outfeed / device-transfer
  primitive anywhere inside a traced region;
* **donation-honored** — every argument the production jit declares donated
  is actually recorded as input/output-aliased by the lowering (JAX drops
  unusable donations with only a warning; the auditor turns that warning,
  and a lowering with no aliasing at all, into a violation);
* **no-dense-gather** — no intermediate with a declared forbidden
  ``(B, pages*page_size, ...)`` dense-pool shape on decode paths, with the
  PR-7 self-validating positive control: the declared oracle mode (the XLA
  reference path) MUST materialize the dense shape, otherwise the check
  itself is broken and the auditor says so instead of passing;
* **dtype-policy** — no silent f32 upcast of the declared bfloat16
  activations: a ``dot_general`` that runs in f32 on operands upcast from
  bf16 and whose result is immediately downcast back to bf16 bought nothing
  but bandwidth (the GEMM should have run in bf16); dots with a quantized
  (int8) ancestor are the fused-dequant contract and exempt, as are
  f32 results that remain f32 (deliberate accumulations, logits).  Under
  ``pallas`` mode, quantized operands may only widen inside ``pallas_call``
  kernels;
* **variant-budget** — the declared steady-state shape set costs exactly
  the declared number of distinct compile signatures (the static twin of
  tests/test_recompile_guard.py).

Findings render as ``entrypoint: [rule] primitive @ eqn — message`` with the
offending jaxpr slice, and ``config-drift`` fires when a registered entry
point disappears — the same conventions as the PR-6 AST checkers.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import pathlib
import warnings
from typing import (Any, Dict, Iterable, Iterator, List, Optional, Sequence,
                    Set, Tuple)

from tools.analysis.entrypoints import (BOTH_MODES, EntryPoint,
                                        build_registry, resolve_target)

RULES = ("no-host-sync", "donation-honored", "no-dense-gather",
         "dtype-policy", "variant-budget")

# primitives that force a host round-trip or device transfer inside a trace
_HOST_SYNC_SUBSTR = ("callback",)     # pure_callback / io_callback / debug_callback
_HOST_SYNC_EXACT = {"infeed", "outfeed", "device_put"}

# dataflow-transparent primitives the dtype rule walks through backwards
_TRANSPARENT = {
    "convert_element_type", "reshape", "transpose", "broadcast_in_dim",
    "squeeze", "slice", "dynamic_slice", "gather", "concatenate", "pad",
    "rev", "add", "sub", "mul", "div", "max", "min", "neg", "select_n",
    "clamp", "stop_gradient", "copy",
}
# elementwise-ish primitives the downcast search walks forwards through
_FORWARD = _TRANSPARENT | {"exp", "log", "tanh", "logistic", "rsqrt", "sqrt",
                           "integer_pow", "pow", "erf", "reduce_sum",
                           "reduce_max"}

_QUANT_DTYPES_DEFAULT = ("int8", "uint8", "int4", "uint4")


@dataclasses.dataclass
class AuditFinding:
    """One audited-rule violation, printable as
    ``entrypoint: [rule] primitive @ eqn — message``."""
    entrypoint: str
    rule: str
    primitive: str = "-"
    eqn: str = "-"
    message: str = ""
    jaxpr_slice: str = ""

    def render(self) -> str:
        return (f"{self.entrypoint}: [{self.rule}] {self.primitive} "
                f"@ eqn {self.eqn} — {self.message}")


@contextlib.contextmanager
def _kernel_mode(mode: str) -> Iterator[None]:
    """Pin REPRO_KERNEL_MODE for the duration of one trace.  kernels/ops.py
    resolves mode="auto" from the environment AT TRACE TIME, so this is the
    exact mechanism production uses to pick a dispatch tier."""
    prev = os.environ.get("REPRO_KERNEL_MODE")
    os.environ["REPRO_KERNEL_MODE"] = mode
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_MODE", None)
        else:
            os.environ["REPRO_KERNEL_MODE"] = prev


def _trace(entry: EntryPoint, mode: str) -> Any:
    import jax
    fn = entry.fn
    # make_jaxpr rides the jit trace cache, keyed on (function identity,
    # avals) — NOT on REPRO_KERNEL_MODE, which ops._resolve reads from the
    # environment at trace time.  A fresh wrapper per trace forces a genuine
    # re-trace under the pinned mode instead of returning the other mode's
    # cached jaxpr.
    with _kernel_mode(mode):
        return jax.make_jaxpr(lambda *a: fn(*a))(*entry.args)


def _subjaxprs(eqn: Any) -> List[Tuple[Any, bool]]:
    """(inner_jaxpr, entered_pallas) pairs reachable from one eqn's params —
    handles ClosedJaxpr params (pjit, scan, ...) and the raw Jaxpr that
    ``pallas_call`` carries, nested arbitrarily in lists/tuples."""
    import jax
    is_pallas = eqn.primitive.name == "pallas_call"
    out: List[Tuple[Any, bool]] = []
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else [val]
        for v in vals:
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                out.append((inner, is_pallas))
            elif isinstance(v, jax.core.Jaxpr):
                out.append((v, is_pallas))
    return out


def _iter_eqns(jaxpr: Any, path: Tuple[int, ...] = (),
               in_pallas: bool = False) -> Iterator[Tuple[str, Any, bool]]:
    """Yield ``("0/3/1", eqn, inside_pallas_kernel)`` over all regions."""
    for i, eqn in enumerate(jaxpr.eqns):
        loc = path + (i,)
        yield "/".join(map(str, loc)), eqn, in_pallas
        for inner, entered in _subjaxprs(eqn):
            yield from _iter_eqns(inner, loc, in_pallas or entered)


def _slice(eqn: Any) -> str:
    txt = str(eqn).replace("\n", " ")
    return txt if len(txt) <= 220 else txt[:217] + "..."


# --------------------------------------------------------------------------
# rule: no-host-sync
# --------------------------------------------------------------------------
def check_host_sync(entry: EntryPoint, jaxpr: Any,
                    mode: str) -> List[AuditFinding]:
    out = []
    for loc, eqn, _ in _iter_eqns(jaxpr.jaxpr):
        name = eqn.primitive.name
        if name in _HOST_SYNC_EXACT or any(s in name for s in _HOST_SYNC_SUBSTR):
            out.append(AuditFinding(
                entry.name, "no-host-sync", name, loc,
                f"host-sync/transfer primitive inside the traced region "
                f"(mode={mode}); the decode hot path must stay device-only",
                _slice(eqn)))
    return out


# --------------------------------------------------------------------------
# rule: no-dense-gather
# --------------------------------------------------------------------------
def _shapes(jaxpr: Any) -> Dict[Tuple[int, ...], Tuple[str, str]]:
    """All intermediate output shapes -> (first primitive, eqn loc)."""
    found: Dict[Tuple[int, ...], Tuple[str, str]] = {}
    for loc, eqn, in_pallas in _iter_eqns(jaxpr.jaxpr):
        if in_pallas:
            continue        # kernel-interior blocks are tile-shaped views
        for v in eqn.outvars:
            shape = getattr(getattr(v, "aval", None), "shape", None)
            if shape is not None and tuple(shape) not in found:
                found[tuple(shape)] = (eqn.primitive.name, loc)
    return found


def check_dense_gather(entry: EntryPoint, jaxpr: Any, mode: str,
                       oracle_seen: Optional[Set[Tuple[int, ...]]] = None
                       ) -> List[AuditFinding]:
    """Forbidden dense shapes must be absent outside the oracle mode; in the
    oracle mode their PRESENCE is required (self-validating control)."""
    out = []
    found = _shapes(jaxpr)
    for shape in entry.dense_shapes:
        if mode == entry.dense_oracle_mode:
            if oracle_seen is not None and shape in found:
                oracle_seen.add(shape)
            continue
        if shape in found:
            prim, loc = found[shape]
            out.append(AuditFinding(
                entry.name, "no-dense-gather", prim, loc,
                f"intermediate with dense pool-gather shape {shape} under "
                f"mode={mode}; the kernel tier exists to keep this "
                f"materialization off the decode path"))
    return out


def oracle_control_findings(entry: EntryPoint,
                            oracle_seen: Set[Tuple[int, ...]],
                            oracle_ran: bool) -> List[AuditFinding]:
    """PR-7's positive control: the reference mode must still gather dense,
    or the no-dense-gather check is vacuous and reports itself broken."""
    if not entry.dense_shapes or entry.dense_oracle_mode is None:
        return []
    if not oracle_ran:
        return []
    out = []
    for shape in entry.dense_shapes:
        if shape not in oracle_seen:
            out.append(AuditFinding(
                entry.name, "no-dense-gather", "-", "-",
                f"positive control failed: oracle mode "
                f"'{entry.dense_oracle_mode}' no longer materializes dense "
                f"shape {shape}, so absence under the kernel tier proves "
                f"nothing — update the entry's declared dense_shapes"))
    return out


# --------------------------------------------------------------------------
# rule: dtype-policy
# --------------------------------------------------------------------------
class _FlatGraph:
    """The traced program flattened across pjit/scan sub-regions: every eqn
    at every depth, with sub-jaxpr boundary variables aliased to their
    call-site operands so dataflow walks cross region boundaries.
    ``pallas_call`` interiors are kept but marked (the fused-kernel
    exemption).  Control-flow primitives whose operand lists don't line up
    1:1 (cond, while) simply break the chain — conservative, never a false
    positive."""

    def __init__(self, jaxpr: Any) -> None:
        self.alias: Dict[int, Any] = {}
        self.producer: Dict[int, Tuple[Any, str, bool]] = {}
        self.consumers: Dict[int, List[Tuple[Any, str, bool]]] = {}
        self.eqns: List[Tuple[str, Any, bool]] = []
        self._walk(jaxpr.jaxpr, (), False)

    def _link(self, inner_vars: Any, outer_vars: Any) -> None:
        import jax
        if len(inner_vars) != len(outer_vars):
            return
        for iv, ov in zip(inner_vars, outer_vars):
            if isinstance(iv, jax.core.Var) and isinstance(ov, jax.core.Var):
                self.alias[id(iv)] = ov

    def canon(self, var: Any) -> Any:
        seen = set()
        while id(var) in self.alias and id(var) not in seen:
            seen.add(id(var))
            var = self.alias[id(var)]
        return var

    def _walk(self, jaxpr: Any, path: Tuple[int, ...],
              in_pallas: bool) -> None:
        for i, eqn in enumerate(jaxpr.eqns):
            loc = "/".join(map(str, path + (i,)))
            self.eqns.append((loc, eqn, in_pallas))
            for inner, entered in _subjaxprs(eqn):
                inner_jaxpr = getattr(inner, "jaxpr", inner)
                if eqn.primitive.name in ("pjit", "closed_call", "core_call",
                                          "remat", "checkpoint"):
                    self._link(inner_jaxpr.invars, eqn.invars)
                    self._link(eqn.outvars, inner_jaxpr.outvars)
                self._walk(inner_jaxpr, path + (i,), in_pallas or entered)
        # producer/consumer maps on canonical vars (second pass so aliases
        # registered above resolve)
        if not path:
            for loc, eqn, pl in self.eqns:
                for v in eqn.outvars:
                    self.producer.setdefault(id(self.canon(v)), (eqn, loc, pl))
                for v in eqn.invars:
                    if hasattr(v, "aval"):
                        self.consumers.setdefault(
                            id(self.canon(v)), []).append((eqn, loc, pl))


def _dtype_of(v: Any) -> str:
    return str(getattr(getattr(v, "aval", None), "dtype", ""))


def _crossed_bf16_upcast(g: _FlatGraph, var: Any,
                         limit: int = 400) -> bool:
    """Backward walk through transparent ops: did this value pass a
    bf16 -> f32 convert?"""
    stack, seen = [g.canon(var)], set()
    while stack and len(seen) < limit:
        v = stack.pop()
        if id(v) in seen:
            continue
        seen.add(id(v))
        prod = g.producer.get(id(v))
        if prod is None:
            continue
        eqn, _, _ = prod
        name = eqn.primitive.name
        if name == "convert_element_type":
            if (_dtype_of(eqn.invars[0]) == "bfloat16"
                    and _dtype_of(eqn.outvars[0]) == "float32"):
                return True
        if name in _TRANSPARENT or name == "pjit":
            stack.extend(g.canon(iv) for iv in eqn.invars
                         if hasattr(iv, "aval"))
    return False


def _has_quant_ancestor(g: _FlatGraph, var: Any, quant_dtypes: Sequence[str],
                        limit: int = 800) -> bool:
    """Backward walk through ANY primitive: does an int8-family value feed
    this operand?  (The fused-dequant exemption: a GEMM against dequantized
    weights legitimately runs in f32.)"""
    stack, seen = [g.canon(var)], set()
    while stack and len(seen) < limit:
        v = stack.pop()
        if id(v) in seen:
            continue
        seen.add(id(v))
        if _dtype_of(v) in quant_dtypes:
            return True
        prod = g.producer.get(id(v))
        if prod is None:
            continue
        eqn, _, _ = prod
        stack.extend(g.canon(iv) for iv in eqn.invars if hasattr(iv, "aval"))
    return False


def _downcast_downstream(g: _FlatGraph, var: Any,
                         limit: int = 400) -> bool:
    """Forward walk through elementwise ops: is this f32 value converted
    back down to bf16?  (If it stays f32 — logits, accumulators — the wide
    compute was the contract, not a silent upcast.)"""
    stack, seen = [g.canon(var)], set()
    while stack and len(seen) < limit:
        v = stack.pop()
        if id(v) in seen:
            continue
        seen.add(id(v))
        for eqn, _, _ in g.consumers.get(id(v), ()):
            name = eqn.primitive.name
            if (name == "convert_element_type"
                    and _dtype_of(eqn.outvars[0]) == "bfloat16"):
                return True
            if name in _FORWARD or name == "pjit":
                stack.extend(g.canon(ov) for ov in eqn.outvars)
    return False


def check_dtype_policy(entry: EntryPoint, jaxpr: Any,
                       mode: str) -> List[AuditFinding]:
    out: List[AuditFinding] = []
    quant = tuple(entry.quant_dtypes) or ()
    need_act = entry.activation_dtype == "bfloat16"
    need_quant = bool(quant) and mode == "pallas"
    if not (need_act or need_quant):
        return []
    g = _FlatGraph(jaxpr)

    if need_act:
        for loc, eqn, in_pallas in g.eqns:
            if in_pallas or eqn.primitive.name != "dot_general":
                continue
            if _dtype_of(eqn.outvars[0]) != "float32":
                continue
            upcast = any(_crossed_bf16_upcast(g, v) for v in eqn.invars
                         if hasattr(v, "aval"))
            if not upcast:
                continue
            if any(_has_quant_ancestor(g, v, _QUANT_DTYPES_DEFAULT)
                   for v in eqn.invars if hasattr(v, "aval")):
                continue        # fused-dequant contract: wide GEMM is the point
            if _downcast_downstream(g, eqn.outvars[0]):
                out.append(AuditFinding(
                    entry.name, "dtype-policy", "dot_general", loc,
                    f"silent f32 upcast (mode={mode}): a GEMM runs in f32 on "
                    f"operands upcast from bfloat16 and its result is "
                    f"immediately downcast back — run it in bf16 (or keep "
                    f"the f32 result if wide accumulation was intended)",
                    _slice(eqn)))

    if need_quant:
        for loc, eqn, in_pallas in g.eqns:
            if in_pallas or eqn.primitive.name != "convert_element_type":
                continue
            src = _dtype_of(eqn.invars[0])
            dst = _dtype_of(eqn.outvars[0])
            if src in quant and dst.startswith("float"):
                out.append(AuditFinding(
                    entry.name, "dtype-policy", "convert_element_type", loc,
                    f"quantized operand widens {src} -> {dst} outside a "
                    f"fused pallas kernel under mode={mode}; dequantization "
                    f"must stay inside the kernel tier",
                    _slice(eqn)))
    return out


# --------------------------------------------------------------------------
# rule: donation-honored
# --------------------------------------------------------------------------
def check_donation(entry: EntryPoint, mode: str) -> List[AuditFinding]:
    import jax
    if not entry.donate:
        return []
    fn = entry.fn
    jitted = fn if hasattr(fn, "lower") else jax.jit(
        fn, donate_argnums=entry.donate)
    out: List[AuditFinding] = []
    with _kernel_mode(mode):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            lowered = jitted.lower(*entry.args)
        dropped = [str(w.message) for w in caught
                   if "donated buffers were not usable" in str(w.message)]
    if dropped:
        detail = dropped[0].splitlines()[0]
        out.append(AuditFinding(
            entry.name, "donation-honored", "-", "-",
            f"declared donation dropped at lowering (mode={mode}): {detail} "
            f"— the annotated buffer is never aliased to an output, so the "
            f"pool is silently double-buffered"))
        return out
    n_aliased = lowered.as_text().count("tf.aliasing_output")
    if n_aliased == 0:
        out.append(AuditFinding(
            entry.name, "donation-honored", "-", "-",
            f"lowering records no input/output aliasing (mode={mode}) "
            f"despite donate_argnums={entry.donate}; donation is annotated "
            f"but not honored"))
    return out


# --------------------------------------------------------------------------
# rule: variant-budget
# --------------------------------------------------------------------------
def _signature(args: Any) -> Tuple[Any, ...]:
    import jax
    return tuple((tuple(x.shape), str(x.dtype))
                 for x in jax.tree_util.tree_leaves(args))


def check_variant_budget(entry: EntryPoint) -> List[AuditFinding]:
    sigs = {_signature(b) for b in entry.builds()}
    if len(sigs) == entry.variant_budget:
        return []
    return [AuditFinding(
        entry.name, "variant-budget", "-", "-",
        f"the declared steady-state shape set compiles {len(sigs)} distinct "
        f"variant(s) but the entry budgets exactly {entry.variant_budget}; "
        f"either a padding/canonicalization step regressed (recompiles at "
        f"serve time) or the declared budget is stale")]


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
def audit_entry(entry: EntryPoint,
                modes: Optional[Sequence[str]] = None) -> List[AuditFinding]:
    """Run every applicable rule for one entry across its kernel modes."""
    findings: List[AuditFinding] = []
    run_modes = [m for m in (modes or entry.modes) if m in entry.modes]
    # the oracle mode must run for the dense positive control even when the
    # caller restricts modes (e.g. CI's REPRO_KERNEL_MODE=pallas pass)
    if (entry.dense_shapes and entry.dense_oracle_mode
            and entry.dense_oracle_mode in entry.modes
            and entry.dense_oracle_mode not in run_modes):
        run_modes = [entry.dense_oracle_mode] + run_modes
    oracle_seen: Set[Tuple[int, ...]] = set()
    oracle_ran = False
    for mode in run_modes:
        jaxpr = _trace(entry, mode)
        findings += check_host_sync(entry, jaxpr, mode)
        findings += check_dense_gather(entry, jaxpr, mode, oracle_seen)
        if mode == entry.dense_oracle_mode:
            oracle_ran = True
        findings += check_dtype_policy(entry, jaxpr, mode)
        findings += check_donation(entry, mode)
    findings += oracle_control_findings(entry, oracle_seen, oracle_ran)
    findings += check_variant_budget(entry)
    return [f for f in findings if not entry.suppresses(f.rule)]


def run_audit(registry: Optional[Sequence[EntryPoint]] = None,
              modes: Optional[Sequence[str]] = None,
              drift: Optional[Sequence[Tuple[str, str, str]]] = None
              ) -> List[AuditFinding]:
    """Audit a registry (default: the real one).  ``modes`` restricts the
    kernel modes traced (None = each entry's declared modes)."""
    if registry is None:
        registry, drift = build_registry()
    findings: List[AuditFinding] = []
    for name, target, err in (drift or ()):
        findings.append(AuditFinding(
            name, "config-drift", "-", "-",
            f"registered entry point target '{target}' no longer resolves "
            f"({err}); update tools/analysis/entrypoints.py if it moved"))
    for entry in registry:
        try:
            resolve_target(entry.target)
        except Exception as e:  # noqa: BLE001
            findings.append(AuditFinding(
                entry.name, "config-drift", "-", "-",
                f"registered entry point target '{entry.target}' no longer "
                f"resolves ({type(e).__name__}: {e}); update "
                f"tools/analysis/entrypoints.py if it moved"))
            continue
        findings += audit_entry(entry, modes)
    return findings


# --------------------------------------------------------------------------
# bench bridge + CI trace cache
# --------------------------------------------------------------------------
def paged_decode_dense_gather_free() -> int:
    """The PR-7 bench row, now answered by the auditor (single source of
    truth): 1 iff the paged decode entry points are dense-gather-free under
    the kernel tier AND the XLA oracle still materializes the dense shape."""
    registry, drift = build_registry()
    if drift:
        return 0
    decode = [e for e in registry if e.dense_shapes]
    if not decode:
        return 0
    findings: List[AuditFinding] = []
    for e in decode:
        findings += [f for f in audit_entry(e, modes=BOTH_MODES)
                     if f.rule == "no-dense-gather"]
    return 0 if findings else 1


def tree_digest(root: pathlib.Path) -> str:
    """Digest of every source file the traced jaxprs depend on — the CI
    cache key for skipping a re-trace on unchanged trees."""
    import jax
    h = hashlib.sha256()
    h.update(jax.__version__.encode())
    for pat in ("src/repro/**/*.py", "tools/analysis/*.py"):
        for p in sorted(pathlib.Path(root).glob(pat)):
            h.update(str(p.relative_to(root)).encode())
            h.update(p.read_bytes())
    return h.hexdigest()


def cached_ok(cache_file: pathlib.Path, digest: str) -> bool:
    try:
        data = json.loads(pathlib.Path(cache_file).read_text())
    except (OSError, ValueError):
        return False
    return bool(data.get("clean")) and data.get("digest") == digest


def write_cache(cache_file: pathlib.Path, digest: str) -> None:
    out = pathlib.Path(cache_file)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"digest": digest, "clean": True}) + "\n")


def load_registry_module(path: pathlib.Path) -> Iterable[EntryPoint]:
    """Load a registry module (``REGISTRY`` list) from a file path — used by
    the known-bad fixture trees under tests/fixtures/analysis/."""
    import importlib.util
    spec = importlib.util.spec_from_file_location("audit_fixture_registry",
                                                  str(path))
    assert spec is not None and spec.loader is not None, path
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return list(mod.REGISTRY)
