"""CLI for the invariant checkers: ``python -m tools.analysis [options]``.

Exit status 0 iff no checker reports a violation.  Every violation prints as
``file:line: [checker] invariant — message`` so CI annotations and editors
can jump straight to the offending line.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Optional, Sequence

from tools.analysis import CHECKERS, REPO_ROOT, run_all


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Run checkers over ``--root`` (default: the repo); nonzero on any
    violation."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="repo-specific static invariant checkers")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="tree to analyze (default: the repo root; tests "
                         "point this at known-bad fixture trees)")
    ap.add_argument("--checker", action="append", dest="checkers",
                    metavar="NAME", choices=sorted(CHECKERS),
                    help="run only this checker (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list available checkers and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(CHECKERS):
            print(name)
        return 0

    root = pathlib.Path(args.root).resolve()
    results = run_all(root, args.checkers)
    total = 0
    for name in sorted(results):
        violations = results[name]
        if violations:
            total += len(violations)
            for v in sorted(violations, key=lambda v: (v.file, v.line)):
                print(v.render())
        else:
            print(f"[{name}] OK")
    if total:
        print(f"\ntools.analysis: {total} violation(s) in {root}")
        return 1
    print(f"tools.analysis: OK ({len(results)} checker(s) clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
