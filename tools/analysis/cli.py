"""CLI for the invariant checkers: ``python -m tools.analysis [options]``.

Exit status 0 iff no checker reports a violation.  Every violation prints as
``file:line: [checker] invariant — message`` so CI annotations and editors
can jump straight to the offending line.

``--audit`` switches to the trace-time jaxpr auditor (the five-rule dynamic
twin of the AST checkers): it traces every registered hot-path entry point
under both ``REPRO_KERNEL_MODE`` values — or only the preset one, when the
variable is already pinned in the environment — and prints violations as
``entrypoint: [rule] primitive @ eqn — message``.
"""

from __future__ import annotations

import argparse
import os
import pathlib
import sys
from typing import Optional, Sequence

from tools.analysis import CHECKERS, REPO_ROOT, run_all


def _run_audit(args: argparse.Namespace) -> int:
    from tools.analysis import jaxpr_audit

    cache = pathlib.Path(args.audit_cache) if args.audit_cache else None
    digest = None
    if cache is not None:
        digest = jaxpr_audit.tree_digest(REPO_ROOT)
        if jaxpr_audit.cached_ok(cache, digest):
            print(f"tools.analysis --audit: cached clean ({digest[:12]})")
            return 0

    modes: Optional[Sequence[str]] = None
    env_mode = os.environ.get("REPRO_KERNEL_MODE", "")
    if env_mode in ("xla", "pallas"):
        modes = (env_mode,)

    if args.audit_registry:
        registry = list(jaxpr_audit.load_registry_module(
            pathlib.Path(args.audit_registry)))
        findings = jaxpr_audit.run_audit(registry, modes)
    else:
        findings = jaxpr_audit.run_audit(None, modes)

    for f in findings:
        print(f.render())
        if f.jaxpr_slice:
            print(f"    {f.jaxpr_slice}")
    if findings:
        print(f"\ntools.analysis --audit: {len(findings)} violation(s)")
        return 1
    label = ",".join(modes) if modes else "xla,pallas"
    print(f"tools.analysis --audit: OK (modes: {label})")
    if cache is not None and digest is not None and not args.audit_registry:
        jaxpr_audit.write_cache(cache, digest)
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Run checkers over ``--root`` (default: the repo); nonzero on any
    violation."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="repo-specific static invariant checkers")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="tree to analyze (default: the repo root; tests "
                         "point this at known-bad fixture trees)")
    ap.add_argument("--checker", action="append", dest="checkers",
                    metavar="NAME", choices=sorted(CHECKERS),
                    help="run only this checker (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list available checkers and exit")
    ap.add_argument("--audit", action="store_true",
                    help="run the trace-time jaxpr auditor over the "
                         "registered hot-path entry points (honors a preset "
                         "REPRO_KERNEL_MODE; both modes otherwise)")
    ap.add_argument("--audit-registry", metavar="PATH", default=None,
                    help="audit the REGISTRY list in this module instead of "
                         "the real registry (tests point this at known-bad "
                         "fixture registries)")
    ap.add_argument("--audit-cache", metavar="PATH", default=None,
                    help="skip the audit when this cache file records a "
                         "clean run for the current source-tree digest; "
                         "refreshed after a clean run")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(CHECKERS):
            print(name)
        return 0

    if args.audit:
        return _run_audit(args)

    root = pathlib.Path(args.root).resolve()
    results = run_all(root, args.checkers)
    total = 0
    for name in sorted(results):
        violations = results[name]
        if violations:
            total += len(violations)
            for v in sorted(violations, key=lambda v: (v.file, v.line)):
                print(v.render())
        else:
            print(f"[{name}] OK")
    if total:
        print(f"\ntools.analysis: {total} violation(s) in {root}")
        return 1
    print(f"tools.analysis: OK ({len(results)} checker(s) clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
