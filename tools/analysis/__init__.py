"""Repo-specific static invariant checkers (``python -m tools.analysis``).

Four AST-driven checkers over the staging/serving core:

* ``thread-confinement`` — no path from executor-submitted code into cache
  metadata mutation or other ``# owner: main-thread`` state;
* ``hot-path-purity`` — jit-traced decode code contains no host syncs, and
  pool buffers passed to jitted functions are donated;
* ``stats-schema`` — engine / simulator / server stats keys stay in sync
  with each other and with docs/METRICS.md;
* ``protocol-conformance`` — every ``*Backend`` implements the full
  `InferenceBackend` surface with matching signatures.

See docs/ANALYSIS.md for the annotation convention and suppression syntax.
"""

from __future__ import annotations

import pathlib
from typing import Dict, List, Optional, Sequence

from tools.analysis import (hot_path_purity, protocol_conformance,
                            stats_schema, thread_confinement)
from tools.analysis.astutil import Violation, suppressed

CHECKERS = {
    thread_confinement.CHECKER: thread_confinement.run,
    hot_path_purity.CHECKER: hot_path_purity.run,
    stats_schema.CHECKER: stats_schema.run,
    protocol_conformance.CHECKER: protocol_conformance.run,
}

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent.parent


def run_all(root: Optional[pathlib.Path] = None,
            names: Optional[Sequence[str]] = None
            ) -> Dict[str, List[Violation]]:
    """Run the selected checkers; inline ``# analysis: ignore`` suppressions
    are applied here so every checker gets them uniformly."""
    root = pathlib.Path(root) if root is not None else REPO_ROOT
    selected = list(names) if names else list(CHECKERS)
    out: Dict[str, List[Violation]] = {}
    for name in selected:
        if name not in CHECKERS:
            raise KeyError(f"unknown checker {name!r}; "
                           f"known: {', '.join(sorted(CHECKERS))}")
        out[name] = [v for v in CHECKERS[name](root)
                     if not suppressed(root, v)]
    return out
