"""Thread-confinement checker.

The staging core (`core/loader.py` + `core/cache.py` + `core/engine.py`)
runs background copy workers with zero locks; its correctness rests on the
invariant stated in the `StagingEngine` docstring: **cache metadata (and the
scheduler's queue state) is touched ONLY on the main thread** — executor
threads stage bytes from read-only host storage and nothing else.

This checker enforces that statically:

1. every callable handed to a stream executor (``<pool>.submit(fn, ...)``)
   or registered as a GC finalizer (``weakref.finalize(obj, fn, ...)``) is an
   entry point into background-thread code;
2. the call graph is walked from those entry points, following edges the AST
   can prove — ``self.method``, bare module functions, and constructor-bound
   callbacks (e.g. ``StagingEngine(loader, self._stage, self._commit_staged)``
   binds ``stage_fn``/``commit_fn`` inside `OffloadEngine.__init__`);
3. any reachable function that *calls* a method annotated
   ``# owner: main-thread`` (the `MultidimensionalCache` mutators:
   admit / pin / begin_inflight / cancel_inflight / ...), *writes* an
   attribute so annotated (``self._pending``, ``self.downgraded``, the device
   pools), or mutates such an attribute through a container method
   (``.append`` / ``.add`` / ``.pop`` / ...) is a violation, reported with
   the full call chain from the submit site.
"""

from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

from tools.analysis.astutil import (CodeIndex, FuncInfo, SourceFile,
                                    Violation, attr_chain, load_source,
                                    missing_file_violation, owner_annotations)

CHECKER = "thread-confinement"

DEFAULT_FILES = (
    "src/repro/core/loader.py",
    "src/repro/core/cache.py",
    "src/repro/core/engine.py",
    # the paged-KV pool's sharing metadata (refcounts, free list, radix
    # trie, COW debt) is main-thread-owned exactly like the expert cache's
    "src/repro/models/kv_pages.py",
    # the fleet heat map feeds cache priorities mid-eviction and the SLO
    # ordering helpers run inside the scheduler step: both belong to the
    # engine/scheduler thread, never to a stream executor
    "src/repro/core/fleet_heat.py",
    "src/repro/serving/workload.py",
)

# container methods that mutate the receiver in place
CONTAINER_MUTATORS = {
    "add", "append", "appendleft", "clear", "discard", "extend", "insert",
    "pop", "popleft", "remove", "setdefault", "update",
}


def _callback_bindings(idx: CodeIndex) -> Dict[Tuple[str, str], str]:
    """Resolve constructor-injected callbacks to their definitions.

    For every call site ``self.X = ClassName(a, b, ...)`` whose positional /
    keyword args include ``self._meth``, match them against
    ``ClassName.__init__``'s parameters and the ``self.attr = param``
    assignments inside it.  Returns {(ClassName, attr_or_param): qualname}.
    """
    param_targets: Dict[Tuple[str, str], str] = {}
    for info in idx.functions.values():
        for call in ast.walk(info.node):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Name)
                    and call.func.id in idx.classes):
                continue
            cls = call.func.id
            init = idx.resolve_method(cls, "__init__")
            if init is None:
                continue
            params = [a.arg for a in init.node.args.args][1:]   # drop self
            pairs = list(zip(params, call.args))
            pairs += [(kw.arg, kw.value) for kw in call.keywords if kw.arg]
            for pname, arg in pairs:
                if (isinstance(arg, ast.Attribute)
                        and isinstance(arg.value, ast.Name)
                        and arg.value.id == "self" and info.cls):
                    target = idx.resolve_method(info.cls, arg.attr)
                    if target is not None:
                        param_targets[(cls, pname)] = target.qualname
    # propagate through `self.attr = param` in each __init__
    bindings = dict(param_targets)
    for cls in idx.classes:
        init = idx.resolve_method(cls, "__init__")
        if init is None:
            continue
        for node in ast.walk(init.node):
            if not isinstance(node, ast.Assign):
                continue
            if not (isinstance(node.value, ast.Name)
                    and (cls, node.value.id) in param_targets):
                continue
            for t in node.targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    bindings[(cls, t.attr)] = param_targets[(cls,
                                                             node.value.id)]
    return bindings


def _resolve_callable(idx: CodeIndex, info: FuncInfo,
                      node: ast.AST) -> Optional[FuncInfo]:
    """Resolve a callable *expression* (submit arg or call target)."""
    if isinstance(node, ast.Name):
        return idx.module_functions.get(node.id)
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
        if node.value.id == "self":
            return idx.resolve_method(info.cls, node.attr)
        if node.value.id in idx.classes:            # Class.staticmethod
            return idx.resolve_method(node.value.id, node.attr)
    return None


def _find_entries(idx: CodeIndex) -> List[Tuple[FuncInfo, FuncInfo, int]]:
    """(entry_fn, submitting_fn, submit_lineno) for every executor submit /
    finalizer registration whose callable resolves."""
    entries = []
    for info in idx.functions.values():
        for call in ast.walk(info.node):
            if not isinstance(call, ast.Call):
                continue
            target_arg = None
            if (isinstance(call.func, ast.Attribute)
                    and call.func.attr == "submit" and call.args):
                target_arg = call.args[0]
            elif (isinstance(call.func, ast.Attribute)
                    and call.func.attr == "finalize"
                    and attr_chain(call.func)[:1] == ["weakref"]
                    and len(call.args) >= 2):
                target_arg = call.args[1]
            if target_arg is None:
                continue
            entry = _resolve_callable(idx, info, target_arg)
            if entry is not None:
                entries.append((entry, info, call.lineno))
    return entries


def _chain(parents: Dict[str, str], qualname: str) -> str:
    parts = [qualname]
    while qualname in parents:
        qualname = parents[qualname]
        parts.append(qualname)
    return " -> ".join(reversed(parts))


def run(root: pathlib.Path,
        rel_files: Sequence[str] = DEFAULT_FILES) -> List[Violation]:
    """Check thread confinement over ``root``-relative ``rel_files``."""
    violations: List[Violation] = []
    files: List[SourceFile] = []
    for rel in rel_files:
        sf = load_source(root, rel)
        if sf is None:
            violations.append(missing_file_violation(CHECKER, rel))
        else:
            files.append(sf)
    if not files:
        return violations

    idx = CodeIndex(files)
    owned_methods, owned_attrs = owner_annotations(files)
    bindings = _callback_bindings(idx)
    entries = _find_entries(idx)

    # BFS over the provable call graph from background entry points
    parents: Dict[str, str] = {}
    queue: List[FuncInfo] = []
    seen = set()
    for entry, submitter, lineno in entries:
        if entry.qualname not in seen:
            seen.add(entry.qualname)
            parents[entry.qualname] = (f"{submitter.qualname} "
                                       f"(submit at {submitter.sf.rel}:"
                                       f"{lineno})")
            queue.append(entry)

    reachable: List[FuncInfo] = []
    while queue:
        info = queue.pop(0)
        reachable.append(info)
        for call in ast.walk(info.node):
            if not isinstance(call, ast.Call):
                continue
            target: Optional[FuncInfo] = None
            fn = call.func
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id == "self"):
                target = idx.resolve_method(info.cls, fn.attr)
                if target is None and info.cls:
                    bound = bindings.get((info.cls, fn.attr))
                    if bound is not None:
                        target = idx.functions.get(bound)
            elif isinstance(fn, ast.Name):
                target = idx.module_functions.get(fn.id)
            if target is not None and target.qualname not in seen:
                seen.add(target.qualname)
                parents[target.qualname] = info.qualname
                queue.append(target)

    # scan everything reachable from a background thread
    for info in reachable:
        chain = _chain(parents, info.qualname)
        for node in ast.walk(info.node):
            if isinstance(node, ast.Call):
                name = (node.func.attr if isinstance(node.func, ast.Attribute)
                        else node.func.id if isinstance(node.func, ast.Name)
                        else None)
                if name in owned_methods:
                    dfile, dline = owned_methods[name]
                    violations.append(Violation(
                        CHECKER, "main-thread-owned-call", info.sf.rel,
                        node.lineno,
                        f"executor-submitted code calls '{name}' "
                        f"(# owner: main-thread at {dfile}:{dline}); "
                        f"path: {chain}"))
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in CONTAINER_MUTATORS):
                    recv = attr_chain(node.func.value)
                    hit = next((a for a in recv[1:] if a in owned_attrs),
                               None)
                    if recv[:1] == ["self"] and hit:
                        dfile, dline = owned_attrs[hit]
                        violations.append(Violation(
                            CHECKER, "main-thread-owned-mutation",
                            info.sf.rel, node.lineno,
                            f"executor-submitted code mutates 'self.{hit}' "
                            f"via .{node.func.attr}() (# owner: main-thread "
                            f"at {dfile}:{dline}); path: {chain}"))
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    while isinstance(t, ast.Subscript):
                        t = t.value
                    names = attr_chain(t)
                    if names[:1] != ["self"]:
                        continue
                    hit = next((a for a in names[1:] if a in owned_attrs),
                               None)
                    if hit:
                        dfile, dline = owned_attrs[hit]
                        violations.append(Violation(
                            CHECKER, "main-thread-owned-write", info.sf.rel,
                            node.lineno,
                            f"executor-submitted code writes 'self.{hit}' "
                            f"(# owner: main-thread at {dfile}:{dline}); "
                            f"path: {chain}"))
    return violations
