"""Hot-path entry-point registry for the trace-time jaxpr auditor.

Every jitted function on the serving hot path is declared here once, with
tiny abstract input shapes (`jax.ShapeDtypeStruct` — nothing is executed,
only traced), the donation the production registration declares, the shapes
a dense pool gather would materialize, and the steady-state shape set the
variant-budget rule counts compile signatures over.  The auditor
(`tools/analysis/jaxpr_audit.py`) traces each entry under both
``REPRO_KERNEL_MODE`` values and applies the five hot-path rules.

Registry conventions (mirroring the PR-6 checkers):

* each entry names its production ``target`` as ``"module:Qual.name"``; a
  target that no longer resolves fires ``config-drift`` instead of crashing;
* a trailing ``# audit: ignore[rule, ...]`` on the ``entry(`` line
  suppresses the named rules for that entry (bare ``ignore`` matches all);
* donation tuples come from the same constants/sites production registers
  (``Model.PAGED_DECODE_DONATE``, ``OffloadEngine._scatter_fn``'s jit, the
  ``_copy_page`` module jit), so the donation-honored rule audits the real
  declaration, not a copy.
"""

from __future__ import annotations

import dataclasses
import importlib
import inspect
import re
from typing import Any, Callable, List, Optional, Tuple

AUDIT_SUPPRESS_RE = re.compile(r"#\s*audit:\s*ignore(?:\[(?P<names>[^\]]*)\])?")

XLA = "xla"
PALLAS = "pallas"
BOTH_MODES = (XLA, PALLAS)


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One registered hot-path jit and the contract the auditor checks."""

    name: str                    # short id, e.g. "engine.grouped_ffn"
    target: str                  # "repro.core.engine:OffloadEngine._grouped_ffn"
    fn: Callable[..., Any]       # the callable to trace (raw or already jitted)
    args: Tuple[Any, ...]        # primary abstract build (ShapeDtypeStructs ok)
    donate: Tuple[int, ...] = ()         # donated argnums, as production declares
    pool_args: Tuple[int, ...] = ()      # which donated args are pool buffers
    dense_shapes: Tuple[Tuple[int, ...], ...] = ()   # forbidden intermediates
    dense_oracle_mode: Optional[str] = XLA   # mode REQUIRED to show the dense
    # shape (the self-validating positive control inherited from the PR-7
    # bench scan: if the oracle stops gathering, the check is broken, not
    # passing); None disables the control
    activation_dtype: Optional[str] = None   # "bfloat16" arms the dtype rule
    quant_dtypes: Tuple[str, ...] = ()       # dtypes that may only widen
    # inside fused (pallas_call) kernels when mode == "pallas"
    variant_builds: Tuple[Tuple[Any, ...], ...] = ()   # steady-state shape set
    variant_budget: int = 1      # distinct compile signatures the set may cost
    modes: Tuple[str, ...] = BOTH_MODES
    ignore: Tuple[str, ...] = ()     # rules suppressed via "# audit: ignore[...]"
    bare_ignore: bool = False
    srcfile: str = ""
    lineno: int = 0

    def builds(self) -> Tuple[Tuple[Any, ...], ...]:
        return self.variant_builds if self.variant_builds else (self.args,)

    def suppresses(self, rule: str) -> bool:
        return self.bare_ignore or rule in self.ignore


def entry(**kw: Any) -> EntryPoint:
    """EntryPoint factory that records its own call site, so a trailing
    ``# audit: ignore[rule]`` comment on the ``entry(`` line suppresses the
    named rules — same line-anchored convention as ``# analysis: ignore``."""
    frame = inspect.currentframe()
    caller = frame.f_back if frame is not None else None
    srcfile, lineno = "", 0
    ignore: Tuple[str, ...] = ()
    bare = False
    if caller is not None:
        srcfile = caller.f_code.co_filename
        lineno = caller.f_lineno
        try:
            with open(srcfile) as fh:
                line = fh.read().splitlines()[lineno - 1]
            m = AUDIT_SUPPRESS_RE.search(line)
            if m:
                names = m.group("names")
                if names:
                    ignore = tuple(n.strip() for n in names.split(","))
                else:
                    bare = True
        except (OSError, IndexError):
            pass
    return EntryPoint(srcfile=srcfile, lineno=lineno, ignore=ignore,
                      bare_ignore=bare, **kw)


def resolve_target(target: str) -> Any:
    """Resolve ``"module:attr.path"`` to the live object; raises on drift."""
    mod_name, _, attr_path = target.partition(":")
    obj: Any = importlib.import_module(mod_name)
    for part in attr_path.split("."):
        obj = getattr(obj, part)
    return obj


# --------------------------------------------------------------------------
# the real registry
# --------------------------------------------------------------------------
def _smoke() -> Tuple[Any, Any, Any]:
    """Tiny bfloat16 mixtral smoke model + grouped/paged engine, built once
    per audit run.  bfloat16 (not the test suites' float32) so the dtype-
    policy rule sees the production activation width; nothing is executed,
    so numerics never matter."""
    import dataclasses as dc

    import jax

    from repro.configs import get_config, smoke_variant
    from repro.core import EngineConfig, OffloadEngine
    from repro.models import build_model

    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=2, d_model=64,
                        vocab=128)
    cfg = dc.replace(cfg, dtype="bfloat16",
                     moe=dc.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = OffloadEngine(m, params, EngineConfig(
        hi_slots=8, lo_slots=4, grouped=True, paged_kv=True, kv_page_size=4,
        kv_pages=32, link_gbps=8.0))
    eng.start_batch(2, 24)
    return m, params, eng


def _scatter_builds(pools: Any, values_shape: Any,
                    dtypes: Any) -> Tuple[Tuple[Any, ...], ...]:
    """Variant-budget shape set for the commit scatter: staged counts 1..8
    padded with the engine's own `pad_pow2`, so the set compiles exactly
    log2(pool) signatures — the static twin of the runtime recompile guard.
    Removing the production padding changes these builds and blows the
    declared budget."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import pad_pow2

    S = jax.ShapeDtypeStruct
    builds = []
    for staged in range(1, 9):
        n = len(pad_pow2(list(range(staged))))
        idx = S((n,), jnp.int32)
        values = [S((n, *shape), dt) for shape, dt in zip(values_shape, dtypes)]
        builds.append((pools, idx, values))
    return tuple(builds)


def build_registry() -> Tuple[List[EntryPoint], List[Tuple[str, str, str]]]:
    """Build the hot-path registry against the live tree.

    Returns ``(entries, drift)`` where ``drift`` lists
    ``(entry_name, target, error)`` for every registered entry point whose
    production target no longer resolves — the auditor turns those into
    ``config-drift`` findings instead of crashing mid-trace."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    S = jax.ShapeDtypeStruct
    entries: List[EntryPoint] = []
    drift: List[Tuple[str, str, str]] = []

    def guard(name: str, target: str,
              builder: Callable[[], EntryPoint]) -> None:
        try:
            resolve_target(target)
            entries.append(builder())
        except Exception as e:  # noqa: BLE001 — drift must never crash the audit
            drift.append((name, target, f"{type(e).__name__}: {e}"))

    m, params, eng = _smoke()
    try:
        cfg = m.cfg
        b, k = 2, cfg.moe.top_k
        d, f = cfg.d_model, cfg.moe.d_ff_expert
        act = jnp.bfloat16
        li = eng.moe_layers[0]
        kp_shape = eng.kv_pool.k[li].shape          # (P, psz, Hkv, hd)
        npages, psz, hkv, hd = kp_shape
        table_shape = tuple(eng.kv_pool.table_device().shape)   # (B, maxp)
        maxp = table_shape[1]
        dense = (b, maxp * psz, hkv, hd)
        layer_p = eng.layer_params[li]
        wi_cols = eng.pool_hi["wi"].shape[-1]

        # ---- the three Pallas-wrapper ops (kernels/ops.py) ----
        ops_pfd_args = (S((b, cfg.num_heads, hd), act), S(kp_shape, act),
                        S(kp_shape, act), S(table_shape, jnp.int32),
                        S((b,), jnp.int32))
        guard("ops.paged_flash_decode",
              "repro.kernels.ops:paged_flash_decode",
              lambda: entry(
                  name="ops.paged_flash_decode",
                  target="repro.kernels.ops:paged_flash_decode",
                  fn=lambda q, pk, pv, t, ln: kops.paged_flash_decode(
                      q, pk, pv, t, ln),
                  args=ops_pfd_args,
                  dense_shapes=(dense,)))

        gsz = eng.ecfg.group_size
        bits = eng.ecfg.lo_bits
        pp = b * k
        # combine contracts x (P, F) against the second lo GEMM's quantized
        # wo — row shapes come straight off the engine's lo pool so the
        # declared tiny shapes track the production packing exactly
        wo_data_row = eng.pool_lo["wo_data"].shape[1:]
        wo_scale_row = eng.pool_lo["wo_scale"].shape[1:]
        gdc_args = (S((pp, f), act),
                    S((pp, *wo_data_row), jnp.int8),
                    S((pp, *wo_scale_row), jnp.float32),
                    S((pp,), jnp.int32), S((pp,), jnp.float32))
        guard("ops.grouped_dequant_combine",
              "repro.kernels.ops:grouped_dequant_combine",
              lambda: entry(
                  name="ops.grouped_dequant_combine",
                  target="repro.kernels.ops:grouped_dequant_combine",
                  fn=lambda x, dq, sc, rows, w: kops.grouped_dequant_combine(
                      x, dq, sc, rows, w, bits=bits, group_size=gsz,
                      num_rows=b),
                  args=gdc_args,
                  activation_dtype="bfloat16",
                  quant_dtypes=("int8",)))

        e_experts = cfg.moe.num_experts
        guard("ops.gating_topk", "repro.kernels.ops:gating_topk",
              lambda: entry(
                  name="ops.gating_topk",
                  target="repro.kernels.ops:gating_topk",
                  fn=lambda x, gates: kops.gating_topk(x, gates, top_k=k),
                  args=(S((b, d), act), S((1, d, e_experts), jnp.float32)),
                  activation_dtype="bfloat16"))

        # ---- engine grouped decode step ----
        hi_pool = {n: S(a.shape, a.dtype) for n, a in eng.pool_hi.items()}
        lo_pool = {n: S(a.shape, a.dtype) for n, a in eng.pool_lo.items()}
        idx32 = S((pp,), jnp.int32)
        gffn_args = (hi_pool["wi"], hi_pool["wo"],
                     lo_pool["wi_data"], lo_pool["wi_scale"],
                     lo_pool["wo_data"], lo_pool["wo_scale"],
                     S((pp, *eng.pool_hi["wi"].shape[1:]), eng.dtype),
                     S((pp, *eng.pool_hi["wo"].shape[1:]), eng.dtype),
                     S((pp, *eng.pool_lo["wi_data"].shape[1:]), jnp.int8),
                     S((pp, *eng.pool_lo["wi_scale"].shape[1:]), jnp.float32),
                     S((pp, *eng.pool_lo["wo_data"].shape[1:]), jnp.int8),
                     S((pp, *eng.pool_lo["wo_scale"].shape[1:]), jnp.float32),
                     S((b, 1, d), act),
                     idx32, idx32, idx32, idx32, idx32, idx32,
                     S((b, k), jnp.float32), S((b, k), jnp.float32))
        guard("engine.grouped_ffn",
              "repro.core.engine:OffloadEngine._grouped_ffn",
              lambda: entry(
                  name="engine.grouped_ffn",
                  target="repro.core.engine:OffloadEngine._grouped_ffn",
                  fn=eng._grouped_ffn,
                  args=gffn_args,
                  activation_dtype="bfloat16",
                  quant_dtypes=("int8",)))

        attn_args = (layer_p, S((b, 1, d), act), S(kp_shape, act),
                     S(kp_shape, act), S(table_shape, jnp.int32),
                     S((b,), jnp.int32), S((b,), jnp.bool_))
        guard("engine.attn_paged",
              "repro.core.engine:OffloadEngine._attn_step_paged",
              lambda: entry(
                  name="engine.attn_paged",
                  target="repro.core.engine:OffloadEngine._attn_step_paged",
                  fn=eng._attn_step_paged,
                  args=attn_args,
                  donate=(2, 3), pool_args=(2, 3),
                  dense_shapes=(dense,)))

        # ---- StagingEngine's batched commit scatter (hi / lo pools) ----
        # The traced fns are the PRODUCTION jitted objects out of
        # eng._scatter_fn's cache — donation included.  The scatter is pure
        # index math with no kernel dispatch, so one mode suffices.
        hi_pools = [hi_pool["wi"], hi_pool["wo"]]
        hi_shapes = [eng.pool_hi["wi"].shape[1:], eng.pool_hi["wo"].shape[1:]]
        guard("engine.commit_scatter_hi",
              "repro.core.engine:OffloadEngine._scatter_fn",
              lambda: entry(
                  name="engine.commit_scatter_hi",
                  target="repro.core.engine:OffloadEngine._scatter_fn",
                  fn=eng._scatter_fn(2),
                  args=(hi_pools, S((2,), jnp.int32),
                        [S((2, *s), jnp.float32) for s in hi_shapes]),
                  donate=(0,), pool_args=(0,),
                  variant_builds=_scatter_builds(
                      hi_pools, hi_shapes, [jnp.float32, jnp.float32]),
                  variant_budget=4, modes=(XLA,)))

        lo_names = ("wi_data", "wi_scale", "wo_data", "wo_scale")
        lo_pools = [lo_pool[n] for n in lo_names]
        lo_shapes = [eng.pool_lo[n].shape[1:] for n in lo_names]
        lo_dts = [jnp.int8, jnp.float32, jnp.int8, jnp.float32]
        guard("engine.commit_scatter_lo",
              "repro.core.engine:OffloadEngine._scatter_fn",
              lambda: entry(
                  name="engine.commit_scatter_lo",
                  target="repro.core.engine:OffloadEngine._scatter_fn",
                  fn=eng._scatter_fn(4),
                  args=(lo_pools, S((2,), jnp.int32),
                        [S((2, *s), dt) for s, dt in zip(lo_shapes, lo_dts)]),
                  donate=(0,), pool_args=(0,),
                  variant_builds=_scatter_builds(lo_pools, lo_shapes, lo_dts),
                  variant_budget=4, modes=(XLA,)))

        # ---- paged decode / prefill-chunk jits (model + serving tier) ----
        kpages = [S(eng.kv_pool.k[i].shape, act)
                  for i in range(len(eng.kv_pool.k))]
        vpages = [S(eng.kv_pool.v[i].shape, act)
                  for i in range(len(eng.kv_pool.v))]
        decode_args = (params, kpages, vpages, S(table_shape, jnp.int32),
                       S((b, 1), jnp.int32), S((b,), jnp.int32),
                       S((b,), jnp.bool_))
        guard("model.decode_step_paged",
              "repro.models.model:Model.decode_step_paged",
              lambda: entry(
                  name="model.decode_step_paged",
                  target="repro.models.model:Model.decode_step_paged",
                  fn=m.decode_step_paged,
                  args=decode_args,
                  donate=type(m).PAGED_DECODE_DONATE,
                  pool_args=type(m).PAGED_DECODE_DONATE,
                  dense_shapes=(dense,)))

        chunk = 4
        prefill_args = (params, kpages, vpages, S(table_shape, jnp.int32),
                        S((b, chunk), jnp.int32), S((b,), jnp.int32),
                        S((b,), jnp.int32), S((b,), jnp.int32))
        guard("model.prefill_chunk_paged",
              "repro.models.model:Model.prefill_chunk_paged",
              lambda: entry(
                  name="model.prefill_chunk_paged",
                  target="repro.models.model:Model.prefill_chunk_paged",
                  fn=m.prefill_chunk_paged,
                  args=prefill_args,
                  donate=type(m).PAGED_PREFILL_DONATE,
                  pool_args=type(m).PAGED_PREFILL_DONATE))

        # ---- pool page-copy jit (models/kv_pages.py) ----
        from repro.models import kv_pages as kvp
        guard("kv.copy_page", "repro.models.kv_pages:_copy_page",
              lambda: entry(
                  name="kv.copy_page",
                  target="repro.models.kv_pages:_copy_page",
                  fn=kvp._copy_page,
                  args=(S(kp_shape, act), S((), jnp.int32), S((), jnp.int32)),
                  donate=(0,), pool_args=(0,),
                  modes=(XLA,)))
    finally:
        eng.close()
    return entries, drift
