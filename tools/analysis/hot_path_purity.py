"""Hot-path purity checker.

The decode hot paths (`decode_step_batch` grouped dispatch,
`decode_step_paged`, the jitted chunked-prefill calls) stay fast because of
two hand-established invariants from PRs 2-4:

1. **jitted bodies are pure device code** — no host syncs inside anything
   `jax.jit` traces: ``.item()`` / ``.tolist()`` / ``np.asarray`` /
   ``np.array`` / ``jax.device_get`` / ``block_until_ready`` /
   ``float(...)``/``int(...)`` on non-constants.  (Host-side *wrappers* may
   sync — that is where the step's single device->host transfer lives — so
   only jit-traced regions are scanned.)
2. **pool buffers are donated** — any jitted function taking the expert
   pools or the paged KV pool buffers (params named ``pools`` / ``kp`` /
   ``vp`` / ``k_pages`` / ``v_pages``) must donate them, otherwise every
   step holds two copies of a pool alive and the fixed-P padding win is
   lost.

Jit registrations are discovered syntactically: ``jax.jit(fn, ...)``,
``functools.partial(jax.jit, ...)`` decorators, and the engine's
``self._jit(name, fn, donate=(...))`` helper.  Wrapped callables resolve
through local defs, methods, import aliases, the ``model`` receiver hint,
and one-hop closure factories (``fn = make_prefill_step(...)`` ->
the factory's returned local def).  Unresolvable wrappers are skipped —
the fixtures in tests/fixtures/analysis pin what must resolve.
"""

from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.analysis.astutil import (CodeIndex, FuncInfo, SourceFile,
                                    Violation, attr_chain, load_source,
                                    missing_file_violation)

CHECKER = "hot-path-purity"

DEFAULT_FILES = (
    "src/repro/core/engine.py",
    "src/repro/serving/api.py",
    "src/repro/serving/decode.py",
    "src/repro/models/model.py",
    "src/repro/models/layers.py",
    "src/repro/models/kv_pages.py",
    "src/repro/quant/quantize.py",
    # kernel tier: dispatch wrappers + pallas_call wrappers (jit roots via
    # @functools.partial(jax.jit, ...)) + the jnp ref oracles they fall
    # back to — all traced into the decode hot path
    "src/repro/kernels/ops.py",
    "src/repro/kernels/flash_decode.py",
    "src/repro/kernels/dequant_matmul.py",
    "src/repro/kernels/stacked_gating.py",
    "src/repro/kernels/ref.py",
)

# decode-path entry points that must exist (config-drift guard: a rename
# must not silently empty this checker); cls None = module-level function
REQUIRED_ENTRY_POINTS = (
    ("src/repro/models/model.py", "Model", "decode_step"),
    ("src/repro/models/model.py", "Model", "decode_step_paged"),
    ("src/repro/models/model.py", "Model", "prefill_chunk_paged"),
    ("src/repro/kernels/flash_decode.py", None, "paged_flash_decode_pallas"),
    ("src/repro/kernels/dequant_matmul.py", None,
     "grouped_dequant_combine_pallas"),
    ("src/repro/kernels/dequant_matmul.py", None,
     "grouped_dequant_matmul_pallas"),
    ("src/repro/kernels/stacked_gating.py", None, "gating_topk_pallas"),
    ("src/repro/kernels/ops.py", None, "paged_flash_decode"),
)

# method calls that synchronize device -> host
SYNC_METHOD_CALLS = {"item", "tolist", "block_until_ready"}
# dotted calls that synchronize (innermost alias resolved per file)
SYNC_DOTTED_CALLS = {("np", "asarray"), ("np", "array"),
                     ("numpy", "asarray"), ("numpy", "array"),
                     ("jax", "device_get")}
# jitted-function params that alias device pools and must be donated
POOL_PARAMS = {"pools", "kp", "vp", "k_pages", "v_pages"}
# attribute receivers with a known class (call resolution hint)
RECEIVER_HINTS = {"model": "Model"}


def _class_constants(idx: CodeIndex) -> Dict[str, Set[int]]:
    """UPPERCASE class-level tuple-of-int constants across the indexed tree
    (e.g. ``Model.PAGED_DECODE_DONATE = (1, 2)``) so donation declarations
    shared between production jits and the trace-time auditor's registry
    still resolve statically."""
    out: Dict[str, Set[int]] = {}
    for sf in idx.files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for stmt in node.body:
                if not (isinstance(stmt, ast.Assign)
                        and isinstance(stmt.value, (ast.Tuple, ast.List))):
                    continue
                vals = {e.value for e in stmt.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)}
                if len(vals) != len(stmt.value.elts):
                    continue
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name) and tgt.id.isupper():
                        out[tgt.id] = vals
    return out


def _donated(call: ast.Call,
             consts: Optional[Dict[str, Set[int]]] = None) -> Set[int]:
    """Parse donate_argnums= / donate= keyword into a set of indices.
    Accepts int/tuple literals and ``Cls.SOME_CONSTANT`` references resolved
    via ``_class_constants``."""
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)}
            if isinstance(v, ast.Attribute) and consts is not None:
                return consts.get(v.attr, set())
    return set()


class _Region:
    """One jit-traced root: the wrapped function/lambda + its site."""

    def __init__(self, node: ast.AST, info: Optional[FuncInfo],
                 sf: SourceFile, site_line: int, donated: Set[int],
                 drop_self: bool) -> None:
        self.node = node            # FunctionDef or Lambda
        self.info = info            # None for lambdas
        self.sf = sf
        self.site_line = site_line
        self.donated = donated
        self.drop_self = drop_self


def _local_def(scope: ast.AST, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(scope):
        if isinstance(node, (ast.FunctionDef,
                             ast.AsyncFunctionDef)) and node.name == name:
            return node
    return None


def _factory_return_def(factory: FuncInfo) -> Optional[ast.FunctionDef]:
    """For closure factories: the local def the factory returns."""
    for node in ast.walk(factory.node):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Name):
            d = _local_def(factory.node, node.value.id)
            if d is not None:
                return d
    return None


def _resolve_wrapped(idx: CodeIndex, sf: SourceFile,
                     enclosing: Optional[FuncInfo], cls: Optional[str],
                     expr: ast.AST
                     ) -> Tuple[Optional[ast.AST], Optional[FuncInfo], bool]:
    """Resolve the callable expression handed to jax.jit.

    Returns (ast node, FuncInfo-or-None, drop_self) — drop_self is True for
    bound methods, whose ``self`` is not a jit argument position.
    """
    if isinstance(expr, ast.Lambda):
        return expr, None, False
    if isinstance(expr, ast.Name):
        if enclosing is not None:
            d = _local_def(enclosing.node, expr.id)
            if d is not None:
                return d, None, False
            # one-hop closure factory: name = factory(...) earlier in scope
            for node in ast.walk(enclosing.node):
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Call)
                        and isinstance(node.value.func, ast.Name)
                        and any(isinstance(t, ast.Name) and t.id == expr.id
                                for t in node.targets)):
                    factory = idx.module_functions.get(node.value.func.id)
                    if factory is not None:
                        d = _factory_return_def(factory)
                        if d is not None:
                            return d, None, False
        info = idx.module_functions.get(expr.id)
        if info is not None:
            return info.node, info, False
        return None, None, False
    if isinstance(expr, ast.Attribute):
        chain = attr_chain(expr)
        if chain[:1] == ["self"] and len(chain) == 2 and cls:
            info = idx.resolve_method(cls, chain[1])
            if info is not None:
                return info.node, info, True
        # receiver hint: model.decode_step_paged, self.model.prefill, ...
        recv = chain[-2] if len(chain) >= 2 else None
        hinted = RECEIVER_HINTS.get(recv)
        if hinted:
            info = idx.resolve_method(hinted, chain[-1])
            if info is not None:
                return info.node, info, True
    return None, None, False


def _enclosing_function_map(sf: SourceFile,
                            idx: CodeIndex) -> Dict[int, FuncInfo]:
    """Map statement lineno -> innermost indexed function containing it."""
    out: Dict[int, FuncInfo] = {}
    for info in idx.functions.values():
        if info.sf is not sf:
            continue
        end = getattr(info.node, "end_lineno", info.node.lineno)
        for ln in range(info.node.lineno, end + 1):
            prev = out.get(ln)
            if prev is None or info.node.lineno > prev.node.lineno:
                out[ln] = info
    return out


def _find_regions(idx: CodeIndex) -> Tuple[List[_Region], List[Violation]]:
    regions: List[_Region] = []
    violations: List[Violation] = []
    consts = _class_constants(idx)
    for sf in idx.files:
        by_line = _enclosing_function_map(sf, idx)
        for node in ast.walk(sf.tree):
            # decorator form: @partial(jax.jit, ...)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if (isinstance(dec, ast.Call) and dec.args
                            and attr_chain(dec.func)[-1:] == ["partial"]
                            and attr_chain(dec.args[0])[-2:] == ["jax",
                                                                 "jit"]):
                        regions.append(_Region(node, None, sf, node.lineno,
                                               _donated(dec, consts), False))
                continue
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            wrapped = None
            if chain[-2:] == ["jax", "jit"] and node.args:
                wrapped = node.args[0]
            elif chain == ["self", "_jit"] and len(node.args) >= 2:
                wrapped = node.args[1]
            if wrapped is None:
                continue
            enclosing = by_line.get(node.lineno)
            cls = enclosing.cls if enclosing else None
            fn_node, info, drop_self = _resolve_wrapped(
                idx, sf, enclosing, cls, wrapped)
            if fn_node is None:
                # bare parameter (the _jit helper's own jax.jit call) or a
                # dynamically built callable: nothing provable to scan
                continue
            regions.append(_Region(fn_node, info, sf, node.lineno,
                                   _donated(node, consts), drop_self))
    return regions, violations


def _params(node: ast.AST, drop_self: bool) -> List[str]:
    args = node.args
    names = [a.arg for a in args.posonlyargs] + [a.arg for a in args.args]
    if drop_self and names and names[0] == "self":
        names = names[1:]
    return names


def _region_calls(idx: CodeIndex, region_node: ast.AST, cls: Optional[str],
                  sf: SourceFile) -> List[FuncInfo]:
    """Provable callees of a jit-traced region (incl. fns passed as args)."""
    out: List[FuncInfo] = []
    amap = idx.aliases.get(sf.rel, {})

    def resolve_name(name: str) -> Optional[FuncInfo]:
        info = idx.module_functions.get(name)
        return info

    for node in ast.walk(region_node):
        if not isinstance(node, ast.Call):
            continue
        cands: List[ast.AST] = [node.func]
        cands += [a for a in node.args if isinstance(a, ast.Name)]
        for expr in cands:
            if isinstance(expr, ast.Name):
                info = resolve_name(expr.id)
                if info is not None:
                    out.append(info)
            elif isinstance(expr, ast.Attribute):
                chain = attr_chain(expr)
                if chain[:1] == ["self"] and len(chain) == 2 and cls:
                    info = idx.resolve_method(cls, chain[1])
                    if info is not None:
                        out.append(info)
                    continue
                if len(chain) == 2 and chain[0] in amap:
                    mod_sf = idx.file_for_module(amap[chain[0]])
                    if mod_sf is not None:
                        info = idx.module_functions.get(chain[1])
                        if info is not None and info.sf is mod_sf:
                            out.append(info)
                    continue
                recv = chain[-2] if len(chain) >= 2 else None
                hinted = RECEIVER_HINTS.get(recv)
                if hinted:
                    info = idx.resolve_method(hinted, chain[-1])
                    if info is not None:
                        out.append(info)
    return out


def _scan_purity(sf: SourceFile, node: ast.AST, origin: str,
                 amap: Dict[str, str]) -> List[Violation]:
    violations: List[Violation] = []
    np_aliases = {alias for alias, mod in amap.items()
                  if mod in ("numpy", "np")} | {"np", "numpy"}
    for n in ast.walk(node):
        if not isinstance(n, ast.Call):
            continue
        chain = attr_chain(n.func)
        if (isinstance(n.func, ast.Attribute)
                and n.func.attr in SYNC_METHOD_CALLS):
            violations.append(Violation(
                CHECKER, "host-sync-in-jit", sf.rel, n.lineno,
                f".{n.func.attr}() inside jit-traced code ({origin}) "
                "synchronizes device->host on every call"))
        elif (len(chain) == 2
              and ((chain[0] in np_aliases and chain[1] in ("asarray",
                                                            "array"))
                   or tuple(chain) in SYNC_DOTTED_CALLS)):
            violations.append(Violation(
                CHECKER, "host-sync-in-jit", sf.rel, n.lineno,
                f"{chain[0]}.{chain[1]}() inside jit-traced code ({origin}) "
                "forces a device->host transfer"))
        elif (isinstance(n.func, ast.Name) and n.func.id in ("float", "int")
              and n.args and not isinstance(n.args[0], ast.Constant)):
            violations.append(Violation(
                CHECKER, "host-sync-in-jit", sf.rel, n.lineno,
                f"{n.func.id}(...) on a non-constant inside jit-traced code "
                f"({origin}) blocks on the device value"))
    return violations


def run(root: pathlib.Path,
        rel_files: Sequence[str] = DEFAULT_FILES) -> List[Violation]:
    """Check jit purity + pool donation over ``root``-relative files."""
    violations: List[Violation] = []
    files: List[SourceFile] = []
    for rel in rel_files:
        sf = load_source(root, rel)
        if sf is None:
            violations.append(missing_file_violation(CHECKER, rel))
        else:
            files.append(sf)
    if not files:
        return violations
    idx = CodeIndex(files)

    loaded_rels = {sf.rel for sf in files}
    for rel, cls, meth in REQUIRED_ENTRY_POINTS:
        if rel not in loaded_rels:
            continue        # already reported missing above
        if cls is None:
            info = idx.module_functions.get(meth)
            found = info is not None and info.sf.rel == rel
        else:
            found = idx.resolve_method(cls, meth) is not None
        if not found:
            qual = meth if cls is None else f"{cls}.{meth}"
            violations.append(Violation(
                CHECKER, "config-drift", rel, 1,
                f"hot-path entry point {qual} not found; update "
                "tools/analysis/hot_path_purity.py if it was renamed"))

    regions, extra = _find_regions(idx)
    violations.extend(extra)

    for region in regions:
        origin = (region.info.qualname if region.info
                  else f"jit site {region.sf.rel}:{region.site_line}")
        # ---- donation rule on the jit root itself
        params = _params(region.node, region.drop_self)
        needed = {i for i, p in enumerate(params) if p in POOL_PARAMS}
        missing = needed - region.donated
        for i in sorted(missing):
            violations.append(Violation(
                CHECKER, "undonated-pool-buffer", region.sf.rel,
                region.site_line,
                f"jit of {origin} takes pool buffer '{params[i]}' at "
                f"position {i} without donate_argnums — two live copies of "
                "the pool per call"))
        # ---- purity scan over the full traced call graph
        seen_ids = set()
        frontier: List[Tuple[ast.AST, Optional[str], SourceFile]] = [
            (region.node, region.info.cls if region.info else None,
             region.sf)]
        while frontier:
            fn_node, cls, sf = frontier.pop()
            if id(fn_node) in seen_ids:
                continue
            seen_ids.add(id(fn_node))
            violations.extend(_scan_purity(
                sf, fn_node, origin, idx.aliases.get(sf.rel, {})))
            for callee in _region_calls(idx, fn_node, cls, sf):
                if id(callee.node) not in seen_ids:
                    frontier.append((callee.node, callee.cls, callee.sf))
    # the same function may be reached from several jit roots; flagging it
    # once per root is noise — dedupe on (invariant, file, line)
    uniq: Dict[Tuple[str, str, int], Violation] = {}
    for v in violations:
        uniq.setdefault((v.invariant, v.file, v.line), v)
    return list(uniq.values())
