"""Docs CI gate (run from the repo root: ``python tools/check_docs.py``).

Two checks, both hard failures:

1. **Markdown links resolve** — every relative link target in README.md and
   docs/*.md must exist on disk (anchors are stripped; http(s)/mailto links
   are skipped).  Keeps ARCHITECTURE.md / METRICS.md from silently rotting
   as files move.

2. **Public symbols are documented** — every public module / class /
   function / method in the serving API surface (``src/repro/serving/api.py``),
   the paged KV pool (``src/repro/models/kv_pages.py``) and the expert
   loader / staging engine (``src/repro/core/loader.py``) must carry a
   docstring.  These modules are the protocol seams new backends and
   schedulers build against, so undocumented symbols there are treated as
   build breaks.
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

MD_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
DOCSTRING_MODULES = [
    ROOT / "src" / "repro" / "serving" / "api.py",
    ROOT / "src" / "repro" / "models" / "kv_pages.py",
    ROOT / "src" / "repro" / "core" / "loader.py",
]

# [text](target) — excluding images; tolerate titles after the target
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def check_markdown_links(errors: list):
    """Verify every relative markdown link target exists on disk."""
    for md in MD_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for m in _LINK_RE.finditer(md.read_text()):
            target = m.group(1).split("#", 1)[0]
            if not target or target.startswith(("http://", "https://",
                                               "mailto:")):
                continue
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {m.group(1)}")


def _missing_docstrings(tree: ast.Module, modname: str):
    """Yield 'modname:line symbol' for public module-level defs and public
    methods of module-level classes without docstrings (nested closures are
    implementation detail and exempt)."""
    if not ast.get_docstring(tree):
        yield f"{modname}:1 <module>"

    def public_defs(body, prefix=""):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                if node.name.startswith("_"):
                    continue
                yield prefix + node.name, node

    for name, node in public_defs(tree.body):
        if ast.get_docstring(node) is None:
            yield f"{modname}:{node.lineno} {name}"
        if isinstance(node, ast.ClassDef):
            for mname, mnode in public_defs(node.body, prefix=name + "."):
                if ast.get_docstring(mnode) is None:
                    yield f"{modname}:{mnode.lineno} {mname}"


def check_docstrings(errors: list):
    """Every public symbol in the gated modules carries a docstring."""
    for path in DOCSTRING_MODULES:
        rel = str(path.relative_to(ROOT))
        if not path.exists():
            errors.append(f"{rel}: file missing")
            continue
        tree = ast.parse(path.read_text())
        for miss in _missing_docstrings(tree, rel):
            errors.append(f"undocumented public symbol: {miss}")


def main() -> int:
    """Run both checks; nonzero exit (build break) on any finding."""
    errors: list = []
    check_markdown_links(errors)
    check_docstrings(errors)
    if errors:
        print(f"check_docs: {len(errors)} problem(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    n_links = sum(len(_LINK_RE.findall(p.read_text()))
                  for p in MD_FILES if p.exists())
    print(f"check_docs: OK ({len(MD_FILES)} markdown files, ~{n_links} links, "
          f"{len(DOCSTRING_MODULES)} docstring-gated modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
