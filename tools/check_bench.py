"""Benchmark-regression CI gate (run from the repo root)::

    python -m benchmarks.decode_speedup --smoke --json results/bench_ci.json
    python -m benchmarks.kernel_bench --smoke --json results/kernel_ci.json
    python tools/check_bench.py results/bench_ci.json results/kernel_ci.json \
        --baseline benchmarks/baseline.json

Compares the smoke benchmark's JSON output against the checked-in
``benchmarks/baseline.json`` and fails (nonzero exit) when a loading-latency
win rots:

* **stall regressions** — any gated ``*load_stall_s*`` metric more than
  ``stall_regress_pct`` (default 20%) above baseline, beyond a small
  absolute slack that absorbs timer noise on tiny values;
* **overlap floors** — any gated ``*overlap_fraction*`` metric below
  ``baseline - overlap_drop`` (the share of copy time hidden behind compute
  must not collapse);
* **invariants** — hard bounds that hold on any machine, e.g.
  ``contended_stall_ratio`` (multi-stream byte-budgeted staging must put
  *less* loading time on the critical path than 1-stream FIFO), minimum
  ``precision_downgrades``/``issue_reorders`` counts proving the budgeted
  issue path actually exercised, and the upgrade-pass recovery gates:
  ``upgrade_recovery_served_lo_final_fraction`` (after a contention burst
  the idle-link upgrade pass must re-promote every downgraded hot expert,
  so the served-lo share of hi decisions decays to ~0),
  ``upgrade_recovery_upgrades`` >= 1, and the deterministic simulated
  ``sim_upgrade_stall_ratio`` <= 1.05 (upgrades ride only idle link time:
  stall with upgrades on stays within 5% of upgrades off — gated on the
  simulator timeline because wall-clock stall swings 20-40% with runner
  load, exactly the noise the contended stall slack exists for), and the
  kernel-tier parity rows from ``benchmarks.kernel_bench --smoke``
  (``kernel_*_relerr`` interpret-mode error ceilings and
  ``kernel_gating_topk_index_match`` == 1).  The
  ``paged_decode_dense_gather_free`` row is informational only — the CI
  ``tools.analysis --audit`` job's no-dense-gather rule is the gated
  source of truth for that invariant.

A markdown delta table is printed to stdout and appended to the GitHub job
summary (``$GITHUB_STEP_SUMMARY``) when present.  Refresh the baseline with
``--update-baseline`` after an intentional performance change and commit the
result.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

DEFAULT_CONFIG = {
    "stall_regress_pct": 20.0,   # fail when stall grows beyond this
    "stall_abs_slack_s": 0.05,   # absolute noise floor for tiny stalls
    "overlap_drop": 0.2,         # max tolerated overlap_fraction decrease
}


def _gated(name: str) -> str:
    """Classify a metric name into a gate kind ('' = informational only).

    Stall gates apply only to the contended-link section: those stalls are
    dominated by deterministic modeled-link sleeps, so they hold within the
    configured slack on any runner.  Non-emulated wall-clock stalls
    (`wallclock_load_stall_s`) vary 2-3x across heterogeneous CI machines
    and are NOT gated absolutely — the machine-relative signals that cover
    them are the overlap floors and the invariants (e.g. grouped speedup,
    contended stall ratio)."""
    if "load_stall_s" in name and name.startswith("contended"):
        return "stall"
    if "overlap_fraction" in name:
        return "overlap"
    return ""


def compare(current: dict, baseline: dict) -> tuple:
    """Evaluate gates; returns (failures, table_rows).  table_rows are
    (metric, base, cur, delta_str, status) tuples for the markdown report."""
    cfg = {**DEFAULT_CONFIG, **baseline.get("config", {})}
    metrics = baseline.get("metrics", {})
    invariants = baseline.get("invariants", {})
    rows_cur = current.get("rows", {})
    failures, table = [], []

    for name, base in sorted(metrics.items()):
        kind = _gated(name)
        cur = rows_cur.get(name)
        if cur is None:
            failures.append(f"metric missing from benchmark output: {name}")
            table.append((name, base, "—", "—", "MISSING"))
            continue
        status, delta = "ok", "—"
        if isinstance(base, (int, float)) and base:
            delta = f"{(cur - base) / abs(base) * 100:+.1f}%"
        if kind == "stall":
            limit = base * (1 + cfg["stall_regress_pct"] / 100.0) \
                + cfg["stall_abs_slack_s"]
            if cur > limit:
                status = "FAIL"
                failures.append(
                    f"{name}: load stall regressed {cur} > {limit:.4f} "
                    f"(baseline {base} +{cfg['stall_regress_pct']}% "
                    f"+{cfg['stall_abs_slack_s']}s slack)")
        elif kind == "overlap":
            floor = max(0.0, base - cfg["overlap_drop"])
            if cur < floor:
                status = "FAIL"
                failures.append(f"{name}: overlap_fraction {cur} fell below "
                                f"floor {floor:.3f} (baseline {base} "
                                f"- {cfg['overlap_drop']})")
        table.append((name, base, cur, delta, status))

    for name, bound in sorted(invariants.items()):
        cur = rows_cur.get(name)
        if cur is None:
            failures.append(f"invariant metric missing: {name}")
            table.append((name, bound, "—", "—", "MISSING"))
            continue
        status = "ok"
        if "max" in bound and cur > bound["max"]:
            status = "FAIL"
            failures.append(f"{name}: {cur} > max {bound['max']} — "
                            f"{bound.get('why', 'invariant violated')}")
        if "min" in bound and cur < bound["min"]:
            status = "FAIL"
            failures.append(f"{name}: {cur} < min {bound['min']} — "
                            f"{bound.get('why', 'invariant violated')}")
        table.append((name, json.dumps(bound), cur, "—", status))
    return failures, table


def markdown_table(table, failures) -> str:
    """Render the delta table (plus a verdict line) as GitHub markdown."""
    lines = ["## Bench regression gate",
             "",
             "| metric | baseline | current | delta | status |",
             "|---|---|---|---|---|"]
    for name, base, cur, delta, status in table:
        mark = "❌" if status in ("FAIL", "MISSING") else "✅"
        lines.append(f"| `{name}` | {base} | {cur} | {delta} | {mark} "
                     f"{status} |")
    lines.append("")
    lines.append(f"**{len(failures)} failure(s)**" if failures
                 else "**all gates passed**")
    return "\n".join(lines)


def update_baseline(current: dict, baseline_path: pathlib.Path) -> None:
    """Rewrite the baseline's gated metrics from the current results,
    preserving config and invariant bounds."""
    baseline = (json.loads(baseline_path.read_text())
                if baseline_path.exists() else {})
    rows = current.get("rows", {})
    metrics = {n: v for n, v in rows.items() if _gated(n)}
    baseline.setdefault("config", dict(DEFAULT_CONFIG))
    baseline["metrics"] = metrics
    baseline.setdefault("invariants", {})
    baseline_path.write_text(json.dumps(baseline, indent=2, sort_keys=True)
                             + "\n")
    print(f"baseline updated: {baseline_path} ({len(metrics)} gated metrics)")


def main(argv=None) -> int:
    """CLI entry point; exit 0 iff every gate passes."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", nargs="+",
                    help="JSON file(s) written by benchmarks/*.py --json; "
                         "rows from later files are merged over earlier "
                         "ones so one gate covers decode_speedup + "
                         "kernel_bench output together")
    ap.add_argument("--baseline", default=str(ROOT / "benchmarks"
                                              / "baseline.json"))
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline's gated metrics from the "
                         "current results instead of gating")
    args = ap.parse_args(argv)

    rows_all: dict = {}
    for path in args.results:
        rows_all.update(json.loads(pathlib.Path(path).read_text())
                        .get("rows", {}))
    current = {"rows": rows_all}
    baseline_path = pathlib.Path(args.baseline)
    if args.update_baseline:
        update_baseline(current, baseline_path)
        return 0
    if not baseline_path.exists():
        print(f"check_bench: baseline missing at {baseline_path}; run with "
              "--update-baseline to create it")
        return 1
    baseline = json.loads(baseline_path.read_text())
    failures, table = compare(current, baseline)
    md = markdown_table(table, failures)
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    if failures:
        print("\ncheck_bench: FAIL")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("\ncheck_bench: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
