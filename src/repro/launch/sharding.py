"""Sharding rules: param/activation pytree -> PartitionSpec tree.

Rules are name-based (pytree path substrings) with *divisibility-aware
degradation*: an axis is only applied to a tensor dim whose size it divides;
otherwise that dim falls back to replication.  This lets one rule table
serve all 12 architectures (e.g. kv_heads=16 shards over `model`, kv_heads=8
falls back to sequence sharding for the KV cache).

Conventions (single-pod axes ("data", "model"); multi-pod prepends "pod"
to the batch axes):
  - 2D weights: row dim over one axis, col dim over the other ("2D sharded",
    megatron x FSDP), chosen so matmul contraction dims match activations.
  - MoE experts: expert dim over `model`, d_model dim over `data`.
  - activations/batch: over ("pod","data"); KV cache heads or sequence over
    `model`.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def fit_spec(mesh: Mesh, shape: Tuple[int, ...], spec: P) -> P:
    """Drop axes that don't divide their dim; trim/extend rank mismatches."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries[: len(shape)]):
        if axis is not None and dim % _axis_size(mesh, axis) == 0 and dim > 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


# Rule table: (substring, base_spec_for_last_ndims). First match wins.
# Specs are written for the *trailing* dims; leading (stacked-block, expert
# pool, etc.) dims are replicated automatically.
#
# mode="train": expert weights FSDP-shard their d_model/d_ff dims over `data`
#   (gathered once per microbatch — amortized over ~1M tokens).
# mode="decode": megatron column/row sharding inside each expert so weights
#   never move at decode time; the only comm is a small activation psum.
def param_rules(dp, mp, mode: str = "train"):
    if mode == "decode":
        return [
            ("experts/wi", P(mp, None, dp)),  # (E, D, 2F): col-sharded
            ("experts/wo", P(mp, dp, None)),  # (E, F, D): row-sharded
        ] + _common_rules(dp, mp)
    return [
        ("experts/wi", P(mp, dp, None)),      # (E, D, 2F)
        ("experts/wo", P(mp, None, dp)),      # (E, F, D)
    ] + _common_rules(dp, mp)


def _common_rules(dp, mp):
    return [
        ("router", P(dp, None)),              # (D, E)
        ("embed", P(mp, dp)),                 # (V, D)
        ("lm_head", P(dp, mp)),               # (D, V)
        ("wq", P(dp, mp)),
        ("wk", P(dp, mp)),
        ("wv", P(dp, mp)),
        ("wo", P(mp, dp)),
        ("w_dkv", P(dp, None)),               # (D, R+rope): R small
        ("w_uk", P(None, mp)),                # (R, H*dh)
        ("w_uv", P(None, mp)),
        ("ffn/wi", P(dp, mp)),                # dense FFN
        ("ffn/wo", P(mp, dp)),
        # shared experts: megatron col/row (model axis only). 2D-sharding
        # them makes every weight-grad conflict with the token sharding and
        # XLA all-gathers fp32 cotangents instead (-30% collective on llama4
        # train from this one rule; shared weights are small enough that
        # dp-replication costs ~10 MB/chip). See EXPERIMENTS.md §Perf it. 18.
        ("shared/wi", P(None, mp)),
        ("shared/wo", P(mp, None)),
        ("in_proj", P(dp, mp)),               # ssm
        ("out_proj", P(mp, dp)),
        ("conv_w", P(None, mp)),
        # everything else (norms, biases, A_log, scales): replicated
    ]


def spec_for_param(path_s: str, shape, mesh: Mesh, dp, mp, mode: str = "train") -> P:
    # Expert-count fallback: when E doesn't divide the model axis (Mixtral's
    # 8 experts on a 16-way axis) the model axis moves to the d_ff dim
    # (megatron-style within each expert) instead of being dropped.
    if "experts/wi" in path_s or "experts/wo" in path_s:
        e = shape[-3]
        if e % _axis_size(mesh, mp) != 0:
            base = P(None, dp, mp) if "wi" in path_s else P(None, mp, dp)
            lead = (None,) * (len(shape) - 3)
            return fit_spec(mesh, shape, P(*lead, *base))
    for needle, base in param_rules(dp, mp, mode):
        if needle in path_s:
            nd = len(base)
            if len(shape) < nd:
                return fit_spec(mesh, shape, P(*list(base)[-len(shape):]))
            lead = (None,) * (len(shape) - nd)
            return fit_spec(mesh, shape, P(*lead, *base))
    return P()  # replicate


def param_shardings(mesh: Mesh, param_shapes: Any, mode: str = "train") -> Any:
    """ShapeDtypeStruct/array tree -> NamedSharding tree."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    if dp is not None and len(dp) == 1:
        dp = dp[0]
    mp = "model"

    def one(path, leaf):
        spec = spec_for_param(_path_str(path), leaf.shape, mesh, dp, mp, mode)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, param_shapes)


# ----------------------------------------------------------------------
# activation / cache shardings
# ----------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """(B, ...) activations: B over (pod, data) when divisible."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]
    return fit_spec(mesh, (batch,) + (0,) * extra_dims, P(dp))


def cache_shardings(mesh: Mesh, cache_shapes: Any, batch: int) -> Any:
    """Decode-cache tree -> NamedSharding tree.

    attention k/v (…, B, S, Hkv, hd): B over dp, Hkv over model when it
    divides, else S over model.  MLA c_kv (…, B, S, R): S over model.
    SSM h (…, B, H, P, N): H over model.  conv (…, B, K-1, C): C over model.
    enc_kv (L, 2, B, S, Hkv, hd): B over dp only.
    """
    dp_t = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp_t if len(dp_t) > 1 else dp_t[0]
    mp = "model"
    all_ax = dp_t + (mp,)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        name = ps.rsplit("/", 1)[-1]
        base: Optional[P] = None
        if "enc_kv" in ps:
            base = P(None, None, dp, None, None, None)
        elif name in ("k", "v"):          # (B, S, Hkv, hd) [+lead]
            b, s, hkv, hd = shape[-4:]
            b_ok = b % _axis_size(mesh, dp) == 0
            h_ok = hkv % _axis_size(mesh, mp) == 0
            if b_ok:
                base = P(dp, None, mp, None) if h_ok else P(dp, mp, None, None)
            else:  # batch too small (long_500k): context-parallel the seq dim
                base = P(None, dp, mp, None) if h_ok else P(None, all_ax, None, None)
        elif name in ("c_kv", "k_rope"):   # (B, S, R)
            b = shape[-3]
            b_ok = b % _axis_size(mesh, dp) == 0
            base = P(dp, mp, None) if b_ok else P(None, all_ax, None)
        elif name == "h":                  # (B, H, P, N)
            base = P(dp, mp, None, None)
        elif name == "conv":               # (B, K-1, C)
            base = P(dp, None, mp)
        if base is None:
            return NamedSharding(mesh, P())
        lead = (None,) * (nd - len(base))
        return NamedSharding(mesh, fit_spec(mesh, shape, P(*lead, *base)))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
