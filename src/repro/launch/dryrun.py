import os
# 512 placeholder devices for the production mesh; LICM disabled because XLA
# otherwise hoists an fp32 convert of the whole remat residual stack out of
# the backward loop (a +5 GB/chip copy at DeepSeek scale).
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
    + " --xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh with 512 placeholder host devices; print memory_analysis,
cost_analysis and parsed collective bytes; emit a JSON record per run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results.jsonl
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES, get_config, shape_supported
from repro.launch.mesh import make_production_mesh, num_chips
from repro.launch.roofline import Roofline, model_flops


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            verbose: bool = True, expert_bits: int = 0) -> dict:
    import dataclasses as _dc

    from repro.launch.specs import input_specs

    cfg = get_config(arch)
    if expert_bits and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(
            cfg.moe, expert_precision=f"int{expert_bits}"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "multi_pod": multi_pod, "status": "skip"}
    if not shape_supported(arch, shape_name):
        rec["reason"] = "long-context skip (DESIGN.md §5)"
        return rec
    t0 = time.time()
    try:
        step_fn, args, in_sh, donate = input_specs(cfg, shape_name, mesh)
        with mesh:
            jitted = jax.jit(step_fn, in_shardings=in_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # trip-count-aware analysis (XLA's cost_analysis counts while bodies
        # once, under-reporting scanned layer stacks; see hlo_analysis.py)
        from repro.launch.hlo_analysis import analyze
        ana = analyze(hlo)
        coll = ana["collectives"]
        shape = INPUT_SHAPES[shape_name]
        n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_chip": float(ana["flops"]),
            "bytes_per_chip": float(ana["bytes"]),
            "xla_cost_analysis": {"flops": float(cost.get("flops", -1.0)),
                                  "bytes": float(cost.get("bytes accessed", -1.0))},
            "collectives": coll,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes_per_chip": mem.argument_size_in_bytes
                    + mem.temp_size_in_bytes + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes,
            },
            "n_tokens": n_tokens,
            "model_flops": model_flops(cfg, shape, n_tokens),
            "chips": num_chips(mesh),
        })
        rl = Roofline(arch, shape_name, mesh_name,
                      rec["flops_per_chip"], rec["bytes_per_chip"],
                      coll["total"])
        rec["roofline"] = rl.asdict()
        if verbose:
            print(f"[{arch} x {shape_name} @ {mesh_name}] OK "
                  f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
            print(f"  memory_analysis: {json.dumps(rec['memory'])}")
            print(f"  cost_analysis: flops/chip={rec['flops_per_chip']:.3e} "
                  f"bytes/chip={rec['bytes_per_chip']:.3e}")
            print(f"  collectives: {json.dumps(coll)}")
            print(f"  roofline: compute={rl.compute_s:.4e}s memory={rl.memory_s:.4e}s "
                  f"collective={rl.collective_s:.4e}s -> {rl.bottleneck}-bound")
    except Exception as e:  # noqa
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
        if verbose:
            print(f"[{arch} x {shape_name} @ {mesh_name}] FAIL {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) pair")
    ap.add_argument("--include-paper-archs", action="store_true",
                    help="also dry-run mixtral-8x7b / phi-moe")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--expert-bits", type=int, default=0, choices=[0, 4, 8],
                    help="beyond-paper: quantized resident experts (decode)")
    ap.add_argument("--out", type=str, default=None, help="append JSONL here")
    args = ap.parse_args()

    if args.all:
        archs = list(ASSIGNED_ARCHS)
        if args.include_paper_archs:
            archs += [a for a in ARCHS if a not in archs]
        pairs = [(a, s) for a in archs for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in pairs:
        rec = run_one(arch, shape, multi_pod=args.multi_pod,
                      expert_bits=args.expert_bits)
        if args.expert_bits:
            rec["expert_bits"] = args.expert_bits
        n_fail += rec["status"] == "fail"
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
