"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
memory term     = HLO_bytes_per_chip / HBM_bw_per_chip
collective term = collective_bytes_per_chip / ICI_link_bw

The post-SPMD HLO module is per-device, so cost_analysis() FLOPs/bytes and
the parsed collective bytes are per-chip quantities; dividing by per-chip
peaks is algebraically the same as the brief's global/(chips*peak) form.

collective_bytes is parsed from the optimized HLO text: we sum the *result*
shape bytes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute op (the data each chip moves over ICI per op, to within
the usual 2(n-1)/n ring factor, which we fold into the reported term).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "bf16[128,4096]" or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or " = " in s:
            for kind in _COLLECTIVES:
                # match ` kind(` as the op being assigned on this line
                if f" {kind}(" in s or f" {kind}-start(" in s:
                    lhs = s.split(" = ", 1)
                    if len(lhs) != 2:
                        continue
                    # result type(s) = everything before the op name
                    rhs = lhs[1]
                    idx = rhs.find(f" {kind}")
                    type_str = rhs[:idx] if idx > 0 else rhs.split(" ")[0]
                    for m in _SHAPE_RE.finditer(type_str):
                        out[kind] += _shape_bytes(m.group(1), m.group(2))
                    out["count"] += 1
                    break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time estimate = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def asdict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "step_s": self.step_s,
        }


def model_flops(cfg, shape, n_tokens: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D=1 token/seq."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * n_tokens
    # inference fwd only ~ 2*N per token (+ attn, ignored in the ratio metric)
    return 2.0 * n * n_tokens
