"""Training launcher.

Single-host (CPU) demo scale:
  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke \
      --steps 200 --batch 8 --seq 64

Production mesh dry-run of the same step function is `repro.launch.dryrun`;
on a real TPU pod this launcher jits with the identical sharding rules.
"""

import argparse
import dataclasses
import json

import jax

from repro.configs import get_config, smoke_variant
from repro.data.pipeline import DataConfig, batches, eval_batches
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import make_eval_step, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--eval-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg, vocab=args.vocab)
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"active={cfg.active_param_count()/1e6:.1f}M")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch)
    it = batches(dc)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    eval_fn = None
    if args.eval_every:
        ev = eval_batches(dc, 2)
        es = jax.jit(make_eval_step(model))

        def eval_fn(params):
            return sum(float(es(params, b)) for b in ev) / len(ev)

    state, hist = train(model, ocfg, it, args.steps,
                        log_every=max(args.steps // 10, 1), eval_fn=eval_fn)
    if args.ckpt_dir:
        path = ckpt.save(args.ckpt_dir, state.params, step=args.steps)
        print(f"checkpoint: {path}")
    print(json.dumps(hist[-1]))


if __name__ == "__main__":
    main()
