"""Production mesh definitions (TPU v5e).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the pod axis is
pure data parallelism (gradient all-reduce crosses the pod boundary over DCN).

Defined as functions so importing this module never touches jax device
state (jax locks the platform device count on first backend init).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh (pod joins data when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_chips(mesh) -> int:
    return mesh.devices.size
