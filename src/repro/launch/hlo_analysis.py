"""Trip-count-aware HLO cost analysis.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` reports) visits a
while-loop body ONCE, so lax.scan-based layer stacks under-report FLOPs,
bytes and collective traffic by ~the layer count.  This module parses the
optimized HLO text, builds the computation call graph (fusions x1, while
bodies x trip-count — trip counts recovered from the loop condition's
compare-against-constant), and accumulates:

    flops        2*M*N*K for every dot (incl. dots inside fusions); the
                 elementwise remainder is <~2% for transformer workloads
    bytes        operand + result bytes of every *top-level* instruction in
                 each computation (fusion internals excluded — they live in
                 registers/VMEM, matching the HloCostAnalysis convention)
    collectives  result bytes per collective kind

All quantities are per-device (post-SPMD HLO is per-device).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0, "tuple": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) (?:\([^)]*\) -> .*)?\{")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _nbytes(type_str: str) -> int:
    tot = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES.get(dt, 4)
    return tot


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # (callee, multiplier): fusions x1, while bodies x trips
    is_fusion_sub: bool = False


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", s)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[\w\[\],]+(?:\{[\d,]*\})?))\s+([\w\-]+)\(")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _parse_def(line: str):
    """'%x = f32[..] op(%a, %b), attrs' -> (name, type_str, op, args_str)."""
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, type_str, op = m.group(1), m.group(2), m.group(3)
    rest = line[m.end():]
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return name, type_str, op, rest[:i]
    return name, type_str, op, rest


def _dot_flops(type_str: str, args: str, line: str, symtab: Dict[str, str]) -> float:
    """2 * prod(output dims) * prod(contracting dims)."""
    out_elems = 1
    shapes = _shape_dims(type_str)
    if not shapes:
        return 0.0
    for d in shapes[0][1]:
        out_elems *= d
    ops = _OPERAND_NAME_RE.findall(args)
    m = _DOT_DIMS_RE.search(line)
    k = 1
    if m and ops:
        lhs_type = symtab.get(ops[0], "")
        lhs_shapes = _shape_dims(lhs_type)
        if lhs_shapes:
            lhs_dims = lhs_shapes[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
    return 2.0 * out_elems * k


# HBM-traffic ops.  The CPU backend fuses far less than the TPU backend, so
# counting every top-level op would inflate the memory term ~20x with
# elementwise chains a TPU compile absorbs into neighbors.  We count
# operand+result bytes only for primitives that are memory-bound on TPU too
# (data movement, matmul I/O, reductions, scatters/gathers, collectives);
# pure elementwise/convert/broadcast ops are treated as fused.
_TRAFFIC_OPS = frozenset({
    "dot", "dot_general", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "sort", "transpose",
    "copy", "copy-start", "concatenate", "slice", "pad", "convolution",
    "custom-call", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "all-reduce-start",
    "all-gather-start", "collective-permute-start", "select-and-scatter",
})


def _op_bytes(op: str, type_str: str, args: str, symtab: Dict[str, str]) -> int:
    """HBM traffic for one op (HloCostAnalysis-style conventions).

    Slicing ops read only the sliced window, not the whole operand (critical
    for lax.scan stacks, where dynamic-slice indexes the full stacked params
    every iteration); updates write only the update window."""
    if op in ("dynamic-slice", "slice", "gather"):
        return 2 * _nbytes(type_str)          # read window + write result
    ops = _OPERAND_NAME_RE.findall(args)
    if op in ("dynamic-update-slice", "scatter"):
        upd = _nbytes(symtab.get(ops[1], "")) if len(ops) > 1 else 0
        return 2 * upd                        # read update + write region
    total = _nbytes(type_str)
    for name in ops:
        t = symtab.get(name)
        if t:
            total += _nbytes(t)
    return total


def _trip_count(cond_lines: List[str]) -> int:
    """Counted loops compare the induction var against a constant."""
    for line in cond_lines:
        if "compare(" in line:
            consts = _CONST_RE.findall(line)
            if consts:
                return int(consts[-1])
    # constant usually materialized on its own line: take the max s32 const
    # (cond computations for counted loops contain only the bound)
    best = 0
    for line in cond_lines:
        m = _CONST_RE.search(line)
        if m and "s32" in line:
            best = max(best, int(m.group(1)))
    return best or 1


def analyze(hlo: str) -> Dict:
    comps = _split_computations(hlo)
    entry_lines = comps.get("__entry__")
    stats: Dict[str, CompStats] = {}

    # Pre-pass: for every computation, the *effective read bytes* of each
    # parameter — a parameter whose only tensor use is dynamic-slice/slice is
    # read slice-by-slice (critical for fused reads of scan-stacked buffers),
    # not in full.
    param_reads: Dict[str, Dict[int, int]] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        pr: Dict[int, int] = {}
        pname_to_idx: Dict[str, int] = {}
        ptype: Dict[int, str] = {}
        for line in lines:
            d = _parse_def(line)
            if d and d[2] == "parameter":
                idx = int(d[3]) if d[3].isdigit() else len(pname_to_idx)
                pname_to_idx[d[0]] = idx
                ptype[idx] = d[1]
        for pname, idx in pname_to_idx.items():
            full = _nbytes(ptype[idx])
            sliced = 0
            other_use = False
            for line in lines:
                if f"%{pname}" not in line:
                    continue
                d = _parse_def(line)
                if d is None or d[0] == pname:
                    continue
                ops_in = _OPERAND_NAME_RE.findall(d[3])
                if pname not in ops_in:
                    continue
                if d[2] in ("dynamic-slice", "slice") and ops_in[0] == pname:
                    sliced += _nbytes(d[1])
                else:
                    other_use = True
            pr[idx] = full if (other_use or sliced == 0) else min(full, sliced)
        param_reads[name] = pr

    for name, lines in comps.items():
        if name == "__entry__":
            continue
        st = CompStats()
        # first pass: symbol table of result types
        symtab: Dict[str, str] = {}
        parsed = []
        for line in lines:
            d = _parse_def(line)
            parsed.append(d)
            if d:
                symtab[d[0]] = d[1]
        for line, d in zip(lines, parsed):
            if d is None:
                continue
            iname, type_str, op, args = d
            if op in ("dot", "dot_general"):
                st.flops += _dot_flops(type_str, args, line, symtab)
            if op in _TRAFFIC_OPS:
                if op in ("fusion", "call"):
                    # fusion reads: per-operand effective bytes (a fused
                    # dynamic-slice of a stacked buffer reads one slice)
                    callee = _CALLS_RE.search(line)
                    pr = param_reads.get(callee.group(1), {}) if callee else {}
                    b = _nbytes(type_str)
                    for j, oname in enumerate(_OPERAND_NAME_RE.findall(args)):
                        t = symtab.get(oname)
                        if t:
                            b += min(_nbytes(t), pr.get(j, _nbytes(t)))
                    st.bytes += b
                else:
                    st.bytes += _op_bytes(op, type_str, args, symtab)
            for kind in _COLLECTIVES:
                if op == kind or op == kind + "-start":
                    st.coll[kind] += _nbytes(type_str)
                    break
            if op == "while":
                b = _BODY_RE.search(line)
                c = _COND_RE.search(line)
                if b:
                    trips = _trip_count(comps.get(c.group(1), [])) if c else 1
                    st.calls.append((b.group(1), float(max(trips, 1))))
            elif op in ("fusion", "call", "conditional"):
                m = _CALLS_RE.search(line)
                if m:
                    st.calls.append((m.group(1), -1.0))  # fusion marker
        stats[name] = st

    # fusion subcomputations: count their dot flops x1 into the caller, but
    # NOT their bytes (internals don't touch HBM).
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(name: str, depth=0) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        st = stats.get(name)
        if st is None or depth > 64:
            return 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}
        fl, by = st.flops, st.bytes
        co = dict(st.coll)
        for callee, mult in st.calls:
            cfl, cby, cco = total(callee, depth + 1)
            if mult < 0:          # fusion: flops + collectives, no bytes
                fl += cfl
                for k in co:
                    co[k] += cco[k]
            else:                  # while body: everything x trips
                fl += mult * cfl
                by += mult * cby
                for k in co:
                    co[k] += mult * cco[k]
        memo[name] = (fl, by, co)
        return memo[name]

    # entry computation name: the one matching __entry__ content
    entry_name = None
    for name, lines in comps.items():
        if name != "__entry__" and lines is entry_lines:
            entry_name = name
            break
    if entry_name is None:  # fallback: largest computation
        entry_name = max(stats, key=lambda n: stats[n].bytes)

    fl, by, co = total(entry_name)
    co_total = sum(co.values())
    return {
        "flops": fl,
        "bytes": by,
        "collectives": {**co, "total": co_total},
        "entry": entry_name,
        "n_computations": len(stats),
    }
