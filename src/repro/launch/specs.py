"""input_specs: ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
zero allocation) for every (architecture x input shape) dry-run target,
plus the step function each shape lowers.

  train_4k    -> train_step(TrainState, Batch)
  prefill_32k -> prefill_step(params, Batch)
  decode_32k  -> serve_step(params, cache, tokens, positions)
  long_500k   -> serve_step with a 524288-token cache, batch 1
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, ModelConfig
from repro.configs.base import InputShape
from repro.launch import sharding as sh
from repro.models.model import Batch, Model
from repro.serving.decode import make_serve_step
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import TrainState, make_train_step


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> Batch:
    kw = {}
    if cfg.frontend == "vision_patches":
        kw["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_prefix_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio_frames":
        kw["audio_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.seq_len, cfg.encoder.d_model), jnp.float32)
    return Batch(
        tokens=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        loss_mask=jax.ShapeDtypeStruct((batch, seq), jnp.float32),
        **kw,
    )


def batch_shardings(mesh, b: Batch):
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]

    def one(leaf):
        if leaf is None:
            return None
        spec = sh.fit_spec(mesh, leaf.shape, P(dp))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, b)


def train_microbatches(cfg: ModelConfig, shape: InputShape, dp_size: int = 16) -> int:
    """Microbatch count for gradient accumulation, sized so the per-chip
    remat carry stack (layers x B_local x S x D x ~6 bytes incl. the fp32
    shadow) stays within ~6 GB of the 16 GB v5e HBM."""
    layers_ = cfg.num_layers
    b_local = max(shape.global_batch // dp_size, 1)  # data(+pod)-axis shards
    stack = layers_ * b_local * shape.seq_len * cfg.d_model * 6
    mb = 1
    # cap: each microbatch must still shard its batch over the full dp axis
    mb_max = max(shape.global_batch // dp_size, 1)
    while stack / mb > 6e9 and mb < mb_max:
        mb *= 2
    while shape.global_batch % mb:
        mb //= 2
    return max(mb, 1)


def _quantized_init(model: Model, bits: int):
    """init fn whose expert weights are groupwise-quantized QTensors —
    the beyond-paper mixed-precision *resident* expert option (the HOBBIT
    insight applied to the HBM tier instead of the PCIe tier)."""
    from repro.quant.quantize import quantize

    def init(key):
        params = model.init(key)

        def q(tree):
            return {"wi": quantize(tree["wi"], bits=bits, group_size=128),
                    "wo": quantize(tree["wo"], bits=bits, group_size=128)}

        def walk(node):
            if isinstance(node, dict):
                return {k: (q(v) if k == "experts" else walk(v))
                        for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        return walk(params)

    return init


def input_specs(cfg: ModelConfig, shape_name: str, mesh) -> Tuple[Callable, tuple, tuple, tuple]:
    """Returns (step_fn, arg_structs, in_shardings, donate_argnums)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    shape: InputShape = INPUT_SHAPES[shape_name]
    model = Model(cfg)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        # bf16 Adam moments for >50B-param models (fp32 states alone would
        # exceed 16 GB/chip at 236B/256 chips)
        big = cfg.param_count() > 50e9
        ocfg = OptimizerConfig(total_steps=10_000,
                               moment_dtype="bfloat16" if big else "float32")
        # Gradient accumulation bounds the remat-residual stack (and the
        # fp32 shadow XLA hoists out of the backward loop) to one microbatch.
        import numpy as _np
        dp_size = int(_np.prod([mesh.shape[a] for a in mesh.axis_names
                                if a in ("pod", "data")]))
        mb = train_microbatches(cfg, shape, dp_size)
        step_fn = make_train_step(model, ocfg, remat=True, microbatches=mb)
        p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_shard = sh.param_shardings(mesh, p_shapes)
        opt_shapes = jax.eval_shape(
            lambda p: init_opt_state(p, ocfg.moment_dtype), p_shapes)
        from repro.training.optimizer import OptState
        opt_sh = OptState(step=repl,
                          mu=sh.param_shardings(mesh, opt_shapes.mu),
                          nu=sh.param_shardings(mesh, opt_shapes.nu))
        state = TrainState(p_shapes, opt_shapes)
        state_sh = TrainState(p_shard, opt_sh)
        b = batch_struct(cfg, shape.global_batch, shape.seq_len)
        b_sh = batch_shardings(mesh, b)
        return step_fn, (state, b), (state_sh, b_sh), (0,)

    if shape.kind == "prefill":
        # VLM prompts carry num_prefix_tokens patch embeddings on top of the
        # text tokens; the cache must hold both
        plen = shape.seq_len + (cfg.num_prefix_tokens
                                if cfg.frontend == "vision_patches" else 0)

        def prefill_step(params, batch):
            return model.prefill(params, batch, plen)
        p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_shard = sh.param_shardings(mesh, p_shapes)
        b = batch_struct(cfg, shape.global_batch, shape.seq_len)
        b_sh = batch_shardings(mesh, b)
        return prefill_step, (p_shapes, b), (p_shard, b_sh), ()

    # decode
    step_fn = make_serve_step(model)
    init_fn = model.init
    if cfg.moe is not None and cfg.moe.expert_precision in ("int8", "int4"):
        init_fn = _quantized_init(model, int(cfg.moe.expert_precision[3:]))
    p_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    p_shard = sh.param_shardings(mesh, p_shapes, mode="decode")
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cache_sh = sh.cache_shardings(mesh, cache_shapes, shape.global_batch)
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]
    tok_sh = NamedSharding(mesh, sh.fit_spec(mesh, toks.shape, P(dp)))
    pos_sh = NamedSharding(mesh, sh.fit_spec(mesh, pos.shape, P(dp)))
    return (step_fn, (p_shapes, cache_shapes, toks, pos),
            (p_shard, cache_sh, tok_sh, pos_sh), (1,))
