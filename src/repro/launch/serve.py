"""Serving launcher over the unified `InferenceBackend` API.

Both the resident dense path and the HOBBIT mixed-precision offload engine
sit behind the same protocol, so one launcher drives either — single-shot
generation or a continuous-batching request workload:

  # dense, one batched generate call
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --backend dense --prompt-len 16 --new-tokens 32

  # HOBBIT offload + simulated edge-hardware latency report
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --backend hobbit --prompt-len 16 --new-tokens 32

  # continuous batching: mixed-length requests through the scheduler
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --backend hobbit --serve-requests 6 --max-batch 2
"""

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine, Thresholds
from repro.core.simulator import HARDWARE, HobbitSimConfig, simulate_systems
from repro.models import build_model
from repro.quant.quantize import expert_nbytes
from repro.serving.api import BackendConfig, generate, make_backend
from repro.serving.batching import BatchingServer, Request
from repro.serving.workload import DEFAULT_AGING_S
from repro.training import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser(
        description="Serve a MoE model through the unified InferenceBackend "
                    "API: --backend dense keeps all weights resident; "
                    "--backend hobbit decodes through the mixed-precision "
                    "expert-offloading engine.  Either backend runs "
                    "single-shot generation or, with --serve-requests, a "
                    "continuous-batching workload through the same "
                    "scheduler.")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", choices=["dense", "hobbit"], default=None,
                    help="inference backend behind the serving API "
                         "(default: dense)")
    ap.add_argument("--mode", choices=["resident", "hobbit"], default=None,
                    help="DEPRECATED alias for --backend "
                         "(resident -> dense)")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=1,
                    help="batch size for single-shot generation "
                         "(both backends support batch >= 1)")
    ap.add_argument("--serve-requests", type=int, default=0,
                    help="if > 0, run N mixed-length requests through the "
                         "continuous-batching scheduler instead of one "
                         "generate call")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="scheduler slots for --serve-requests")
    ap.add_argument("--jit", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="jit the dense prefill/decode steps "
                         "(--no-jit: eager, for debugging)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="paged KV cache: slots draw pages from a shared "
                         "pool instead of each allocating max_len up front "
                         "(either backend; all-'attn' archs)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV page (--paged-kv)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="KV pool size in pages (--paged-kv; default: the "
                         "dense equivalent, batch * ceil(max_len/page))")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="tokens per chunked-prefill call (--paged-kv)")
    ap.add_argument("--prefix-sharing", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="radix prefix cache over the KV page pool "
                         "(--paged-kv): admissions alias already-resident "
                         "prompt-prefix pages across slots (copy-on-write "
                         "on divergence) and only pay for their unshared "
                         "suffix.  --no-prefix-sharing prefills every "
                         "prompt in full")
    ap.add_argument("--admit-k", type=int, default=4,
                    help="max requests prefilling concurrently in the "
                         "scheduler (--serve-requests)")
    ap.add_argument("--sched", choices=["slo", "fifo"], default="slo",
                    help="scheduler admission policy (--serve-requests): "
                         "'slo' orders the queue by SLO urgency (priority + "
                         "aging + TTFT slack) and preempts a low-priority "
                         "decode when a more urgent request cannot fit; "
                         "'fifo' is strict arrival order, no preemption")
    ap.add_argument("--aging-s", type=float, default=DEFAULT_AGING_S,
                    help="seconds of queue wait worth one priority level "
                         "(--sched slo): bounds every request's wait, so "
                         "low-priority work cannot starve")
    ap.add_argument("--preempt-margin", type=float, default=1.0,
                    help="effective-priority gap the queued request must "
                         "hold over the best victim before the scheduler "
                         "pauses it (--sched slo); higher = rarer "
                         "preemption")
    ap.add_argument("--priority-every", type=int, default=0,
                    help="mark every Nth --serve-requests request "
                         "priority 2 with a 2 s TTFT SLO (0 = all "
                         "priority 0, no SLOs) — exercises the SLO-aware "
                         "scheduler end to end")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common N-token prefix (a shared system "
                         "prompt) to every --serve-requests prompt, so the "
                         "--prefix-sharing radix cache has something to "
                         "alias (0 = fully distinct prompts)")
    ap.add_argument("--hi-slots", type=int, default=16)
    ap.add_argument("--lo-slots", type=int, default=8)
    ap.add_argument("--t1", type=float, default=0.6)
    ap.add_argument("--t2", type=float, default=0.9)
    ap.add_argument("--streams", type=int, default=2,
                    help="expert staging streams sharing the modeled H2D "
                         "link (hobbit backend; default one hi- + one "
                         "lo-precision stream)")
    ap.add_argument("--ordered", action="store_true",
                    help="FIFO staging issue (with --streams 1 this is the "
                         "PR-2 parity scheduler; default is byte-budgeted "
                         "biggest-gate-first issue with hi->lo downgrades "
                         "under link pressure)")
    ap.add_argument("--upgrade", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="idle-link upgrade pass: re-issue hi copies for "
                         "downgraded (lo-substituted) experts when the hi "
                         "stream has leftover link budget, so downgrades "
                         "stay temporary.  --no-upgrade restores the PR-4 "
                         "per-token downgrade semantics")
    ap.add_argument("--link-gbps", type=float, default=None,
                    help="modeled H2D link bandwidth in GB/s; default "
                         "measures the host copy rate at startup.  An "
                         "explicit value also *emulates* the link (copies "
                         "occupy their stream for bytes/link seconds) so "
                         "contended-link behavior is observable on this "
                         "CPU-only host")
    ap.add_argument("--hw", choices=list(HARDWARE), default="rtx4090",
                    help="hardware cost model for the simulated latency report")
    args = ap.parse_args()

    kind = args.backend or {"resident": "dense", "hobbit": "hobbit",
                            None: "dense"}[args.mode]

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        params, _ = ckpt.restore(args.ckpt_dir, params)

    if kind == "hobbit":
        assert cfg.moe is not None, "--backend hobbit requires a MoE arch"
    # flags mirror BackendConfig 1:1 (the deprecated kwarg form is gone here)
    backend = make_backend(BackendConfig(
        kind=kind, jit=args.jit, paged=args.paged_kv,
        page_size=args.page_size, kv_pages=args.kv_pages,
        prefill_chunk=args.prefill_chunk,
        prefix_sharing=args.prefix_sharing,
        engine=EngineConfig(
            hi_slots=args.hi_slots, lo_slots=args.lo_slots,
            thresholds=Thresholds(args.t1, args.t2),
            streams=args.streams, ordered=args.ordered,
            upgrade=args.upgrade, link_gbps=args.link_gbps)
        if kind == "hobbit" else None), model, params)

    rng = np.random.default_rng(0)
    report = {"backend": kind, "paged_kv": args.paged_kv}

    if args.serve_requests > 0:
        srv = BatchingServer(backend, max_batch=args.max_batch,
                             max_len=(args.shared_prefix + args.prompt_len * 2
                                      + args.new_tokens + 8),
                             admit_k=args.admit_k, policy=args.sched,
                             aging_s=args.aging_s,
                             preempt_margin=args.preempt_margin)
        common = rng.integers(0, cfg.vocab_size, args.shared_prefix)
        for i in range(args.serve_requests):
            plen = args.prompt_len * (1 + i % 2)
            prompt = np.concatenate(
                [common, rng.integers(0, cfg.vocab_size, plen)])
            urgent = args.priority_every and i % args.priority_every == 0
            srv.submit(Request(
                rid=i, prompt=prompt,
                max_new_tokens=args.new_tokens // (1 + i % 2),
                priority=2 if urgent else 0,
                ttft_slo_s=2.0 if urgent else None))
        srv.run()
        report["serving"] = srv.stats()
        report["scheduler"] = {"policy": args.sched,
                               "preemptions": srv.preemptions}
    else:
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32)
        res = generate(backend, prompts, args.new_tokens)
        report.update({"prefill_s": res.prefill_s, "decode_s": res.decode_s,
                       "decode_tok_s": res.decode_tok_s,
                       "tokens": res.tokens[0, -8:].tolist()})

    if kind == "hobbit":
        eng: OffloadEngine = backend.engine
        stats = eng.stats()
        hw = HARDWARE[args.hw]
        base = get_config(args.arch)  # full-scale dims for the latency model
        sim_cfg = HobbitSimConfig(
            thresholds=Thresholds(args.t1, args.t2),
            hi_slots=args.hi_slots, lo_slots=args.lo_slots,
            hi_bytes=expert_nbytes(base.d_model, base.moe.d_ff_expert, 16),
            lo_bytes=expert_nbytes(base.d_model, base.moe.d_ff_expert, 4))
        sim = simulate_systems(eng.trace, eng.num_moe_layers, hw, sim_cfg)
        report.update({
            "cache_hit_ratio": round(stats["cache"]["hit_ratio"], 3),
            "loads": {"hi": stats["loads_hi"], "lo": stats["loads_lo"],
                      "skips": stats["skips"]},
            "pred_accuracy": stats["pred_accuracy"],
            # wall-clock loading observability (engine.stats() contract)
            "load_stall_s": round(stats["load_stall_s"], 4),
            "overlap_fraction": round(stats["overlap_fraction"], 3),
            "gating_s": round(stats["gating_s"], 4),
            # multi-stream staging (StagingEngine; docs/METRICS.md)
            "streams": stats["streams"],
            "per_stream_bytes": stats["per_stream_bytes"],
            "issue_reorders": stats["issue_reorders"],
            "precision_downgrades": stats["precision_downgrades"],
            # idle-link upgrade pass: downgrade recovery + residual exposure
            "upgrades": stats["upgrades"],
            "upgrade_bytes": stats["upgrade_bytes"],
            "served_lo_expert_steps": stats["served_lo_expert_steps"],
            "link_utilization": round(stats["link_utilization"], 3),
            "simulated_decode_tok_s": {k: round(v["tok_per_s"], 2)
                                       for k, v in sim.items()},
            "simulated_overlap_fraction": {k: round(v["overlap_fraction"], 3)
                                           for k, v in sim.items()},
            "hw_profile": hw.name,
        })
    backend.close()         # release staging threads before reporting
    print(json.dumps(report))


if __name__ == "__main__":
    main()
