"""Serving launcher: standard resident serving or the HOBBIT offload engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --mode hobbit --prompt-len 16 --new-tokens 32
"""

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine, Thresholds
from repro.core.simulator import HARDWARE, HobbitSimConfig, simulate_systems
from repro.models import build_model
from repro.quant.quantize import expert_nbytes
from repro.serving.decode import generate
from repro.training import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", choices=["resident", "hobbit"], default="resident")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--hi-slots", type=int, default=16)
    ap.add_argument("--lo-slots", type=int, default=8)
    ap.add_argument("--t1", type=float, default=0.6)
    ap.add_argument("--t2", type=float, default=0.9)
    ap.add_argument("--hw", choices=list(HARDWARE), default="rtx4090",
                    help="hardware cost model for the simulated latency report")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        params, _ = ckpt.restore(args.ckpt_dir, params)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    if args.mode == "resident":
        res = generate(model, params, prompts, args.new_tokens)
        print(json.dumps({"prefill_s": res.prefill_s, "decode_s": res.decode_s,
                          "decode_tok_s": res.decode_tok_s,
                          "tokens": res.tokens[0, -8:].tolist()}))
        return

    assert cfg.moe is not None, "--mode hobbit requires a MoE arch"
    eng = OffloadEngine(model, params, EngineConfig(
        hi_slots=args.hi_slots, lo_slots=args.lo_slots,
        thresholds=Thresholds(args.t1, args.t2)))
    out = eng.generate(list(map(int, prompts[0])), args.new_tokens)
    stats = eng.stats()
    hw = HARDWARE[args.hw]
    base = get_config(args.arch)  # full-scale dims for the latency model
    sim_cfg = HobbitSimConfig(
        thresholds=Thresholds(args.t1, args.t2),
        hi_slots=args.hi_slots, lo_slots=args.lo_slots,
        hi_bytes=expert_nbytes(base.d_model, base.moe.d_ff_expert, 16),
        lo_bytes=expert_nbytes(base.d_model, base.moe.d_ff_expert, 4))
    sim = simulate_systems(eng.trace, eng.num_moe_layers, hw, sim_cfg)
    print(json.dumps({
        "generated": out[-8:],
        "cache_hit_ratio": round(stats["cache"].hit_ratio(), 3),
        "loads": {"hi": stats["loads_hi"], "lo": stats["loads_lo"],
                  "skips": stats["skips"]},
        "pred_accuracy": stats["pred_accuracy"],
        "simulated_decode_tok_s": {k: round(v["tok_per_s"], 2)
                                   for k, v in sim.items()},
        "hw_profile": hw.name,
    }, default=str))


if __name__ == "__main__":
    main()
