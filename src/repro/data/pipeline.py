"""Synthetic-but-structured data pipeline.

Deterministic, seedable, shardable token streams with enough statistical
structure (Zipfian unigrams + order-2 Markov chains + repeated motifs) that
a small LM measurably learns: perplexity drops well below the unigram
entropy, expert routers develop preferences (which HOBBIT's cache exploits),
and quantization-accuracy experiments have a non-degenerate signal.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.models.model import Batch

import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.35        # probability a motif is replayed
    n_motifs: int = 64
    markov_states: int = 128


class SyntheticLM:
    """Order-1 Markov over a state space + Zipf emission + motif replay."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipfian unigram over vocab
        ranks = np.arange(1, v + 1)
        self.unigram = (1.0 / ranks ** cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # sparse markov transition: each state prefers a small token subset
        s = cfg.markov_states
        self.state_tokens = rng.choice(v, size=(s, 16), p=self.unigram)
        self.token_state = rng.integers(0, s, size=v)
        # motifs: fixed short token strings occasionally replayed verbatim
        self.motifs = rng.choice(v, size=(cfg.n_motifs, cfg.motif_len), p=self.unigram)

    def sample_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, np.int64)
        state = rng.integers(0, self.cfg.markov_states)
        i = 0
        while i < n:
            if rng.random() < self.cfg.motif_prob:
                m = self.motifs[rng.integers(0, self.cfg.n_motifs)]
                take = min(len(m), n - i)
                out[i : i + take] = m[:take]
                i += take
                if i < n:
                    state = self.token_state[out[i - 1]]
                continue
            cand = self.state_tokens[state]
            out[i] = cand[rng.integers(0, len(cand))]
            state = self.token_state[out[i]]
            i += 1
        return out


def batches(cfg: DataConfig, *, host_id: int = 0, num_hosts: int = 1,
            start_step: int = 0) -> Iterator[Batch]:
    """Infinite deterministic batch stream; disjoint across hosts; resumable
    by step (checkpoint restart contract)."""
    gen = SyntheticLM(cfg)
    per_host = cfg.batch_size // num_hosts
    assert per_host * num_hosts == cfg.batch_size
    step = start_step
    while True:
        toks = np.empty((per_host, cfg.seq_len), np.int32)
        for b in range(per_host):
            rng = np.random.default_rng(
                (cfg.seed, step, host_id * per_host + b))
            toks[b] = gen.sample_tokens(rng, cfg.seq_len)
        yield Batch(tokens=jnp.asarray(toks),
                    loss_mask=jnp.ones((per_host, cfg.seq_len), jnp.float32))
        step += 1


def eval_batches(cfg: DataConfig, n: int, *, seed_offset: int = 10_000):
    """Finite held-out set (disjoint seeds from the train stream)."""
    c2 = dataclasses.replace(cfg, seed=cfg.seed + seed_offset)
    it = batches(c2)
    return [next(it) for _ in range(n)]


def unigram_entropy(cfg: DataConfig) -> float:
    gen = SyntheticLM(cfg)
    p = gen.unigram
    return float(-(p * np.log(p)).sum())
