"""Gemma-3 27B [hf:google/gemma-3-1b-pt family] — 5:1 local:global, 128k, qk-norm."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,           # 10 blocks of (5 local + 1 global) + 2 tail local
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=("attn_local",) * 5 + ("attn",),
    moe_pattern=(False,) * 6,
    window_size=1024,
    qk_norm=True,
    sandwich_norm=True,
    scale_embedding=True,
    ffn_activation="swiglu",
    rope_theta=1000000.0,
    max_seq_len=131072,
    source="hf:google/gemma-3-1b-pt (Gemma 3 family card)",
).validate()
