"""Nemotron-4 15B [arXiv:2402.16819] — dense GQA, squared-ReLU MLP, layernorm."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    block_pattern=("attn",),
    moe_pattern=(False,),
    ffn_activation="sq_relu",
    norm_type="layernorm",
    tie_embeddings=False,
    rope_theta=10000.0,
    max_seq_len=4096,
    source="arXiv:2402.16819 (Nemotron-4 15B)",
).validate()
