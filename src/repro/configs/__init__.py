"""Architecture registry: --arch <id> resolves here."""

from repro.configs.base import (
    INPUT_SHAPES,
    EncoderConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    smoke_variant,
)

from repro.configs import (  # noqa: E402
    deepseek_v2_236b,
    gemma2_27b,
    gemma3_27b,
    granite_3_2b,
    internvl2_26b,
    jamba_v0_1_52b,
    llama4_scout_17b_a16e,
    mamba2_780m,
    mixtral_8x7b,
    nemotron_4_15b,
    phi_moe,
    whisper_tiny,
)

# The 10 assigned architectures (brief) ...
ASSIGNED_ARCHS = {
    "gemma2-27b": gemma2_27b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
    "gemma3-27b": gemma3_27b.CONFIG,
    "granite-3-2b": granite_3_2b.CONFIG,
    "jamba-v0.1-52b": jamba_v0_1_52b.CONFIG,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.CONFIG,
    "internvl2-26b": internvl2_26b.CONFIG,
    "nemotron-4-15b": nemotron_4_15b.CONFIG,
    "whisper-tiny": whisper_tiny.CONFIG,
}

# ... plus the paper's own evaluation models.
PAPER_ARCHS = {
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
    "phi-moe": phi_moe.CONFIG,
}

ARCHS = {**ASSIGNED_ARCHS, **PAPER_ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


# (arch, shape) pairs excluded from the dry-run matrix, with reasons
# (mirrors DESIGN.md §5).
LONG_CONTEXT_SKIPS = {
    "deepseek-v2-236b": "pure full attention (MLA is still global); no sub-quadratic variant",
    "granite-3-2b": "pure full attention",
    "nemotron-4-15b": "pure full attention",
    "internvl2-26b": "pure full attention backbone",
    "whisper-tiny": "enc-dec decoder context is 448 by construction",
}


def shape_supported(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch in LONG_CONTEXT_SKIPS:
        return False
    return True


__all__ = [
    "ARCHS", "ASSIGNED_ARCHS", "PAPER_ARCHS", "INPUT_SHAPES",
    "LONG_CONTEXT_SKIPS", "EncoderConfig", "InputShape", "MLAConfig",
    "ModelConfig", "MoEConfig", "SSMConfig", "get_config", "shape_supported",
    "smoke_variant",
]
