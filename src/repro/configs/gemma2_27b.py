"""Gemma-2 27B [arXiv:2408.00118] — dense, local+global alternating, softcaps."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    block_pattern=("attn_local", "attn"),   # alternating 4096-window local / global
    moe_pattern=(False, False),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    ffn_activation="swiglu",
    sandwich_norm=True,
    scale_embedding=True,
    rope_theta=10000.0,
    max_seq_len=8192,
    source="arXiv:2408.00118 (Gemma 2)",
).validate()
