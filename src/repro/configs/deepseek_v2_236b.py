"""DeepSeek-V2 236B [arXiv:2405.04434] — MoE 160e top-6, 2 shared, MLA kv_lora=512.

First layer uses a dense FFN (the paper's design); the remaining 59 are MoE.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,        # MLA: KV heads notionally = heads; cache is compressed
    head_dim=128,
    d_ff=12288,              # dense first-layer FFN width
    vocab_size=102400,
    prefix_pattern=("attn",),
    prefix_moe=(False,),
    block_pattern=("attn",),
    moe_pattern=(True,),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff_expert=1536,
        num_shared_experts=2,
        d_ff_shared=1536,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    ffn_activation="swiglu",
    rope_theta=10000.0,
    max_seq_len=131072,
    source="arXiv:2405.04434 (DeepSeek-V2)",
).validate()
