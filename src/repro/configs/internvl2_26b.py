"""InternVL2-26B [arXiv:2404.16821] — InternViT (stub) + InternLM2-20B backbone.

The vision tower is a STUB per the brief: input_specs provides precomputed
patch embeddings (256 tokens after pixel-shuffle) prepended to the text.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    block_pattern=("attn",),
    moe_pattern=(False,),
    frontend="vision_patches",
    num_prefix_tokens=256,
    ffn_activation="swiglu",
    rope_theta=1000000.0,
    max_seq_len=32768,
    source="arXiv:2404.16821 (InternVL2; InternLM2 backbone)",
).validate()
