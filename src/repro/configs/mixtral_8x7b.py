"""Mixtral-8x7B [arXiv:2401.04088] — the paper's primary evaluation model."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("attn",),
    moe_pattern=(True,),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336, capacity_factor=1.25),
    ffn_activation="swiglu",
    rope_theta=1000000.0,
    max_seq_len=32768,
    source="arXiv:2401.04088 (Mixtral of Experts); HOBBIT Table 1",
).validate()
