"""Phi-MoE (Phi-3.5-MoE) [arXiv:2404.14219] — the paper's second model.
16 experts/layer, top-2, 32 layers (HOBBIT Table 1)."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi-moe",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    block_pattern=("attn",),
    moe_pattern=(True,),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400, capacity_factor=1.25),
    ffn_activation="swiglu",
    rope_theta=10000.0,
    max_seq_len=131072,
    source="arXiv:2404.14219 (Phi-3.5-MoE); HOBBIT Table 1",
).validate()
