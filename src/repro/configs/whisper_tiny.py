"""Whisper-tiny [arXiv:2212.04356] — enc-dec audio; conv/mel frontend STUBBED.

input_specs provides precomputed frame embeddings (1500, 384) — the conv
feature extractor is the one allowed stub.  The decoder (what we build in
full) is a 4-layer transformer with cross-attention into the encoder states.
"""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    block_pattern=("attn",),
    moe_pattern=(False,),
    encoder=EncoderConfig(num_layers=4, d_model=384, num_heads=6, d_ff=1536, seq_len=1500),
    frontend="audio_frames",
    ffn_activation="gelu",
    norm_type="layernorm",
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions, not rope
    max_seq_len=448,
    source="arXiv:2212.04356 (Whisper)",
).validate()
