"""Config system.

A ``ModelConfig`` fully describes one architecture.  Heterogeneous layer stacks
are expressed as a repeating *block pattern* (one period of layer kinds) plus an
optional unrolled prefix (e.g. DeepSeek's dense first layer) and tail (layers
left over when depth % period != 0).  The scanned body keeps HLO size and
compile time O(period) instead of O(depth).

Layer kinds:  "attn" (global), "attn_local" (sliding window), "attn_chunked"
(block-local chunks, llama4 iRoPE style), "ssm" (Mamba2 SSD).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0                 # per shared expert (deepseek style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # beyond-paper: resident expert weight precision on the distributed path
    expert_precision: str = "bf16"       # bf16 | int8 | int4


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                 # 0 = full-rank queries
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style bidirectional encoder consumed via cross-attention."""
    num_layers: int = 4
    d_model: int = 384
    num_heads: int = 6
    d_ff: int = 1536
    seq_len: int = 1500                  # mel frames after conv frontend (stubbed)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    moe_pattern: Tuple[bool, ...] = (False,)   # which pattern slots use MoE FFN
    prefix_pattern: Tuple[str, ...] = ()       # unrolled leading layers
    prefix_moe: Tuple[bool, ...] = ()
    window_size: int = 4096                    # attn_local / attn_chunked extent
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    ffn_activation: str = "swiglu"             # swiglu | gelu | sq_relu
    norm_type: str = "rmsnorm"                 # rmsnorm | layernorm
    sandwich_norm: bool = False                # gemma2/3 post-norms
    qk_norm: bool = False                      # gemma3
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    scale_embedding: bool = False              # gemma: x *= sqrt(d_model)
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None             # audio_frames | vision_patches
    num_prefix_tokens: int = 0                 # VLM patch tokens prepended
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    max_seq_len: int = 131072
    source: str = ""                           # citation for the config

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def body_layers(self) -> int:
        return self.num_layers - len(self.prefix_pattern)

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_blocks(self) -> int:
        return self.body_layers // self.period

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        tail = self.body_layers % self.period
        return self.block_pattern[:tail]

    @property
    def tail_moe(self) -> Tuple[bool, ...]:
        tail = self.body_layers % self.period
        return self.moe_pattern[:tail]

    def layer_kinds(self) -> Tuple[str, ...]:
        """Flat per-layer kind list (prefix + scanned body + tail)."""
        return (self.prefix_pattern
                + self.block_pattern * self.num_blocks
                + self.tail_pattern)

    def layer_is_moe(self) -> Tuple[bool, ...]:
        return (self.prefix_moe
                + self.moe_pattern * self.num_blocks
                + self.tail_moe)

    @property
    def is_sub_quadratic(self) -> bool:
        """True when no layer does *global* attention over the full context,
        or the architecture is explicitly long-context by design (hybrid SSM).

        Used by the launcher to decide long_500k eligibility together with the
        per-arch skip table in DESIGN.md §5."""
        kinds = set(self.layer_kinds())
        if kinds <= {"ssm"}:
            return True
        return "attn" not in kinds

    def validate(self) -> "ModelConfig":
        assert self.num_layers > 0 and self.d_model > 0
        assert len(self.moe_pattern) == len(self.block_pattern), self.name
        assert len(self.prefix_moe) == len(self.prefix_pattern), self.name
        assert self.body_layers >= self.period, self.name
        if any(self.layer_is_moe()):
            assert self.moe is not None, self.name
        if "ssm" in self.layer_kinds():
            assert self.ssm is not None, self.name
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads not divisible by kv heads")
        return self

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for kind, is_moe in zip(self.layer_kinds(), self.layer_is_moe()):
            if kind.startswith("attn"):
                if self.mla is not None:
                    m = self.mla
                    qd = m.nope_head_dim + m.rope_head_dim
                    n += d * self.num_heads * qd                       # wq
                    n += d * (m.kv_lora_rank + m.rope_head_dim)        # w_dkv
                    n += m.kv_lora_rank * self.num_heads * m.nope_head_dim
                    n += m.kv_lora_rank * self.num_heads * m.v_head_dim
                    n += self.num_heads * m.v_head_dim * d             # wo
                else:
                    n += d * self.num_heads * hd
                    n += 2 * d * self.num_kv_heads * hd
                    n += self.num_heads * hd * d
            elif kind == "ssm":
                s = self.ssm
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                conv_dim = d_in + 2 * s.n_groups * s.d_state
                n += d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                n += s.d_conv * conv_dim + 2 * nheads + d_in * d
            # FFN
            if is_moe:
                mc = self.moe
                mult = 3 if self.ffn_activation == "swiglu" else 2
                n += d * mc.num_experts  # router
                n += mc.num_experts * mult * d * mc.d_ff_expert
                if mc.num_shared_experts:
                    n += mult * d * (mc.d_ff_shared or mc.d_ff_expert) * mc.num_shared_experts
            else:
                mult = 3 if self.ffn_activation == "swiglu" else 2
                n += mult * d * self.d_ff
        if self.encoder is not None:
            e = self.encoder
            per = 4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff
            n += e.num_layers * per
            # decoder cross-attention (one per decoder layer)
            n += self.num_layers * 4 * d * d
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        mc = self.moe
        mult = 3 if self.ffn_activation == "swiglu" else 2
        per_expert = mult * d * mc.d_ff_expert
        n_moe_layers = sum(self.layer_is_moe())
        total = self.param_count()
        total -= n_moe_layers * mc.num_experts * per_expert
        total += n_moe_layers * mc.top_k * per_expert
        return total


# Input shapes assigned to this paper (see the brief).
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def smoke_variant(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
                  vocab: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=4 experts etc.)."""
    period = cfg.period
    layers = max(layers, period)
    layers = (layers // period) * period + len(cfg.prefix_pattern)
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(heads, cfg.num_kv_heads))
    while heads % kv:
        kv -= 1
    kw = dict(
        num_layers=layers, d_model=d_model, num_heads=heads, num_kv_heads=kv,
        d_ff=d_model * 2, vocab_size=vocab, head_dim=d_model // heads,
        max_seq_len=1024, num_prefix_tokens=min(cfg.num_prefix_tokens, 8),
        window_size=min(cfg.window_size, 64),
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=d_model, d_ff_shared=d_model if cfg.moe.d_ff_shared else 0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=64, rope_head_dim=32,
                              nope_head_dim=d_model // heads, v_head_dim=d_model // heads)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=32, head_dim=32, chunk_size=32)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(num_layers=2, d_model=d_model, num_heads=heads,
                                      d_ff=d_model * 2, seq_len=32)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw).validate()
