"""Mamba2-780m [arXiv:2405.21060] — attention-free SSM (state-space duality)."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,             # unused (attention-free); kept for config uniformity
    num_kv_heads=1,
    d_ff=0,                  # no FFN: mamba2 blocks are the whole layer
    vocab_size=50280,
    block_pattern=("ssm",),
    moe_pattern=(False,),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    max_seq_len=1048576,
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
).validate()
