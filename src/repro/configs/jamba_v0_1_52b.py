"""Jamba v0.1 52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE 16e top-2.

One Jamba block = 8 layers with a single attention layer (index 4 in the
released model) and MoE replacing the MLP every other layer (odd indices).
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,           # 4 blocks of period 8
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    moe_pattern=(False, True, False, True, False, True, False, True),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336, capacity_factor=1.25),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    ffn_activation="swiglu",
    rope_theta=10000.0,      # jamba attention layers use no positional encoding;
    max_seq_len=262144,      # we keep rope off for them via use_rope=False in model
    source="arXiv:2403.19887 (Jamba)",
).validate()
