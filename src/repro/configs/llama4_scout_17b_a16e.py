"""Llama-4 Scout 17B-active/16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE top-1
+ shared expert, iRoPE chunked-local attention (3 chunked : 1 global)."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,           # 12 blocks of (3 chunked + 1 global)
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn_chunked", "attn_chunked", "attn_chunked", "attn"),
    moe_pattern=(True, True, True, True),
    window_size=8192,        # attention chunk size (iRoPE)
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                  num_shared_experts=1, d_ff_shared=8192, capacity_factor=1.5),
    ffn_activation="swiglu",
    rope_theta=500000.0,
    max_seq_len=10485760,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
).validate()
