"""repro: HOBBIT (mixed-precision expert offloading for MoE inference) on
TPU/JAX - multi-pod training/serving framework. See DESIGN.md."""

__version__ = "0.1.0"
