"""Public jit'd wrappers for the Pallas kernels, with shape padding and a
CPU-friendly execution policy.

On the TPU target the kernels run compiled; on this CPU container they run in
``interpret=True`` mode (Pallas executes the kernel body in Python) so every
test validates the real kernel body.  ``mode`` selects:

    "auto"      pallas-interpret on CPU, pallas-compiled on TPU
    "pallas"    force the pallas path (compiled on TPU, interpret elsewhere)
    "xla"       reference dense path (dequantize + dot) — used by the model
                code when running big CPU smoke tests where interpret-mode
                python execution would be too slow.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.stacked_gating import stacked_gating_pallas
from repro.quant.quantize import PACK_FACTOR, QTensor, dequantize


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def dequant_matmul(x, q: QTensor, *, mode: str = "auto",
                   block_m: int = 128, block_n: int = 128, block_k: int = 256):
    """y = x @ dequant(q), fused.  x: (..., K); q: K x N quantized."""
    if mode == "xla" or (mode == "auto" and not _on_tpu()):
        return ref.dequant_matmul_ref(x, q)

    interpret = not _on_tpu()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    m, n = x2.shape[0], q.data.shape[-1]
    pack = PACK_FACTOR[q.bits]

    bm = min(block_m, _pad_to(m, 8))
    bk = min(block_k, k)
    bn = min(block_n, n)
    mp, np_, kp = _pad_to(m, bm), _pad_to(n, bn), _pad_to(k, bk)
    if (mp, np_, kp) != (m, n, k):
        x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
        data = jnp.pad(q.data, ((0, (kp - k) // pack), (0, np_ - n)))
        scale = jnp.pad(q.scale, ((0, (kp - k) // q.group_size), (0, np_ - n)))
    else:
        data, scale = q.data, q.scale
    out = dequant_matmul_pallas(
        x2, data, scale, bits=q.bits, group_size=q.group_size,
        block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def grouped_dequant_matmul(x, data, scale, *, bits: int, group_size: int,
                           mode: str = "auto",
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 256):
    """Batched per-expert fused dequant GEMM: y[p] = x[p] @ dequant(data[p]).

    This is the grouped-decode hot path: every active (token row, expert)
    pair of a MoE layer becomes one slice p, so the whole layer's low-
    precision expert compute is a single dispatch instead of O(batch*top_k)
    tiny calls.

        x      (P, K)             activations, one row per pair
        data   (P, K // pack, N)  packed codes gathered from the lo pool
        scale  (P, K // group, N) groupwise scales
        out    (P, N)             f32

    On TPU the 2-D fused kernel is vmapped over the pair axis (one kernel
    launch with a batch grid dimension); elsewhere the reference dequant +
    einsum path runs (one XLA dispatch either way)."""
    if mode == "xla" or (mode == "auto" and not _on_tpu()):
        q = QTensor(data, scale, bits, group_size, x.shape[-1])
        w = dequantize(q)                       # (P, K, N) f32
        return jnp.einsum("pk,pkn->pn", x.astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)

    def one(xp, dp, sp):
        q = QTensor(dp, sp, bits, group_size, xp.shape[-1])
        return dequant_matmul(xp[None], q, mode=mode, block_m=block_m,
                              block_n=block_n, block_k=block_k)[0]

    return jax.vmap(one)(x, data, scale)


def stacked_gating(x, gates, *, mode: str = "auto", block_d: int = 512):
    """logits (P, B, E) for P stacked gate matrices; see stacked_gating.py."""
    if mode == "xla" or (mode == "auto" and not _on_tpu()):
        return ref.stacked_gating_ref(x, gates)
    interpret = not _on_tpu()
    b, d = x.shape
    p, _, e = gates.shape
    bd = min(block_d, d)
    dp = _pad_to(d, bd)
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        gates = jnp.pad(gates, ((0, 0), (0, dp - d), (0, 0)))
    return stacked_gating_pallas(x, gates, block_d=bd, interpret=interpret)


def flash_decode(q, k, v, lengths, *, mode: str = "auto", block_s: int = 256):
    """Single-token decode attention; expands GQA kv heads to q heads.
    q: (B,Hq,hd); k/v: (B,S,Hkv,hd); lengths: (B,)."""
    b, hq, hd = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        g = hq // hkv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if mode == "xla" or (mode == "auto" and not _on_tpu()):
        return ref.flash_decode_ref(q, k, v, lengths)
    interpret = not _on_tpu()
    s = k.shape[1]
    bs = min(block_s, s)
    sp = _pad_to(s, bs)
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    return flash_decode_pallas(q, k, v, lengths, block_s=bs, interpret=interpret)
