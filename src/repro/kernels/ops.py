"""Public jit'd wrappers for the Pallas kernels, with shape padding, a
CPU-friendly execution policy, and dispatch observability.

On the TPU target the kernels run compiled; on CPU the pallas path runs in
``interpret=True`` mode (Pallas executes the kernel body in Python) so tests
validate the real kernel body.  ``mode`` selects:

    "auto"      pallas on TPU, the XLA reference path elsewhere — unless the
                ``REPRO_KERNEL_MODE`` env var ("xla" / "pallas") overrides
                the choice (CI sets "pallas" to run every kernel body in
                interpret mode on CPU)
    "pallas"    force the pallas path (compiled on TPU, interpret elsewhere)
    "xla"       reference dense path (dequantize + dot) — used for big CPU
                smoke tests where interpret-mode python execution would be
                too slow

Every dispatch records which implementation ran in a module-level counter
(``dispatch_counts()``), surfaced through ``engine.stats()["kernel_dispatch"]``
so a misconfigured run can't silently benchmark the einsum path.  Counters
tick when an op is dispatched OR traced into a jit computation: under jit
a nonzero ``<op>.pallas`` count proves the pallas kernel is in the compiled
graph (steady-state calls replay the trace without re-counting).
"""

from __future__ import annotations

import os
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import (
    dequant_matmul_pallas,
    grouped_dequant_combine_pallas,
    grouped_dequant_matmul_pallas,
)
from repro.kernels.flash_decode import (
    flash_decode_pallas,
    paged_flash_decode_pallas,
)
from repro.kernels.stacked_gating import gating_topk_pallas, stacked_gating_pallas
from repro.quant.quantize import PACK_FACTOR, QTensor, dequantize

_DISPATCH_COUNTS: Dict[str, int] = {}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    """Collapse "auto" to the implementation that will actually run."""
    if mode == "auto":
        env = os.environ.get("REPRO_KERNEL_MODE", "")
        if env in ("xla", "pallas"):
            return env
        return "pallas" if _on_tpu() else "xla"
    return mode


def _record(op: str, impl: str) -> None:
    key = f"{op}.{impl}"
    _DISPATCH_COUNTS[key] = _DISPATCH_COUNTS.get(key, 0) + 1


def _record_pallas(op: str, interpret: bool) -> None:
    _record(op, "pallas_interpret" if interpret else "pallas")


def dispatch_counts() -> Dict[str, int]:
    """Copy of the per-op dispatch counters, keyed ``"<op>.<impl>"`` with
    impl one of xla / pallas / pallas_interpret."""
    return dict(_DISPATCH_COUNTS)


def reset_dispatch_counts() -> None:
    _DISPATCH_COUNTS.clear()


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _k_block(k: int, group_size: int, pack: int, cap: int) -> int:
    """Largest k-block <= cap that divides K and covers whole quant groups
    and packed bytes (K is a multiple of both by the quant layout)."""
    # smallest legal tile: lcm(group_size, pack); group_size is a multiple
    # of pack for every supported layout, so group_size itself is legal
    step = group_size if group_size % pack == 0 else group_size * pack
    best = step
    m = step
    while m <= min(cap, k):
        if k % m == 0:
            best = m
        m += step
    return best


def dequant_matmul(x, q: QTensor, *, mode: str = "auto",
                   block_m: int = 128, block_n: int = 128, block_k: int = 256):
    """y = x @ dequant(q), fused.  x: (..., K); q: K x N quantized."""
    if _resolve(mode) == "xla":
        _record("dequant_matmul", "xla")
        return ref.dequant_matmul_ref(x, q)

    interpret = not _on_tpu()
    _record_pallas("dequant_matmul", interpret)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    m, n = x2.shape[0], q.data.shape[-1]
    pack = PACK_FACTOR[q.bits]

    bm = min(block_m, _pad_to(m, 8))
    bk = min(block_k, k)
    bn = min(block_n, n)
    mp, np_, kp = _pad_to(m, bm), _pad_to(n, bn), _pad_to(k, bk)
    if (mp, np_, kp) != (m, n, k):
        x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
        data = jnp.pad(q.data, ((0, (kp - k) // pack), (0, np_ - n)))
        scale = jnp.pad(q.scale, ((0, (kp - k) // q.group_size), (0, np_ - n)))
    else:
        data, scale = q.data, q.scale
    out = dequant_matmul_pallas(
        x2, data, scale, bits=q.bits, group_size=q.group_size,
        block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def grouped_dequant_matmul(x, data, scale, *, bits: int, group_size: int,
                           mode: str = "auto", block_k: int = 512):
    """Batched per-expert fused dequant GEMM: y[p] = x[p] @ dequant(data[p]).

    This is the grouped-decode hot path: every active (token row, expert)
    pair of a MoE layer becomes one slice p, so the whole layer's low-
    precision expert compute is a single dispatch instead of O(batch*top_k)
    tiny calls.

        x      (P, K)             activations, one row per pair
        data   (P, K // pack, N)  packed codes gathered from the lo pool
        scale  (P, K // group, N) groupwise scales
        out    (P, N)             f32

    The pallas path is ONE kernel launch over the (P, K/bk) grid — the
    int-unpack, scale-multiply, and GEMM happen per tile in VREGs; the
    reference path is dense dequantize + einsum (one XLA dispatch)."""
    if _resolve(mode) == "xla":
        _record("grouped_dequant_matmul", "xla")
        q = QTensor(data, scale, bits, group_size, x.shape[-1])
        w = dequantize(q)                       # (P, K, N) f32
        return jnp.einsum("pk,pkn->pn", x.astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)

    interpret = not _on_tpu()
    _record_pallas("grouped_dequant_matmul", interpret)
    k = x.shape[-1]
    bk = _k_block(k, group_size, PACK_FACTOR[bits], block_k)
    return grouped_dequant_matmul_pallas(
        x, data, scale, bits=bits, group_size=group_size, block_k=bk,
        interpret=interpret)


def grouped_dequant_combine(x, data, scale, rows, weights, *, bits: int,
                            group_size: int, num_rows: int,
                            mode: str = "auto", block_k: int = 512):
    """Fused grouped dequant-GEMM + gated combine over the padded pair grid:
    out[rows[p]] += weights[p] * (x[p] @ dequant(data[p], scale[p])).

        x        (P, K)             per-pair activations
        data     (P, K//pack, N)    packed codes
        scale    (P, K//group, N)   groupwise scales
        rows     (P,) int           destination token row, sorted
                                    non-decreasing; pads carry num_rows
        weights  (P,) f32           gate weight per pair (0 for pads)
        out      (num_rows, N) f32

    The pallas path scatters through a data-dependent output index map so
    unpack, GEMM, gating, and combine are one kernel; pad rows (row ==
    num_rows) are dropped in-kernel by weight 0 + the wrapper's hit mask.
    The reference path is dequantize + einsum + ``.at[rows].add`` with
    mode="drop"."""
    if _resolve(mode) == "xla":
        _record("grouped_dequant_combine", "xla")
        return ref.grouped_dequant_combine_ref(
            x, data, scale, rows, weights, bits=bits, group_size=group_size,
            num_rows=num_rows)

    interpret = not _on_tpu()
    _record_pallas("grouped_dequant_combine", interpret)
    k = x.shape[-1]
    bk = _k_block(k, group_size, PACK_FACTOR[bits], block_k)
    return grouped_dequant_combine_pallas(
        x, data, scale, rows, weights, bits=bits, group_size=group_size,
        num_rows=num_rows, block_k=bk, interpret=interpret)


def stacked_gating(x, gates, *, mode: str = "auto", block_d: int = 512):
    """logits (P, B, E) for P stacked gate matrices; see stacked_gating.py."""
    if _resolve(mode) == "xla":
        _record("stacked_gating", "xla")
        return ref.stacked_gating_ref(x, gates)
    interpret = not _on_tpu()
    _record_pallas("stacked_gating", interpret)
    b, d = x.shape
    p, _, e = gates.shape
    bd = min(block_d, d)
    dp = _pad_to(d, bd)
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        gates = jnp.pad(gates, ((0, 0), (0, dp - d), (0, 0)))
    return stacked_gating_pallas(x, gates, block_d=bd, interpret=interpret)


def gating_topk(x, gates, *, top_k: int, mode: str = "auto",
                block_d: int = 512):
    """Fused router: stacked gate matmul + softmax + top-k in one pass.

        x      (B, D)      activations
        gates  (P, D, E)   stacked router weights
        out    (logits (P,B,E) f32, vals (P,B,K) f32 softmax probs of the
                selected experts, idx (P,B,K) i32)

    Ties select the lowest expert index on both paths."""
    if _resolve(mode) == "xla":
        _record("gating_topk", "xla")
        return ref.gating_topk_ref(x, gates, top_k=top_k)
    interpret = not _on_tpu()
    _record_pallas("gating_topk", interpret)
    b, d = x.shape
    bd = min(block_d, d)
    dp = _pad_to(d, bd)
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        gates = jnp.pad(gates, ((0, 0), (0, dp - d), (0, 0)))
    return gating_topk_pallas(x, gates, top_k=top_k, block_d=bd,
                              interpret=interpret)


def flash_decode(q, k, v, lengths, *, mode: str = "auto", block_s: int = 256):
    """Single-token decode attention; expands GQA kv heads to q heads.
    q: (B,Hq,hd); k/v: (B,S,Hkv,hd); lengths: (B,)."""
    b, hq, hd = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        g = hq // hkv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if _resolve(mode) == "xla":
        _record("flash_decode", "xla")
        return ref.flash_decode_ref(q, k, v, lengths)
    interpret = not _on_tpu()
    _record_pallas("flash_decode", interpret)
    s = k.shape[1]
    bs = min(block_s, s)
    sp = _pad_to(s, bs)
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    return flash_decode_pallas(q, k, v, lengths, block_s=bs, interpret=interpret)


def paged_flash_decode(q, pages_k, pages_v, table, lengths, *,
                       mode: str = "auto", scale=None, softcap: float = 0.0):
    """Decode attention straight out of the paged KV pool — the page table
    drives the kernel's K/V block index maps, so the dense (B, maxp*psz)
    gathered cache view is never materialized (the ref oracle gathers).

        q        (B, Hq, hd)        current-token queries
        pages_k  (P, psz, Hkv, hd)  shared page pool (pages_v alike)
        table    (B, maxp) int      physical page per logical page
        lengths  (B,) int           valid cache tokens per slot
        out      (B, Hq, hd) f32    zeros where lengths == 0"""
    if _resolve(mode) == "xla":
        _record("paged_flash_decode", "xla")
        return ref.paged_flash_decode_ref(q, pages_k, pages_v, table, lengths,
                                          scale=scale, softcap=softcap)
    interpret = not _on_tpu()
    _record_pallas("paged_flash_decode", interpret)
    return paged_flash_decode_pallas(q, pages_k, pages_v, table, lengths,
                                     interpret=interpret, scale=scale,
                                     softcap=softcap)
