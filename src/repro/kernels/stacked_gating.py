"""Stacked gating Pallas kernel — the paper's "Stacking Computer" (HOBBIT §3.3).

The adaptive expert predictor needs the gate logits of the next ``p`` layers
evaluated on the *current* layer's hidden state.  Computed naively that is
``p`` sequential (D x E) matvecs; the paper's observation is that E is tiny
(8..160), so all ``p`` gates can be stacked into a single (p*E) output matmul
whose cost is flat in ``p`` (Fig. 17a).

Kernel contract:
    x        (B, D)        activations (bf16/f32)
    gates    (P, D, E)     stacked gate weights for the next P layers
    out      (P, B, E)     f32 logits

Grid over P: one gate layer per grid step; each step is a (B,D)x(D,E) tile
matmul held fully in VMEM (B and E are small at decode time; D is blocked).
Top-k selection happens outside the kernel (jnp.top_k on (P, B, E)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stacked_gating_kernel(x_ref, g_ref, o_ref, *, k_steps: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (B, bd)
    g = g_ref[0].astype(jnp.float32)            # (bd, E)
    o_ref[0] += jnp.dot(x, g, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def stacked_gating_pallas(x, gates, *, block_d: int = 512, interpret: bool = False):
    """logits[p] = x @ gates[p] for all p in one pallas_call."""
    b, d = x.shape
    p, dg, e = gates.shape
    assert dg == d
    block_d = min(block_d, d)
    assert d % block_d == 0
    k_steps = d // block_d

    kernel = functools.partial(_stacked_gating_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(p, k_steps),
        in_specs=[
            pl.BlockSpec((b, block_d), lambda ip, kk: (0, kk)),
            pl.BlockSpec((1, block_d, e), lambda ip, kk: (ip, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, e), lambda ip, kk: (ip, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, b, e), jnp.float32),
        interpret=interpret,
    )(x, gates)
