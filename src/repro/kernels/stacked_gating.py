"""Stacked gating Pallas kernel — the paper's "Stacking Computer" (HOBBIT §3.3).

The adaptive expert predictor needs the gate logits of the next ``p`` layers
evaluated on the *current* layer's hidden state.  Computed naively that is
``p`` sequential (D x E) matvecs; the paper's observation is that E is tiny
(8..160), so all ``p`` gates can be stacked into a single (p*E) output matmul
whose cost is flat in ``p`` (Fig. 17a).

Kernel contract:
    x        (B, D)        activations (bf16/f32)
    gates    (P, D, E)     stacked gate weights for the next P layers
    out      (P, B, E)     f32 logits

Grid over P: one gate layer per grid step; each step is a (B,D)x(D,E) tile
matmul held fully in VMEM (B and E are small at decode time; D is blocked).
`stacked_gating_pallas` emits logits only (top-k outside, predictor path);
`gating_topk_pallas` additionally runs softmax + iterative top-k selection
in the final k-step — the serving hot path's fused gating op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stacked_gating_kernel(x_ref, g_ref, o_ref, *, k_steps: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (B, bd)
    g = g_ref[0].astype(jnp.float32)            # (bd, E)
    o_ref[0] += jnp.dot(x, g, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def stacked_gating_pallas(x, gates, *, block_d: int = 512, interpret: bool = False):
    """logits[p] = x @ gates[p] for all p in one pallas_call."""
    b, d = x.shape
    p, dg, e = gates.shape
    assert dg == d
    block_d = min(block_d, d)
    assert d % block_d == 0
    k_steps = d // block_d

    kernel = functools.partial(_stacked_gating_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(p, k_steps),
        in_specs=[
            pl.BlockSpec((b, block_d), lambda ip, kk: (0, kk)),
            pl.BlockSpec((1, block_d, e), lambda ip, kk: (ip, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, e), lambda ip, kk: (ip, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, b, e), jnp.float32),
        interpret=interpret,
    )(x, gates)


def _gating_topk_kernel(x_ref, g_ref, l_ref, v_ref, i_ref, *, k_steps: int,
                        top_k: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        l_ref[...] = jnp.zeros_like(l_ref)

    x = x_ref[...].astype(jnp.float32)          # (B, bd)
    g = g_ref[0].astype(jnp.float32)            # (bd, E)
    l_ref[0] += jnp.dot(x, g, preferred_element_type=jnp.float32)

    @pl.when(kk == k_steps - 1)
    def _select():
        logits = l_ref[0]                       # (B, E) fully accumulated
        z = logits - jnp.max(logits, axis=-1, keepdims=True)
        ez = jnp.exp(z)
        probs = ez / jnp.sum(ez, axis=-1, keepdims=True)
        work = probs
        for j in range(top_k):                  # static unroll; ties -> lowest idx
            idx = jnp.argmax(work, axis=-1).astype(jnp.int32)       # (B,)
            v_ref[0, :, j] = jnp.max(work, axis=-1)
            i_ref[0, :, j] = idx
            sel = jax.lax.broadcasted_iota(jnp.int32, work.shape, 1) \
                == idx[:, None]
            work = jnp.where(sel, -1.0, work)


@functools.partial(jax.jit,
                   static_argnames=("top_k", "block_d", "interpret"))
def gating_topk_pallas(x, gates, *, top_k: int, block_d: int = 512,
                       interpret: bool = False):
    """Batched router matmul + softmax + top-k in one pass: the D axis is
    accumulated into the logits block across k-steps, and the final k-step
    runs softmax + iterative top-k selection on the VMEM-resident block
    before it flushes.  Returns (logits (P,B,E) f32, vals (P,B,K) f32 softmax
    probabilities of the selected experts, idx (P,B,K) i32)."""
    b, d = x.shape
    p, dg, e = gates.shape
    assert dg == d
    assert 0 < top_k <= e, (top_k, e)
    block_d = min(block_d, d)
    assert d % block_d == 0
    k_steps = d // block_d

    kernel = functools.partial(_gating_topk_kernel, k_steps=k_steps,
                               top_k=top_k)
    return pl.pallas_call(
        kernel,
        grid=(p, k_steps),
        in_specs=[
            pl.BlockSpec((b, block_d), lambda ip, kk: (0, kk)),
            pl.BlockSpec((1, block_d, e), lambda ip, kk: (ip, kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b, e), lambda ip, kk: (ip, 0, 0)),
            pl.BlockSpec((1, b, top_k), lambda ip, kk: (ip, 0, 0)),
            pl.BlockSpec((1, b, top_k), lambda ip, kk: (ip, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, b, e), jnp.float32),
            jax.ShapeDtypeStruct((p, b, top_k), jnp.float32),
            jax.ShapeDtypeStruct((p, b, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(x, gates)
