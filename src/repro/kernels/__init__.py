from repro.kernels.ops import dequant_matmul, flash_decode, stacked_gating

__all__ = ["dequant_matmul", "flash_decode", "stacked_gating"]
