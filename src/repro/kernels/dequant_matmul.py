"""Fused groupwise-dequant x matmul Pallas TPU kernel.

This is HOBBIT's expert-compute hot-spot, adapted to TPU: instead of
dequantizing a low-precision expert to bf16 in HBM and then running a GEMM
(the GPU flow: cudaMemcpy + dequant pass + cuBLAS), the packed int8/int4/int2
codes stay packed in HBM and are expanded to fp32 *inside the matmul tile
loop, in VREGs*, after the DMA into VMEM.  Decode-time expert FFNs are memory
bound, so shrinking the bytes the MXU pipeline pulls from HBM by 2-8x moves
the memory-roofline term directly.

Layout contract (matches repro.quant.quantize):
    x      (M, K)            bf16/f32 activations
    data   (K // pack, N)    int8, `pack` codes per byte along K
    scale  (K // group, N)   f32 groupwise scales
    out    (M, N)            f32

Tiling: grid (M/bm, N/bn, K/bk) with out-block accumulation over the k axis.
``bk`` must be a multiple of the quantization group size so each k-step sees
an integer number of scale rows; block shapes are MXU-aligned (128 lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.quantize import PACK_FACTOR


def _unpack_block(data_blk, bits: int):
    """(bk//pack, bn) int8 -> (bk, bn) f32 signed codes, inside the kernel."""
    pack = PACK_FACTOR[bits]
    if pack == 1:
        return data_blk.astype(jnp.float32)
    u = data_blk.astype(jnp.int32) & 0xFF  # treat as unsigned byte lanes
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    parts = []
    for i in range(pack):
        nib = (u >> (bits * i)) & mask
        parts.append(jnp.where(nib >= half, nib - (1 << bits), nib))
    codes = jnp.stack(parts, axis=1)  # (bk//pack, pack, bn)
    kp, _, bn = codes.shape
    return codes.reshape(kp * pack, bn).astype(jnp.float32)


def _dequant_matmul_kernel(x_ref, data_ref, scale_ref, o_ref, *, bits: int,
                           group_size: int, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_block(data_ref[...], bits)          # (bk, bn) f32
    scales = scale_ref[...]                              # (bk//G, bn) f32
    bk, bn = codes.shape
    groups = bk // group_size
    w = codes.reshape(groups, group_size, bn) * scales.reshape(groups, 1, bn)
    w = w.reshape(bk, bn)                                # dequantized tile
    x = x_ref[...].astype(jnp.float32)                   # (bm, bk)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group_size", "block_m", "block_n", "block_k", "interpret"),
)
def dequant_matmul_pallas(x, data, scale, *, bits: int, group_size: int,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 256, interpret: bool = False):
    """y = x @ dequant(data, scale).  Shapes must divide the block sizes."""
    m, k = x.shape
    kp, n = data.shape
    pack = PACK_FACTOR[bits]
    assert kp * pack == k, (kp, pack, k)
    assert block_k % group_size == 0, "block_k must cover whole quant groups"
    assert block_k % pack == 0
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)
    k_steps = k // block_k

    grid = (m // block_m, n // block_n, k_steps)
    kernel = functools.partial(
        _dequant_matmul_kernel, bits=bits, group_size=group_size, k_steps=k_steps
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // pack, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // group_size, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, data, scale)
