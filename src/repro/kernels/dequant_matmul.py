"""Fused groupwise-dequant x matmul Pallas TPU kernel.

This is HOBBIT's expert-compute hot-spot, adapted to TPU: instead of
dequantizing a low-precision expert to bf16 in HBM and then running a GEMM
(the GPU flow: cudaMemcpy + dequant pass + cuBLAS), the packed int8/int4/int2
codes stay packed in HBM and are expanded to fp32 *inside the matmul tile
loop, in VREGs*, after the DMA into VMEM.  Decode-time expert FFNs are memory
bound, so shrinking the bytes the MXU pipeline pulls from HBM by 2-8x moves
the memory-roofline term directly.

Layout contract (matches repro.quant.quantize):
    x      (M, K)            bf16/f32 activations
    data   (K // pack, N)    int8, `pack` codes per byte along K
    scale  (K // group, N)   f32 groupwise scales
    out    (M, N)            f32

Tiling: grid (M/bm, N/bn, K/bk) with out-block accumulation over the k axis.
``bk`` must be a multiple of the quantization group size so each k-step sees
an integer number of scale rows; block shapes are MXU-aligned (128 lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.quantize import PACK_FACTOR


def _unpack_block(data_blk, bits: int):
    """(bk//pack, bn) int8 -> (bk, bn) f32 signed codes, inside the kernel."""
    pack = PACK_FACTOR[bits]
    if pack == 1:
        return data_blk.astype(jnp.float32)
    u = data_blk.astype(jnp.int32) & 0xFF  # treat as unsigned byte lanes
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    parts = []
    for i in range(pack):
        nib = (u >> (bits * i)) & mask
        parts.append(jnp.where(nib >= half, nib - (1 << bits), nib))
    codes = jnp.stack(parts, axis=1)  # (bk//pack, pack, bn)
    kp, _, bn = codes.shape
    return codes.reshape(kp * pack, bn).astype(jnp.float32)


def _dequant_matmul_kernel(x_ref, data_ref, scale_ref, o_ref, *, bits: int,
                           group_size: int, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_block(data_ref[...], bits)          # (bk, bn) f32
    scales = scale_ref[...]                              # (bk//G, bn) f32
    bk, bn = codes.shape
    groups = bk // group_size
    w = codes.reshape(groups, group_size, bn) * scales.reshape(groups, 1, bn)
    w = w.reshape(bk, bn)                                # dequantized tile
    x = x_ref[...].astype(jnp.float32)                   # (bm, bk)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group_size", "block_m", "block_n", "block_k", "interpret"),
)
def dequant_matmul_pallas(x, data, scale, *, bits: int, group_size: int,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 256, interpret: bool = False):
    """y = x @ dequant(data, scale).  Shapes must divide the block sizes."""
    m, k = x.shape
    kp, n = data.shape
    pack = PACK_FACTOR[bits]
    assert kp * pack == k, (kp, pack, k)
    assert block_k % group_size == 0, "block_k must cover whole quant groups"
    assert block_k % pack == 0
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)
    k_steps = k // block_k

    grid = (m // block_m, n // block_n, k_steps)
    kernel = functools.partial(
        _dequant_matmul_kernel, bits=bits, group_size=group_size, k_steps=k_steps
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // pack, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // group_size, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, data, scale)


def _dequant_tile(data_ref, scale_ref, *, bits: int, group_size: int):
    """Expand one (1, bk//pack, N) packed tile to (bk, N) f32 in VREGs."""
    codes = _unpack_block(data_ref[0], bits)                 # (bk, bn)
    scales = scale_ref[0]                                    # (bk//G, bn)
    bk, bn = codes.shape
    groups = bk // group_size
    w = codes.reshape(groups, group_size, bn) * scales.reshape(groups, 1, bn)
    return w.reshape(bk, bn)


def _grouped_dequant_kernel(x_ref, data_ref, scale_ref, o_ref, *, bits: int,
                            group_size: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(data_ref, scale_ref, bits=bits, group_size=group_size)
    x = x_ref[...].astype(jnp.float32)                       # (1, bk)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("bits", "group_size", "block_k", "interpret"))
def grouped_dequant_matmul_pallas(x, data, scale, *, bits: int,
                                  group_size: int, block_k: int = 512,
                                  interpret: bool = False):
    """y[p] = x[p] @ dequant(data[p], scale[p]) — the whole (P, K) pair
    batch in ONE kernel launch, grid (P, K/bk), out-row accumulation over
    the k axis.  x: (P,K); data: (P,K//pack,N); scale: (P,K//group,N)."""
    p_, k = x.shape
    _, kp, n = data.shape
    pack = PACK_FACTOR[bits]
    assert kp * pack == k, (kp, pack, k)
    assert block_k % group_size == 0 and block_k % pack == 0
    assert k % block_k == 0, (k, block_k)
    k_steps = k // block_k

    kernel = functools.partial(_grouped_dequant_kernel, bits=bits,
                               group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=(p_, k_steps),
        in_specs=[
            pl.BlockSpec((1, block_k), lambda ip, kk: (ip, kk)),
            pl.BlockSpec((1, block_k // pack, n), lambda ip, kk: (ip, kk, 0)),
            pl.BlockSpec((1, block_k // group_size, n),
                         lambda ip, kk: (ip, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda ip, kk: (ip, 0)),
        out_shape=jax.ShapeDtypeStruct((p_, n), jnp.float32),
        interpret=interpret,
    )(x, data, scale)


def _grouped_dequant_combine_kernel(rows_ref, x_ref, data_ref, scale_ref,
                                    w_ref, o_ref, *, bits: int,
                                    group_size: int):
    ip = pl.program_id(0)
    kk = pl.program_id(1)
    # the output block index is rows_ref[ip]: consecutive pairs hitting the
    # same row revisit the block, so initialize only on the first visit
    first = (ip == 0) | (rows_ref[ip] != rows_ref[jnp.maximum(ip - 1, 0)])

    @pl.when(first & (kk == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(data_ref, scale_ref, bits=bits, group_size=group_size)
    x = x_ref[...].astype(jnp.float32)                       # (1, bk)
    o_ref[...] += w_ref[0, 0] * jnp.dot(x, w,
                                        preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group_size", "num_rows", "block_k", "interpret"))
def grouped_dequant_combine_pallas(x, data, scale, rows, weights, *,
                                   bits: int, group_size: int, num_rows: int,
                                   block_k: int = 512,
                                   interpret: bool = False):
    """Fused grouped dequant-GEMM + gated combine: out[rows[p]] +=
    weights[p] * (x[p] @ dequant(data[p], scale[p])), in one kernel launch.

    The combine-scatter happens through a data-dependent OUTPUT index map
    (out block index = rows[p], a scalar-prefetch operand): pairs of the
    same token row land in the same VMEM-resident output block and
    accumulate in place.  `rows` MUST therefore be sorted non-decreasing
    (the engine's pair builder emits them that way); pad pairs carry
    row == num_rows, are clipped into range for the index map, and are
    neutralized by weight 0 — the wrapper zeroes rows no real pair visited
    (their pool buffers are never initialized by the kernel)."""
    p_, k = x.shape
    _, kp, n = data.shape
    pack = PACK_FACTOR[bits]
    assert kp * pack == k, (kp, pack, k)
    assert block_k % group_size == 0 and block_k % pack == 0
    assert k % block_k == 0, (k, block_k)
    assert rows.shape == (p_,) and weights.shape == (p_,)
    k_steps = k // block_k

    rows_clip = jnp.clip(rows, 0, num_rows - 1).astype(jnp.int32)
    wcol = weights.reshape(p_, 1).astype(jnp.float32)
    kernel = functools.partial(_grouped_dequant_combine_kernel, bits=bits,
                               group_size=group_size)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(p_, k_steps),
            in_specs=[
                pl.BlockSpec((1, block_k), lambda ip, kk, rr: (ip, kk)),
                pl.BlockSpec((1, block_k // pack, n),
                             lambda ip, kk, rr: (ip, kk, 0)),
                pl.BlockSpec((1, block_k // group_size, n),
                             lambda ip, kk, rr: (ip, kk, 0)),
                pl.BlockSpec((1, 1), lambda ip, kk, rr: (ip, 0)),
            ],
            out_specs=pl.BlockSpec((1, n), lambda ip, kk, rr: (rr[ip], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_rows, n), jnp.float32),
        interpret=interpret,
    )(rows_clip, x, data, scale, wcol)
    hit = jnp.zeros((num_rows,), jnp.float32).at[rows].add(1.0, mode="drop")
    return jnp.where(hit[:, None] > 0, out, 0.0)
