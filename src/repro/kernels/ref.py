"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.quantize import QTensor, dequantize


def dequant_matmul_ref(x, q: QTensor):
    """y = x @ dequant(q) computed with the straightforward dense path."""
    w = dequantize(q, dtype=jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def stacked_gating_ref(x, gates):
    """logits[p] = x @ gates[p] via einsum."""
    return jnp.einsum(
        "bd,pde->pbe", x.astype(jnp.float32), gates.astype(jnp.float32),
        preferred_element_type=jnp.float32)


def flash_decode_ref(q, k, v, lengths, scale=None):
    """Single-token decode attention oracle: masked softmax over the cache.
    q: (B,H,hd); k/v: (B,S,H,hd); lengths: (B,)."""
    b, h, hd = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, :] < lengths.reshape(-1, 1, 1)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32))
