"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.quantize import QTensor, dequantize


def dequant_matmul_ref(x, q: QTensor):
    """y = x @ dequant(q) computed with the straightforward dense path."""
    w = dequantize(q, dtype=jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def stacked_gating_ref(x, gates):
    """logits[p] = x @ gates[p] via einsum."""
    return jnp.einsum(
        "bd,pde->pbe", x.astype(jnp.float32), gates.astype(jnp.float32),
        preferred_element_type=jnp.float32)


def flash_decode_ref(q, k, v, lengths, scale=None):
    """Single-token decode attention oracle: masked softmax over the cache.
    q: (B,H,hd); k/v: (B,S,H,hd); lengths: (B,)."""
    b, h, hd = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, :] < lengths.reshape(-1, 1, 1)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32))


def paged_flash_decode_ref(q, pages_k, pages_v, table, lengths, *,
                           scale=None, softcap: float = 0.0):
    """Paged decode-attention oracle: gather the slot-contiguous logical K/V
    view through the page table, then masked softmax (GQA heads expanded).
    q: (B,Hq,hd); pages_k/v: (P,psz,Hkv,hd); table: (B,maxp); lengths: (B,).
    Rows with length 0 return exact zeros (the fused kernel's contract)."""
    b, hq, hd = q.shape
    _, psz, hkv, _ = pages_k.shape
    maxp = table.shape[1]
    kg = pages_k[table].reshape(b, maxp * psz, hkv, hd)
    vg = pages_v[table].reshape(b, maxp * psz, hkv, hd)
    if hkv != hq:
        g = hq // hkv
        kg = jnp.repeat(kg, g, axis=2)
        vg = jnp.repeat(vg, g, axis=2)
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) * scale
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    mask = jnp.arange(maxp * psz)[None, None, :] < lengths.reshape(-1, 1, 1)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", w, vg.astype(jnp.float32))
    return jnp.where((lengths > 0).reshape(-1, 1, 1), out, 0.0)


def grouped_dequant_combine_ref(x, data, scale, rows, weights, *, bits: int,
                                group_size: int, num_rows: int):
    """Fused grouped dequant-GEMM + gated combine oracle: per-pair GEMM via
    dense dequantize + einsum, then a weighted scatter-add into the per-row
    output.  Pad pairs carry row == num_rows and are dropped by the scatter.
    x: (P,K); data: (P,K//pack,N); scale: (P,K//group,N); rows/weights: (P,)."""
    q = QTensor(data, scale, bits, group_size, x.shape[-1])
    w = dequantize(q, dtype=jnp.float32)                    # (P, K, N)
    y = jnp.einsum("pk,pkn->pn", x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)
    out = jnp.zeros((num_rows, y.shape[-1]), jnp.float32)
    return out.at[rows].add(weights.astype(jnp.float32)[:, None] * y,
                            mode="drop")


def gating_topk_ref(x, gates, *, top_k: int):
    """Fused gating oracle: stacked router matmul + softmax + top-k.
    Returns (logits (P,B,E) f32, vals (P,B,K) f32, idx (P,B,K) i32); ties
    resolve to the lowest expert index, matching the kernel's iterative
    argmax."""
    logits = stacked_gating_ref(x, gates)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, top_k)
    return logits, vals, idx.astype(jnp.int32)
