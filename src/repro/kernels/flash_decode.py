"""Flash-decode attention Pallas kernel: one query token per sequence
against a long KV cache, online-softmax over KV blocks.

Decode attention is the second memory-bound hot-spot of MoE serving (after
expert weights): the whole KV cache streams through the MXU once per token.
The flash formulation keeps one (block_s, head_dim) KV tile in VMEM at a
time and carries running max/denominator statistics, so the score vector is
never materialized in HBM — on TPU this bounds VMEM use to the tile size
and lets the DMA pipeline hide the HBM streaming.

Contract:
    q        (B, H, hd)        current-token queries (kv heads pre-expanded)
    k, v     (B, S, H, hd)     cache
    lengths  (B, 1)            #valid cache slots per sequence (<= S)
    out      o (B, H, hd) fp32, m (B, H, 1), l (B, H, 1)
Final output = o / l (done by the wrapper).

Grid (B, H, S/block_s); the (o, m, l) blocks are revisited across the S axis
and updated with the standard rescaling recurrence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                         *, block_s: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)               # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bs, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bs, hd)
    length = len_ref[0, 0]

    logits = (k @ q) * scale                              # (bs,)
    pos = s_idx * block_s + jax.lax.iota(jnp.int32, block_s)
    logits = jnp.where(pos < length, logits, NEG_INF)

    m_old = m_ref[0, 0, 0]
    m_new = jnp.maximum(m_old, jnp.max(logits))
    p = jnp.exp(logits - m_new)                           # (bs,)
    corr = jnp.exp(m_old - m_new)
    l_ref[0, 0, 0] = l_ref[0, 0, 0] * corr + jnp.sum(p)
    o_ref[0, 0, :] = o_ref[0, 0, :] * corr + p @ v
    m_ref[0, 0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_pallas(q, k, v, lengths, *, block_s: int = 256,
                        interpret: bool = False, scale: float | None = None):
    """Returns attention output (B, H, hd) fp32."""
    b, h, hd = q.shape
    s = k.shape[1]
    assert k.shape == (b, s, h, hd) and v.shape == k.shape
    assert s % block_s == 0, (s, block_s)
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    lengths2 = lengths.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_flash_decode_kernel, block_s=block_s,
                               scale=scale)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(b, h, s // block_s),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda bb, hh, ss: (bb, hh, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda bb, hh, ss: (bb, ss, hh, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda bb, hh, ss: (bb, ss, hh, 0)),
            pl.BlockSpec((1, 1), lambda bb, hh, ss: (bb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda bb, hh, ss: (bb, hh, 0)),
            pl.BlockSpec((1, 1, 1), lambda bb, hh, ss: (bb, hh, 0)),
            pl.BlockSpec((1, 1, 1), lambda bb, hh, ss: (bb, hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths2)
    return o / jnp.maximum(l, 1e-30)


def _paged_flash_decode_kernel(table_ref, len_ref, q_ref, k_ref, v_ref,
                               o_ref, m_ref, l_ref, *, page_size: int,
                               scale: float, softcap: float):
    bb = pl.program_id(0)
    pp = pl.program_id(2)

    @pl.when(pp == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)               # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (psz, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # (psz, hd)
    length = len_ref[bb]

    logits = (k @ q) * scale                              # (psz,)
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    # token t of a slot lives at LOGICAL page t // psz: mask by the logical
    # page index pp, not the physical page the table maps it to
    pos = pp * page_size + jax.lax.iota(jnp.int32, page_size)
    logits = jnp.where(pos < length, logits, NEG_INF)

    m_old = m_ref[0, 0, 0]
    m_new = jnp.maximum(m_old, jnp.max(logits))
    p = jnp.exp(logits - m_new)
    # explicit re-mask: when EVERY position seen so far is invalid (length
    # 0), m_new == NEG_INF and exp(NEG_INF - NEG_INF) would turn the mask
    # into uniform weights; zeroed p keeps l at 0 so the wrapper returns 0
    p = jnp.where(pos < length, p, 0.0)
    corr = jnp.exp(m_old - m_new)
    l_ref[0, 0, 0] = l_ref[0, 0, 0] * corr + jnp.sum(p)
    o_ref[0, 0, :] = o_ref[0, 0, :] * corr + p @ v
    m_ref[0, 0, 0] = m_new


@functools.partial(jax.jit,
                   static_argnames=("interpret", "scale", "softcap"))
def paged_flash_decode_pallas(q, pages_k, pages_v, table, lengths, *,
                              interpret: bool = False,
                              scale: float | None = None,
                              softcap: float = 0.0):
    """Flash decode straight out of a paged KV pool: the page table rides in
    as a scalar-prefetch operand and drives the K/V block index maps, so each
    grid step DMAs exactly one physical (psz, hd) page — the dense
    (B, maxp*psz) gathered cache view is never materialized.

        q        (B, Hq, hd)           current-token queries
        pages_k  (P, psz, Hkv, hd)     shared page pool (pages_v alike)
        table    (B, maxp) int         physical page id per logical page
        lengths  (B,) int              #valid cache tokens per slot

    GQA is handled in the index map (query head hh reads kv head hh // g) —
    no repeated K/V is ever built.  Returns (B, Hq, hd) f32; rows with
    length 0 return exact zeros."""
    b, hq, hd = q.shape
    num_pages, psz, hkv, hd2 = pages_k.shape
    assert hd2 == hd and pages_v.shape == pages_k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    maxp = table.shape[1]
    assert table.shape == (b, maxp)
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)

    kernel = functools.partial(_paged_flash_decode_kernel, page_size=psz,
                               scale=scale, softcap=softcap)
    kv_spec = pl.BlockSpec(
        (1, psz, 1, hd), lambda bb, hh, pp, tab, ln: (tab[bb, pp], 0, hh // g, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda bb, hh, pp, tab, ln: (bb, hh, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda bb, hh, pp, tab, ln: (bb, hh, 0)),
            pl.BlockSpec((1, 1, 1), lambda bb, hh, pp, tab, ln: (bb, hh, 0)),
            pl.BlockSpec((1, 1, 1), lambda bb, hh, pp, tab, ln: (bb, hh, 0)),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), q, pages_k, pages_v)
    return o / jnp.maximum(l, 1e-30)
