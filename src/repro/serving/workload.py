"""Workload generator + SLO scheduling policy helpers.

One deterministic (seeded) trace drives BOTH the live `BatchingServer` and
the virtual-clock `core.simulator.ServingTimeline`, so scheduling policies
are searched on the deterministic timeline and the winner serves real
traffic — the same live/simulated split the staging policies use.

A trace is a list of `WorkloadRequest`s with arrival offsets, drawn from a
mix of `RequestClass`es (per-class prompt/output length distributions,
priorities and SLOs).  Arrivals are bursty Poisson: a base rate with
periodic bursts multiplying it (`burst_factor` inside every
`burst_every_s`-long cycle's first `burst_len_s`).  Classes can opt into a
shared-prefix cohort (a common system prompt prepended to their prompts)
so the prefix-sharing radix cache has something to alias.

The policy helpers (`effective_priority`, `slo_urgency`) are the ONE
definition of SLO ordering used by both the live scheduler
(`serving/batching.py`) and the simulator timeline: effective priority is
the request's static priority plus an aging credit (one priority level per
`aging_s` seconds waited), which bounds starvation — any waiting request
eventually outranks any fixed priority.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

# one priority level earned per this many seconds of queue wait: the aging
# term that makes SLO ordering starvation-free (a priority-0 request waiting
# k*AGING_S seconds outranks a fresh priority-k request)
DEFAULT_AGING_S = 10.0


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One traffic class: lengths, priority, SLOs, mix weight."""
    name: str
    weight: float = 1.0                      # mix share (relative)
    priority: int = 0                        # static priority (higher wins)
    ttft_slo_s: Optional[float] = None       # submit -> first token target
    tpot_slo_s: Optional[float] = None       # per-output-token target
    prompt_tokens: Tuple[int, int] = (16, 64)   # uniform [lo, hi)
    new_tokens: Tuple[int, int] = (8, 32)       # uniform [lo, hi)
    shared_prefix: bool = False              # prepend the cohort system prompt


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    classes: Tuple[RequestClass, ...]
    num_requests: int = 32
    arrival_rate: float = 4.0                # mean requests/s outside bursts
    burst_factor: float = 4.0                # rate multiplier inside a burst
    burst_every_s: float = 8.0               # burst cycle period
    burst_len_s: float = 2.0                 # burst duration per cycle
    shared_prefix_tokens: int = 32           # cohort system-prompt length
    vocab: int = 128
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class WorkloadRequest:
    """One generated request: arrival offset + the Request fields."""
    rid: int
    arrival_s: float
    prompt: np.ndarray                       # int32 token ids
    max_new_tokens: int
    priority: int = 0
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None
    cls: str = ""


def generate_workload(cfg: WorkloadConfig) -> List[WorkloadRequest]:
    """Deterministic bursty-Poisson trace over the configured class mix."""
    rng = np.random.default_rng(cfg.seed)
    weights = np.array([c.weight for c in cfg.classes], dtype=np.float64)
    weights /= weights.sum()
    sys_prompt = rng.integers(0, cfg.vocab, cfg.shared_prefix_tokens)
    out: List[WorkloadRequest] = []
    t = 0.0
    for rid in range(cfg.num_requests):
        # thinned Poisson arrivals: the rate is arrival_rate, multiplied by
        # burst_factor inside each cycle's first burst_len_s
        in_burst = (t % cfg.burst_every_s) < cfg.burst_len_s
        rate = cfg.arrival_rate * (cfg.burst_factor if in_burst else 1.0)
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        c = cfg.classes[int(rng.choice(len(cfg.classes), p=weights))]
        plen = int(rng.integers(*c.prompt_tokens))
        body = rng.integers(0, cfg.vocab, plen)
        prompt = (np.concatenate([sys_prompt, body]) if c.shared_prefix
                  else body).astype(np.int32)
        out.append(WorkloadRequest(
            rid=rid, arrival_s=t, prompt=prompt,
            max_new_tokens=int(rng.integers(*c.new_tokens)),
            priority=c.priority, ttft_slo_s=c.ttft_slo_s,
            tpot_slo_s=c.tpot_slo_s, cls=c.name))
    return out


def to_requests(trace: Sequence[WorkloadRequest], *, t0: float = 0.0):
    """Convert a trace into live `serving.batching.Request`s (submitted_at
    pre-set to t0 + arrival offset; `BatchingServer.submit` honors it)."""
    from repro.serving.batching import Request
    return [Request(rid=w.rid, prompt=w.prompt,
                    max_new_tokens=w.max_new_tokens,
                    submitted_at=t0 + w.arrival_s, priority=w.priority,
                    ttft_slo_s=w.ttft_slo_s, tpot_slo_s=w.tpot_slo_s)
            for w in trace]


# ----------------------------------------------------------------------
# SLO ordering policy (shared by BatchingServer and ServingTimeline)

# owner: main-thread — SLO ordering runs inside the scheduler step (live
# server and virtual-clock timeline both call it from the admitting thread)
def effective_priority(priority: int, submitted_at: float, now: float,
                       aging_s: float = DEFAULT_AGING_S) -> float:
    """Static priority + aging credit (1 level per `aging_s` waited).

    The aging term is the starvation bound: a request of priority p0 that
    has waited `(p1 - p0 + m) * aging_s` outranks any fresh priority-p1
    request by margin m, so no fixed priority can hold it back forever.
    """
    return float(priority) + max(0.0, now - submitted_at) / aging_s


# owner: main-thread
def slo_urgency(priority: int, submitted_at: float,
                ttft_slo_s: Optional[float], now: float,
                aging_s: float = DEFAULT_AGING_S) -> Tuple[float, float]:
    """Sort key for admission: most urgent first under ascending sort.

    Primary: -effective_priority (higher effective priority first).
    Secondary: TTFT deadline slack (requests closest to — or furthest
    past — their deadline first; no-SLO requests order by age).
    """
    slack = ((submitted_at + ttft_slo_s - now) if ttft_slo_s is not None
             else 1e12 + submitted_at - now)  # no deadline: after SLO peers,
    #                                           oldest first among themselves
    return (-effective_priority(priority, submitted_at, now, aging_s), slack)
