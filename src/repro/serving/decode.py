"""Serving substrate: prefill + decode step builders and a generate loop.

`make_serve_step` is what the decode-shape dry-runs lower: one new token
against a KV cache of length seq_len.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Batch, Model


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, cache, tokens (B,1), positions (B,)) ->
    (logits (B,V), new_cache)."""

    def serve_step(params, cache, tokens, positions):
        return model.decode_step(params, cache, tokens, positions)

    return serve_step


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill_step(params, batch: Batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def sample_token(logits, key, temperature: float = 0.0, top_k: int = 0):
    """logits (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(lg, top_k)
        lg = jnp.where(lg < vals[:, -1:], -1e30, lg)
    return jax.random.categorical(key, lg).astype(jnp.int32)


class GenerateResult(NamedTuple):
    tokens: np.ndarray          # (B, prompt+new)
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def decode_tok_s(self) -> float:
        return self.tokens.shape[0] * self.steps / max(self.decode_s, 1e-9)


def generate(model: Model, params, prompts: jnp.ndarray, new_tokens: int, *,
             max_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0, extra: Optional[dict] = None,
             jit: bool = True) -> GenerateResult:
    """Greedy/temperature generation with a jitted decode step."""
    import time

    b, s = prompts.shape
    max_len = max_len or (s + new_tokens + 1)
    extra = extra or {}
    batch = Batch(tokens=prompts, loss_mask=jnp.ones(prompts.shape), **extra)

    prefill = make_prefill_step(model, max_len)
    step = make_serve_step(model)
    if jit:
        prefill = jax.jit(prefill)
        step = jax.jit(step, donate_argnums=1)

    t0 = time.time()
    logits, cache, positions = prefill(params, batch)
    logits.block_until_ready()
    t1 = time.time()

    key = jax.random.PRNGKey(seed)
    out = [np.asarray(prompts)]
    tok = sample_token(logits, key, temperature)
    for i in range(new_tokens):
        out.append(np.asarray(tok)[:, None])
        if i == new_tokens - 1:
            break
        key, sub = jax.random.split(key)
        logits, cache = step(params, cache, tok[:, None], positions)
        positions = positions + 1
        tok = sample_token(logits, sub, temperature)
    t2 = time.time()
    return GenerateResult(np.concatenate(out, axis=1), t1 - t0, t2 - t1, new_tokens)
