"""Continuous batching for the serving path, backend-agnostic.

The scheduler owns `max_batch` slots on an `InferenceBackend` (dense or
HOBBIT-offload — identical code path).  Requests queue FIFO; a request is
admitted into any free slot via `backend.join` (its own prefill), decodes
together with whatever else is in flight, and on completion `release`s the
slot so the next queued request joins at the very next step — no bucketing
by prompt length and no waiting for batch-mates to finish.

Per-request latency is split into queue wait / prefill / decode so the
reported `decode_tok_s` measures decode steps only (queue wait and prefill
are reported separately).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.api import DenseBackend, InferenceBackend
from repro.serving.decode import sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,)
    max_new_tokens: int
    submitted_at: float = 0.0
    # filled on completion:
    output: Optional[np.ndarray] = None
    queue_wait_s: float = 0.0       # submit -> admission into a slot
    prefill_latency_s: float = 0.0  # this request's own prefill (join) time
    decode_s: float = 0.0           # wall time of decode steps it rode in
    load_stall_s: float = 0.0       # share of expert-load stall in its steps
    total_latency_s: float = 0.0


class BatchingServer:
    """Slot-based continuous batching over any `InferenceBackend`.

    Accepts either a backend, or `(model, params)` for the common dense case
    (kept for backwards compatibility with the original server)."""

    def __init__(self, backend_or_model, params=None, *, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0):
        if isinstance(backend_or_model, Model):
            backend: InferenceBackend = DenseBackend(backend_or_model, params)
        else:
            backend = backend_or_model
        self.backend = backend
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        # scheduler event log: (event, slot, rid, step_index) — lets tests
        # and operators confirm mid-flight admissions/retirements
        self.events: List[Tuple[str, int, int, int]] = []
        self._step_time_s = 0.0
        self._step_tokens = 0

    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _sample(self, logits) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        return np.asarray(sample_token(jnp.asarray(logits), sub,
                                       self.temperature))

    def run(self):
        """Serve until queue and in-flight slots are drained."""
        if not self.queue:
            return
        self.backend.start_batch(self.max_batch, self.max_len)
        free = list(range(self.max_batch))
        for slot in free:           # slots are inactive until a request joins
            self.backend.release(slot)
        active: Dict[int, Request] = {}
        outs: Dict[int, List[int]] = {}
        pending_tok: Dict[int, int] = {}
        step_idx = 0
        last_stall = self.backend.stats().get("load_stall_s", 0.0)

        def retire(slot: int):
            req = active.pop(slot)
            req.output = np.asarray(outs.pop(slot), np.int32)
            req.total_latency_s = time.time() - req.submitted_at
            pending_tok.pop(slot, None)
            self.backend.release(slot)
            self.completed.append(req)
            self.events.append(("retire", slot, req.rid, step_idx))
            free.append(slot)

        while self.queue or active:
            # finished requests free their slots before the next step
            for slot in [s for s, r in active.items()
                         if len(outs[s]) >= r.max_new_tokens]:
                retire(slot)
            # admission: queued requests take any free slot mid-flight
            while free and self.queue:
                slot, req = free.pop(0), self.queue.pop(0)
                t0 = time.time()
                logits = self.backend.join(
                    slot, np.asarray(req.prompt, np.int32))
                t1 = time.time()
                req.queue_wait_s = t0 - req.submitted_at
                req.prefill_latency_s = t1 - t0
                tok = int(self._sample(logits[None])[0])
                active[slot] = req
                outs[slot] = [tok][: req.max_new_tokens]
                pending_tok[slot] = tok
                self.events.append(("join", slot, req.rid, step_idx))
            stepping = [s for s, r in active.items()
                        if len(outs[s]) < r.max_new_tokens]
            if not stepping:
                continue
            tokens = np.zeros((self.max_batch,), np.int32)
            for slot in stepping:
                tokens[slot] = pending_tok[slot]
            t0 = time.time()
            logits = self.backend.step(tokens)
            dt = time.time() - t0
            # expert-load stall accrued this step, split across the requests
            # that rode in it (offload backends only; dense reports 0)
            now_stall = self.backend.stats().get("load_stall_s", 0.0)
            stall = (now_stall - last_stall) / len(stepping)
            last_stall = now_stall
            nxt = self._sample(logits)
            for slot in stepping:
                active[slot].decode_s += dt
                active[slot].load_stall_s += stall
                outs[slot].append(int(nxt[slot]))
                pending_tok[slot] = int(nxt[slot])
            self._step_time_s += dt
            self._step_tokens += len(stepping)
            step_idx += 1

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        if not self.completed:
            return {}
        done = self.completed
        backend_stats = self.backend.stats()
        return {
            "requests": len(done),
            "mean_queue_wait_s": float(np.mean([r.queue_wait_s for r in done])),
            "mean_prefill_s": float(np.mean([r.prefill_latency_s for r in done])),
            "mean_decode_s": float(np.mean([r.decode_s for r in done])),
            "mean_load_stall_s": float(np.mean([r.load_stall_s for r in done])),
            "mean_total_s": float(np.mean([r.total_latency_s for r in done])),
            # decode throughput over decode-step wall time only (queue wait
            # and prefill are reported separately above)
            "decode_tok_s": self._step_tokens / max(self._step_time_s, 1e-9),
            "overlap_fraction": backend_stats.get("overlap_fraction", 0.0),
            "backend": backend_stats,
        }
