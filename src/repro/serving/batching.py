"""Request batching for the serving path.

Requests are bucketed by exact prompt length (the paper's workload uses
fixed prompt lengths of 16 / 128) and served as fixed batches; per-request
latency statistics are tracked.  Decode supports per-slot positions, so
mixed-completion-length batches finish independently (a slot's output is
truncated at its own max_new_tokens).
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Batch, Model
from repro.serving.decode import make_prefill_step, make_serve_step, sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,)
    max_new_tokens: int
    submitted_at: float = 0.0
    # filled on completion:
    output: Optional[np.ndarray] = None
    prefill_latency_s: float = 0.0
    total_latency_s: float = 0.0


class BatchingServer:
    """Bucket-by-length static batching with a jitted decode step per shape."""

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: Dict[int, List[Request]] = defaultdict(list)
        self._prefill = jax.jit(make_prefill_step(model, max_len))
        self._step = jax.jit(make_serve_step(model), donate_argnums=1)
        self.completed: List[Request] = []

    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue[len(req.prompt)].append(req)

    def _serve_batch(self, reqs: List[Request]):
        b = len(reqs)
        prompts = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = Batch(tokens=prompts, loss_mask=jnp.ones(prompts.shape))
        t0 = time.time()
        logits, cache, positions = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        steps = max(r.max_new_tokens for r in reqs)
        outs = [[] for _ in range(b)]
        self.key, sub = jax.random.split(self.key)
        tok = sample_token(logits, sub, self.temperature)
        for i in range(steps):
            for j in range(b):
                if i < reqs[j].max_new_tokens:
                    outs[j].append(int(tok[j]))
            if i == steps - 1:
                break
            self.key, sub = jax.random.split(self.key)
            logits, cache = self._step(self.params, cache, tok[:, None], positions)
            positions = positions + 1
            tok = sample_token(logits, sub, self.temperature)
        done = time.time()
        for j, r in enumerate(reqs):
            r.output = np.asarray(outs[j], np.int32)
            r.prefill_latency_s = t_prefill
            r.total_latency_s = done - r.submitted_at
            self.completed.append(r)

    def run(self):
        """Drain the queue, largest buckets first."""
        for length in sorted(self.queue, key=lambda k: -len(self.queue[k])):
            reqs = self.queue[length]
            while reqs:
                chunk, self.queue[length] = reqs[: self.max_batch], reqs[self.max_batch:]
                reqs = self.queue[length]
                self._serve_batch(chunk)

    def stats(self) -> dict:
        if not self.completed:
            return {}
        tot_new = sum(len(r.output) for r in self.completed)
        tot_decode = sum(r.total_latency_s - r.prefill_latency_s for r in self.completed)
        return {
            "requests": len(self.completed),
            "mean_prefill_s": float(np.mean([r.prefill_latency_s for r in self.completed])),
            "mean_total_s": float(np.mean([r.total_latency_s for r in self.completed])),
            "decode_tok_s": tot_new / max(tot_decode, 1e-9),
        }
