"""Continuous batching for the serving path, backend-agnostic.

The scheduler owns `max_batch` slots on an `InferenceBackend` (dense or
HOBBIT-offload — identical code path).  Admission is SLO-aware by default
(`policy="slo"`): the queue is ordered by `serving.workload.slo_urgency`
(effective priority = static priority + aging credit, tie-broken by TTFT
deadline slack), which degrades to FIFO when requests carry no
priority/SLO metadata; `policy="fifo"` forces strict arrival order.  When
the most urgent queued request cannot be admitted (no free slot or no KV
headroom) and it outranks the least urgent decoding request by more than
`preempt_margin` effective-priority levels, the scheduler *preempts*: the
victim's KV state is snapshotted to host (`backend.pause`), its slot and
pages are freed, and it is requeued with its decode progress intact — it
resumes later via `backend.resume` without re-prefilling.  Aging bounds
starvation (any waiting request eventually outranks any fixed priority).

Admission is *chunked and batched*: up to `admit_k` queued requests are in
admission concurrently, and one `backend.join_step()` call per scheduler
iteration advances ALL of them by one prefill chunk (one shared jitted
call on paged backends) before the next decode step runs — so a long
prompt prefills in fixed-size chunks interleaved with decode steps and
never stalls in-flight decodes.  On completion a request `release`s its
slot (returning its KV pages to the pool on paged backends) and the next
queued request joins at the very next step — no bucketing by prompt length
and no waiting for batch-mates to finish.

Admission is KV-aware: a request is only admitted when
`backend.can_admit(prompt + max_new_tokens + 1)` says the pool can hold its
*whole* lifetime (the backend reserves that budget at `join_begin`), so a
paged pool can never starve an in-flight decode; when the pool is full the
request simply waits in the queue for a retirement to free pages — that
wait is reported as `admission_wait_s`.

Per-request latency is split into queue wait / prefill / decode so the
reported `decode_tok_s` measures decode steps only (queue wait and prefill
are reported separately).  See docs/METRICS.md for every stats() field.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.kv_pages import PagePoolExhausted
from repro.models.model import Model
from repro.serving.api import DenseBackend, InferenceBackend
from repro.serving.decode import sample_token
from repro.serving.workload import (DEFAULT_AGING_S, effective_priority,
                                    slo_urgency)


@dataclasses.dataclass
class Request:
    """One generation request and, after completion, its latency breakdown."""
    rid: int
    prompt: np.ndarray              # (S,)
    max_new_tokens: int
    submitted_at: float = 0.0
    # SLO metadata (all optional — a metadata-free request behaves FIFO):
    priority: int = 0               # static class priority (higher wins)
    ttft_slo_s: Optional[float] = None   # submit -> first token target
    tpot_slo_s: Optional[float] = None   # per-output-token decode target
    # filled on completion:
    output: Optional[np.ndarray] = None
    queue_wait_s: float = 0.0       # submit -> admission started (slot+KV)
    admission_wait_s: float = 0.0   # submit -> prefill complete (first token)
    prefill_latency_s: float = 0.0  # this request's own (chunked) prefill
    decode_s: float = 0.0           # wall time of decode steps it rode in
    load_stall_s: float = 0.0       # share of expert-load stall in its steps
    precision_downgrades: float = 0.0   # share of issue-time hi->lo downgrades
    served_lo: float = 0.0          # share of lo-for-hi expert-steps in its
    #                                 steps (accuracy-exposure proxy; decays
    #                                 to 0 once upgrades land hi re-copies)
    total_latency_s: float = 0.0


class BatchingServer:
    """Slot-based continuous batching over any `InferenceBackend`.

    `admit_k` bounds how many requests prefill concurrently (they share one
    jitted chunk call per iteration on paged backends).  Accepts either a
    backend, or `(model, params)` for the common dense case (kept for
    backwards compatibility with the original server)."""

    def __init__(self, backend_or_model, params=None, *, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0,
                 admit_k: int = 4, policy: str = "slo",
                 aging_s: float = DEFAULT_AGING_S,
                 preempt_margin: float = 1.0):
        """policy: "slo" (urgency-ordered admission + preemption; degrades
        to FIFO when requests carry no priority/SLO metadata) or "fifo"
        (strict arrival order, never preempts).  aging_s / preempt_margin
        parameterize `serving.workload.effective_priority`."""
        if isinstance(backend_or_model, Model):
            backend: InferenceBackend = DenseBackend(backend_or_model, params)
        else:
            backend = backend_or_model
        assert policy in ("slo", "fifo"), policy
        self.backend = backend
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.admit_k = admit_k
        self.policy = policy
        self.aging_s = float(aging_s)
        self.preempt_margin = float(preempt_margin)
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        # scheduler event log: (event, slot, rid, step_index) — lets tests
        # and operators confirm mid-flight admissions/retirements ("admit" =
        # chunked prefill started, "join" = prefill complete, slot decoding;
        # "preempt"/"resume" bracket a pause/resume preemption)
        self.events: List[Tuple[str, int, int, int]] = []
        self.preemptions = 0
        self._step_time_s = 0.0
        self._step_tokens = 0
        self._occupancy_sum = 0         # Σ per-step live slots (decode+admit)
        self._steps = 0
        self._closed = False
        self._last_backend_stats: Optional[dict] = None

    def submit(self, req: Request):
        """Queue a request.  A pre-set `submitted_at` (a workload trace's
        arrival offset) is honored; 0.0 means "now"."""
        if req.submitted_at == 0.0:
            req.submitted_at = time.time()
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _sample(self, logits) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        return np.asarray(sample_token(jnp.asarray(logits), sub,
                                       self.temperature))

    def run(self):
        """Serve until queue, admissions and in-flight slots are drained."""
        if not self.queue:
            return
        self.backend.start_batch(self.max_batch, self.max_len)
        free = list(range(self.max_batch))
        for slot in free:           # slots are inactive until a request joins
            self.backend.release(slot)
        active: Dict[int, Request] = {}
        admitting: Dict[int, Request] = {}
        admit_t0: Dict[int, float] = {}
        outs: Dict[int, List[int]] = {}
        pending_tok: Dict[int, int] = {}
        step_idx = 0
        stats0 = self.backend.stats()
        last_stall = stats0.get("load_stall_s", 0.0)
        last_downgrades = stats0.get("precision_downgrades", 0)
        last_served_lo = stats0.get("served_lo_expert_steps", 0)

        def retire(slot: int):
            req = active.pop(slot)
            req.output = np.asarray(outs.pop(slot), np.int32)
            req.total_latency_s = time.time() - req.submitted_at
            pending_tok.pop(slot, None)
            self.backend.release(slot)
            self.completed.append(req)
            self.events.append(("retire", slot, req.rid, step_idx))
            free.append(slot)

        def order_queue():
            """SLO policy: most urgent first (degenerates to FIFO without
            priority/SLO metadata — urgency then orders purely by age)."""
            if self.policy == "slo" and len(self.queue) > 1:
                now = time.time()
                self.queue.sort(key=lambda r: slo_urgency(
                    r.priority, r.submitted_at, r.ttft_slo_s, now,
                    self.aging_s))

        def try_preempt() -> bool:
            """Preempt-and-requeue: pause the least-urgent decoding victim
            when the most urgent queued request cannot be admitted and
            outranks it by more than preempt_margin effective-priority
            levels.  Returns True when a slot+pages were freed."""
            if self.policy != "slo" or not self.queue or not active:
                return False
            req = self.queue[0]
            now = time.time()
            eff = lambda r: effective_priority(  # noqa: E731
                r.priority, r.submitted_at, now, self.aging_s)
            victim_slot = min(active, key=lambda s: eff(active[s]))
            vreq = active[victim_slot]
            if eff(vreq) + self.preempt_margin >= eff(req):
                return False
            snap = self.backend.pause(victim_slot)
            active.pop(victim_slot)
            vreq._paused = {"snapshot": snap,           # type: ignore[attr-defined]
                            "outs": outs.pop(victim_slot),
                            "pending_tok": pending_tok.pop(victim_slot)}
            self.queue.append(vreq)
            free.append(victim_slot)
            self.preemptions += 1
            self.events.append(("preempt", victim_slot, vreq.rid, step_idx))
            return True

        while self.queue or active or admitting:
            # finished requests free their slots before the next step
            for slot in [s for s, r in active.items()
                         if len(outs[s]) >= r.max_new_tokens]:
                retire(slot)
            # admission: up to admit_k queued requests prefill concurrently,
            # each gated on KV capacity for its whole lifetime.  At most one
            # preemption per scheduler iteration keeps the pause path from
            # thrashing the batch.
            order_queue()
            if self.queue and len(admitting) < self.admit_k:
                req = self.queue[0]
                need = len(req.prompt) + req.max_new_tokens + 1
                blocked = not free or not self.backend.can_admit(
                    need, prompt=None if getattr(req, "_paused", None)
                    else req.prompt)
                if blocked:
                    try_preempt()       # at most one pause per iteration
            while free and self.queue and len(admitting) < self.admit_k:
                req = self.queue[0]
                need = len(req.prompt) + req.max_new_tokens + 1
                paused = getattr(req, "_paused", None)
                # the prompt rides along so paged backends can price the
                # request net of prefix sharing (aliased prefix = free);
                # a resuming request restores private pages, so no prompt
                if not self.backend.can_admit(
                        need, prompt=None if paused else req.prompt):
                    if not active and not admitting:
                        # nothing in flight can ever free capacity for it
                        raise RuntimeError(
                            f"request rid={req.rid} needs {need} KV tokens "
                            "but the drained pool cannot hold it; grow "
                            "kv_pages / max_len or shrink the request")
                    break               # wait for a retirement to free pages
                self.queue.pop(0)
                slot = free.pop(0)
                t0 = time.time()
                if paused is not None:
                    # resume a preempted victim: KV restored from its host
                    # snapshot, decode continues where it left off.  The
                    # snapshot may need a few more pages than can_admit
                    # priced (aliased prefix pages were copied out private),
                    # so a failed restore just requeues the victim.
                    try:
                        self.backend.resume(slot, paused["snapshot"])
                    except PagePoolExhausted:
                        self.queue.insert(0, req)
                        free.insert(0, slot)
                        break           # wait for a retirement to free pages
                    req._paused = None  # type: ignore[attr-defined]
                    active[slot] = req
                    outs[slot] = paused["outs"]
                    pending_tok[slot] = paused["pending_tok"]
                    self.events.append(("resume", slot, req.rid, step_idx))
                    continue
                req.queue_wait_s = t0 - req.submitted_at
                self.backend.join_begin(slot, np.asarray(req.prompt, np.int32),
                                        reserve_tokens=need)
                admitting[slot] = req
                admit_t0[slot] = t0
                self.events.append(("admit", slot, req.rid, step_idx))
            # one shared call advances every in-progress admission one chunk
            if admitting:
                done = self.backend.join_step()
                now = time.time()
                for slot, logits in done.items():
                    req = admitting.pop(slot)
                    req.prefill_latency_s = now - admit_t0.pop(slot)
                    req.admission_wait_s = now - req.submitted_at
                    tok = int(self._sample(logits[None])[0])
                    active[slot] = req
                    outs[slot] = [tok][: req.max_new_tokens]
                    pending_tok[slot] = tok
                    self.events.append(("join", slot, req.rid, step_idx))
            stepping = [s for s, r in active.items()
                        if len(outs[s]) < r.max_new_tokens]
            if not stepping:
                continue
            tokens = np.zeros((self.max_batch,), np.int32)
            for slot in stepping:
                tokens[slot] = pending_tok[slot]
            t0 = time.time()
            logits = self.backend.step(tokens)
            dt = time.time() - t0
            # expert-load stall and issue-time precision downgrades accrued
            # this step, split across the requests that rode in it (offload
            # backends only; dense reports 0)
            step_stats = self.backend.stats()
            now_stall = step_stats.get("load_stall_s", 0.0)
            stall = (now_stall - last_stall) / len(stepping)
            last_stall = now_stall
            now_dg = step_stats.get("precision_downgrades", 0)
            downgrades = (now_dg - last_downgrades) / len(stepping)
            last_downgrades = now_dg
            now_sl = step_stats.get("served_lo_expert_steps", 0)
            served_lo = (now_sl - last_served_lo) / len(stepping)
            last_served_lo = now_sl
            nxt = self._sample(logits)
            for slot in stepping:
                active[slot].decode_s += dt
                active[slot].load_stall_s += stall
                active[slot].precision_downgrades += downgrades
                active[slot].served_lo += served_lo
                outs[slot].append(int(nxt[slot]))
                pending_tok[slot] = int(nxt[slot])
            self._step_time_s += dt
            self._step_tokens += len(stepping)
            self._occupancy_sum += len(stepping) + len(admitting)
            self._steps += 1
            step_idx += 1

    # ------------------------------------------------------------------
    def close(self):
        """Scheduler teardown: snapshot the backend's final stats (so
        `stats()` keeps working after close instead of raising on a closed
        backend), then close the backend so offload backends always release
        their staging worker threads.  Idempotent (backend close is); a
        closed server must not be run() again."""
        if not self._closed:
            try:
                self._last_backend_stats = self.backend.stats()
            except Exception:
                self._last_backend_stats = self._last_backend_stats or {}
            self._closed = True
        close = getattr(self.backend, "close", None)
        if close is not None:
            close()

    def __enter__(self):
        """Context-manager support: `with BatchingServer(...) as srv:`."""
        return self

    def __exit__(self, *exc):
        """Always close the backend on scope exit, error or not."""
        self.close()
        return False

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate serving metrics over completed requests (see
        docs/METRICS.md for the full glossary)."""
        if not self.completed:
            return {}
        done = self.completed
        # after close() the backend's staging threads are gone: serve the
        # snapshot taken at close instead of raising (regression: PR 9)
        backend_stats = (self._last_backend_stats if self._closed
                         else self.backend.stats()) or {}
        declared = [r for r in done
                    if r.ttft_slo_s is not None or r.tpot_slo_s is not None]

        def met(r: Request) -> bool:
            ok = True
            if r.ttft_slo_s is not None:
                ok = r.admission_wait_s <= r.ttft_slo_s
            if ok and r.tpot_slo_s is not None and r.output is not None \
                    and len(r.output) > 1:
                ok = r.decode_s / (len(r.output) - 1) <= r.tpot_slo_s
            return ok

        return {
            "requests": len(done),
            "mean_queue_wait_s": float(np.mean([r.queue_wait_s for r in done])),
            "admission_wait_s": float(np.mean([r.admission_wait_s
                                               for r in done])),
            "mean_prefill_s": float(np.mean([r.prefill_latency_s for r in done])),
            "mean_decode_s": float(np.mean([r.decode_s for r in done])),
            "mean_load_stall_s": float(np.mean([r.load_stall_s for r in done])),
            # issue-time hi->lo downgrades attributed to the requests that
            # rode in the steps where the staging engine made them
            "mean_precision_downgrades": float(np.mean(
                [r.precision_downgrades for r in done])),
            # lo-for-hi expert-steps attributed to the requests that rode in
            # them: each request's accuracy exposure to downgrade
            # substitution (decays toward 0 while idle-link upgrades land)
            "mean_served_lo": float(np.mean([r.served_lo for r in done])),
            "precision_downgrades": backend_stats.get(
                "precision_downgrades", 0),
            "issue_reorders": backend_stats.get("issue_reorders", 0),
            "upgrades": backend_stats.get("upgrades", 0),
            "upgrade_bytes": backend_stats.get("upgrade_bytes", 0),
            "served_lo_expert_steps": backend_stats.get(
                "served_lo_expert_steps", 0),
            "link_utilization": backend_stats.get("link_utilization", 0.0),
            "mean_total_s": float(np.mean([r.total_latency_s for r in done])),
            # SLO scheduling outcomes: attainment over requests declaring a
            # TTFT/TPOT target (1.0 when none do), tail first-token latency,
            # and pause/resume preemptions fired by the SLO policy
            "slo_attainment": ((sum(met(r) for r in declared) / len(declared))
                               if declared else 1.0),
            "p99_ttft_s": float(np.percentile(
                [r.admission_wait_s for r in done], 99)),
            "preemptions": self.preemptions,
            # decode throughput over decode-step wall time only (queue wait
            # and prefill are reported separately above)
            "decode_tok_s": self._step_tokens / max(self._step_time_s, 1e-9),
            # mean live slots per decode step (decoding + admitting): the
            # paged-vs-dense occupancy metric of benchmarks/decode_speedup
            "mean_occupancy": (self._occupancy_sum / self._steps
                               if self._steps else 0.0),
            "overlap_fraction": backend_stats.get("overlap_fraction", 0.0),
            "kv_page_fraction": backend_stats.get("kv_page_fraction", 0.0),
            "backend": backend_stats,
        }
