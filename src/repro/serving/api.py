"""Unified serving API: one `InferenceBackend` protocol in front of both the
dense (resident-weights) decode path and the HOBBIT mixed-precision expert
offloading engine, so schedulers, launchers, examples and benchmarks drive a
single interface regardless of where the experts live.

The protocol is slot-oriented to support *continuous batching*
(`serving.batching.BatchingServer`): a backend holds `batch` KV-cache slots,
a finished request `release()`s its slot mid-flight, and a queued request
`join()`s the freed slot at the next step without disturbing its neighbours.

    backend methods
    ---------------
    start_batch(batch, max_len)      allocate B slots (all marked active)
    prefill(prompts (B,S)) -> (B,V)  full-batch prefill, last-token logits
    join(slot, prompt (S,)) -> (V,)  admit one request into a slot mid-flight
    join_begin(slot, prompt, ...)    start an *incremental* admission
    join_step() -> {slot: (V,)}      advance all admissions by one chunk
    can_admit(tokens, *, prompt)     does KV capacity exist for a request?
                                     (with `prompt`: net of prefix sharing)
    pause(slot) -> snapshot          preempt a slot mid-decode: snapshot its
                                     KV to host and release the slot
    resume(slot, snapshot)           re-admit a paused request from snapshot
    release(slot)                    free a slot (and its KV pages)
    step(tokens (B,)) -> (B,V)       one decode step for the whole batch
    stats() -> dict                  backend-specific counters

Backends are constructed through ``make_backend(BackendConfig(...))`` —
one typed config instead of the historical kwarg sprawl (the old
``make_backend(kind, ..., paged=..., page_size=...)`` form still works for
one release behind a DeprecationWarning).

KV memory comes in two layouts, selected per backend at construction:

  * dense (default): ``start_batch`` allocates a (B, max_len) cache up
    front — simple, but one long request inflates every slot.
  * paged (``paged=True`` / ``EngineConfig(paged_kv=True)``): a fixed
    device-resident page pool (`repro.models.kv_pages.PagedKVPool`,
    ~64-token pages) with per-slot page tables; a slot's memory grows with
    its actual length, ``release`` returns its pages to the pool, and
    admission reserves a request's full budget so decode never starves.
    Prompts are prefilled in fixed-size chunks (``join_begin``/``join_step``)
    that the scheduler interleaves with decode steps.

Usage::

    from repro.serving.api import DenseBackend, HobbitBackend, generate
    from repro.core import EngineConfig, OffloadEngine

    backend = DenseBackend(model, params)                  # resident weights
    res = generate(backend, prompts, new_tokens=32)        # same helper...

    eng = OffloadEngine(model, params, EngineConfig(hi_slots=16, lo_slots=8))
    res = generate(HobbitBackend(eng), prompts, 32)        # ...either way

`generate` / `score_nll` here are thin helpers over the protocol; the
continuous-batching scheduler lives in `serving.batching`.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.kv_pages import ChunkedPrefill, PagedKVPool
from repro.models.model import Batch, Model, supports_paged_kv
from repro.serving.decode import (GenerateResult, make_prefill_step,
                                  sample_token)


@runtime_checkable
class InferenceBackend(Protocol):
    """Slot-oriented decode interface served by the continuous scheduler."""

    model: Model

    def start_batch(self, batch: int, max_len: int) -> None:
        """Allocate `batch` KV slots able to reach `max_len` tokens each
        (dense: up-front per-slot buffers; paged: a shared page pool)."""
        ...

    def prefill(self, prompts: np.ndarray) -> np.ndarray:
        """Full-batch prefill of (B, S) prompts; returns last-token logits
        (B, V) and marks every slot active."""
        ...

    def join(self, slot: int, prompt: np.ndarray) -> np.ndarray:
        """Admit one request into a free slot mid-flight (blocking: runs the
        whole prompt).  Returns last-token logits (V,)."""
        ...

    def join_begin(self, slot: int, prompt: np.ndarray,
                   reserve_tokens: Optional[int] = None) -> None:
        """Start an incremental admission into `slot`, reserving
        `reserve_tokens` of KV capacity (prompt + decode budget) so the
        request can never hit pool exhaustion mid-decode."""
        ...

    def join_step(self) -> dict:
        """Advance every in-progress admission by one prefill chunk (one
        shared jitted call where the backend supports it).  Returns
        {slot: last-token logits (V,)} for admissions that completed."""
        ...

    def can_admit(self, tokens: int, *, prompt=None) -> bool:
        """True iff KV capacity for a request of `tokens` total length is
        available right now (dense backends: always).  `prompt` (the token
        ids about to be admitted, keyword-only) lets paged backends price
        the request net of prefix sharing: a prompt whose prefix aliases
        already-resident pages only needs pages for its unshared suffix."""
        ...

    def pause(self, slot: int) -> dict:
        """Preempt an *active* slot mid-decode: snapshot its KV state to
        host (paged backends gather the slot's written pages through its
        table; pages aliased by other slots keep those sharers' refcounts),
        release the slot, and return an opaque snapshot for `resume`.  The
        scheduler uses this to evict a low-priority victim so a more urgent
        request can take its slot/pages."""
        ...

    def resume(self, slot: int, snapshot: dict) -> None:
        """Re-admit a paused request — possibly into a different slot —
        from its `pause` snapshot: restore KV content and position, then
        mark the slot active.  Decode after resume is logits-identical to
        the unpreempted run."""
        ...

    def release(self, slot: int) -> None:
        """Free a slot: its rows become junk until the next join, and any
        KV pages it held return to the pool."""
        ...

    def step(self, tokens: np.ndarray) -> np.ndarray:
        """One decode step for the whole batch ((B,) tokens -> (B, V)
        logits); inactive slots ride along but are not advanced."""
        ...

    def stats(self) -> dict:
        """JSON-serializable backend counters (uniform keys: load_stall_s,
        overlap_fraction, precision_downgrades, issue_reorders,
        link_utilization, kv_pages_used, kv_page_fraction, ...)."""
        ...

    def close(self) -> None:
        """Release backend resources (offload backends: staging worker
        threads).  Idempotent; serving entry points raise RuntimeError after
        close instead of failing deep inside an executor."""
        ...


# --------------------------------------------------------------------------
# shared protocol plumbing
# --------------------------------------------------------------------------

def _blocking_join(backend, slot: int, prompt) -> np.ndarray:
    """THE blocking-join implementation.

    Both backends' `join` (and the engine's) are documented thin wrappers
    over this loop: begin an incremental admission, then drive `join_step`
    until `slot` completes.  Other pending admissions advance alongside;
    their finished logits are stashed on the backend (`_unclaimed_joins`)
    and stay claimable by the next `join_step` call."""
    backend.join_begin(slot, np.asarray(prompt, np.int32).reshape(-1))
    while True:
        done = backend.join_step()
        lg = done.pop(slot, None)
        backend._unclaimed_joins.update(done)
        if lg is not None:
            return lg


# --------------------------------------------------------------------------
# dense (resident-weights) backend
# --------------------------------------------------------------------------

def _scatter_slot(dst_cache, src_cache, slot: int):
    """Write a batch=1 prefill cache into row `slot` of a batched cache.

    The nested decode-cache layout puts the batch axis at 0 for prefix/tail
    entries, 1 for scanned-block entries (stacked (num_blocks, B, ...)), and
    2 for the whisper enc_kv buffer."""

    def ax0(b, o):
        return b.at[slot].set(o[0].astype(b.dtype))

    def ax1(b, o):
        return b.at[:, slot].set(o[:, 0].astype(b.dtype))

    tmap = jax.tree_util.tree_map
    out = {
        "prefix": [tmap(ax0, b, o) for b, o in
                   zip(dst_cache["prefix"], src_cache["prefix"])],
        "blocks": [tmap(ax1, b, o) for b, o in
                   zip(dst_cache["blocks"], src_cache["blocks"])],
        "tail": [tmap(ax0, b, o) for b, o in
                 zip(dst_cache["tail"], src_cache["tail"])],
    }
    if "enc_kv" in dst_cache:
        out["enc_kv"] = dst_cache["enc_kv"].at[:, :, slot].set(
            src_cache["enc_kv"][:, :, 0].astype(dst_cache["enc_kv"].dtype))
    return out


def _gather_slot(src_cache, slot: int):
    """Read row `slot` of a batched decode cache out as a batch=1 cache —
    the exact inverse of `_scatter_slot` (same per-entry batch axes), so a
    `pause` snapshot re-scatters bit-identically on `resume`."""

    def ax0(b):
        return b[slot:slot + 1]

    def ax1(b):
        return b[:, slot:slot + 1]

    tmap = jax.tree_util.tree_map
    out = {
        "prefix": [tmap(ax0, b) for b in src_cache["prefix"]],
        "blocks": [tmap(ax1, b) for b in src_cache["blocks"]],
        "tail": [tmap(ax0, b) for b in src_cache["tail"]],
    }
    if "enc_kv" in src_cache:
        out["enc_kv"] = src_cache["enc_kv"][:, :, slot:slot + 1]
    return out


class DenseBackend:
    """All weights resident on device; jitted prefill + decode step.

    ``paged=True`` swaps the per-slot (B, max_len) cache for a shared
    `PagedKVPool` (page_size-token pages, pool of `kv_pages` pages —
    default the dense equivalent) and prefills prompts in
    `prefill_chunk`-token chunks; requires `supports_paged_kv(model.cfg)`."""

    def __init__(self, model: Model, params, *, jit: bool = True,
                 paged: bool = False, page_size: int = 64,
                 kv_pages: Optional[int] = None, prefill_chunk: int = 64,
                 prefix_sharing: bool = True):
        self.model = model
        self.params = params
        self._jit = jit
        self.paged = paged
        self.page_size = page_size
        self.kv_pages = kv_pages
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = prefix_sharing
        if paged and not supports_paged_kv(model.cfg):
            raise ValueError(f"arch {model.cfg.name} does not support "
                             "the paged KV layout")

        def step(params, cache, tokens, positions, active):
            # active mask: released slots must not consume MoE dispatch
            # capacity (their junk rows would crowd live tokens at batch > 8)
            return model.decode_step(params, cache, tokens, positions,
                                     active=active)

        self._step = jax.jit(step, donate_argnums=1) if jit else step
        # donate the page buffers (args 1, 2 after params): the pool is
        # rebound to the outputs immediately, mirroring the dense cache
        self._paged_step = (jax.jit(model.decode_step_paged,
                                    donate_argnums=Model.PAGED_DECODE_DONATE)
                            if jit else model.decode_step_paged)
        self._prefill_fns = {}          # max_len -> (jitted) prefill
        self.kv: Optional[PagedKVPool] = None
        self._admission: Optional[ChunkedPrefill] = None
        self._pending_joins: dict = {}  # non-paged incremental admissions
        self._unclaimed_joins: dict = {}  # finished during a blocking join
        self.batch = 0
        self.max_len = 0

    def _prefill(self, max_len: int):
        if max_len not in self._prefill_fns:
            fn = make_prefill_step(self.model, max_len)
            self._prefill_fns[max_len] = jax.jit(fn) if self._jit else fn
        return self._prefill_fns[max_len]

    def start_batch(self, batch: int, max_len: int) -> None:
        """Allocate serving state: dense (B, max_len) cache, or — paged —
        (re)start the page pool (buffers are rebuilt only when shape-relevant
        parameters changed)."""
        self.batch, self.max_len = batch, max_len
        self.positions = jnp.zeros((batch,), jnp.int32)
        self.active = np.ones((batch,), bool)
        self._pending_joins = {}
        self._unclaimed_joins = {}
        if not self.paged:
            self.cache = self.model.init_cache(batch, max_len)
            return
        self.kv = self.model.init_cache(batch, max_len, paged=True,
                                        page_size=self.page_size,
                                        num_pages=self.kv_pages,
                                        prefix_sharing=self.prefix_sharing)
        self._admission = ChunkedPrefill(self.model, self.params, self.kv,
                                         chunk=self.prefill_chunk,
                                         jit=self._jit)

    def prefill(self, prompts) -> np.ndarray:
        """Full-batch prefill.  Paged: chunked prefill with every row
        reserving the full max_len (dense budget semantics).  Returns
        last-token logits (B, V)."""
        prompts_np = np.asarray(prompts, np.int32)
        if self.paged:
            # chunked prefill over the whole batch: every row reserves the
            # full max_len (same budget semantics as the dense allocator)
            for r in range(prompts_np.shape[0]):
                self._admission.begin(r, prompts_np[r],
                                      reserve_tokens=self.max_len)
            done: dict = {}
            while len(done) < prompts_np.shape[0]:
                done.update(self._admission.step())
            out = np.stack([done[r] for r in range(prompts_np.shape[0])])
            self.positions = jnp.asarray(
                [prompts_np.shape[1]] * prompts_np.shape[0], jnp.int32)
            self.active[:] = True
            return out
        prompts = jnp.asarray(prompts_np)
        batch = Batch(tokens=prompts, loss_mask=jnp.ones(prompts.shape))
        logits, self.cache, self.positions = self._prefill(self.max_len)(
            self.params, batch)
        self.active[:] = True
        return np.asarray(logits, np.float32)

    def join(self, slot: int, prompt) -> np.ndarray:
        """Blocking admission — a documented thin wrapper over
        `join_begin`/`join_step` (`_blocking_join`, the single blocking-join
        implementation shared by every backend).  Paged slots reserve the
        full max_len; concurrently pending admissions advance alongside and
        their finished logits stay claimable by the next join_step."""
        return _blocking_join(self, slot, prompt)

    def join_begin(self, slot: int, prompt,
                   reserve_tokens: Optional[int] = None) -> None:
        """Start an incremental admission.  Paged: reserves KV pages for
        `reserve_tokens` (default max_len) and queues the prompt for chunked
        prefill.  Dense: stashes the prompt; join_step runs it one-shot."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if self.paged:
            self._admission.begin(slot, prompt,
                                  reserve_tokens=reserve_tokens or self.max_len)
        else:
            self._pending_joins[slot] = prompt

    def join_step(self) -> dict:
        """Advance admissions one chunk (paged: ONE shared jitted call over
        every pending prompt; dense: each pending prompt's one-shot prefill).
        Completed slots are activated; returns their logits, plus any slots
        that finished inside an earlier blocking `join` and were not yet
        claimed."""
        done: dict = dict(self._unclaimed_joins)
        self._unclaimed_joins = {}
        if self.paged:
            done.update(self._admission.step())
            for slot in done:
                plen = int(self.kv.lens[slot])
                self.positions = self.positions.at[slot].set(plen)
                self.active[slot] = True
            return done
        for slot, prompt in list(self._pending_joins.items()):
            del self._pending_joins[slot]
            done[slot] = self._join_dense(slot, prompt)
        return done

    def _join_dense(self, slot: int, prompt) -> np.ndarray:
        prompt = jnp.asarray(np.asarray(prompt, np.int32).reshape(1, -1))
        batch = Batch(tokens=prompt, loss_mask=jnp.ones(prompt.shape))
        logits, one_cache, positions = self._prefill(self.max_len)(
            self.params, batch)
        self.cache = _scatter_slot(self.cache, one_cache, slot)
        self.positions = self.positions.at[slot].set(int(positions[0]))
        self.active[slot] = True
        return np.asarray(logits[0], np.float32)

    def can_admit(self, tokens: int, *, prompt=None) -> bool:
        """Paged: does the pool have unreserved pages for `tokens`?  With
        `prompt`, the pool prices the best prefix-sharing plan — aliased
        prefix pages are free, only the unshared suffix needs reservable
        pages.  Dense: always (the (B, max_len) slot is pre-allocated)."""
        if self.paged:
            return self.kv.can_reserve(tokens, prompt=prompt)
        return True

    def pause(self, slot: int) -> dict:
        """Preempt `slot` mid-decode: snapshot its KV to host and free the
        slot.  Paged: the snapshot gathers the slot's written pages through
        its table *before* release, so pages aliased by other slots keep
        those sharers' refcounts (only this slot's references drop)."""
        pos = int(np.asarray(self.positions)[slot])
        if self.paged:
            snap = self.kv.snapshot_slot(slot)
            self.kv.release(slot)
            self.active[slot] = False
            return {"layout": "paged", "position": pos, "kv": snap}
        cache = jax.tree_util.tree_map(np.asarray,
                                       _gather_slot(self.cache, slot))
        self.active[slot] = False
        return {"layout": "dense", "position": pos, "cache": cache}

    def resume(self, slot: int, snapshot: dict) -> None:
        """Re-admit a paused request into `slot` (any free slot works): the
        snapshot's KV bytes are written back verbatim, so decode continues
        logits-identical to the unpreempted run."""
        if self.paged:
            self.kv.restore_slot(slot, snapshot["kv"])
        else:
            self.cache = _scatter_slot(self.cache, snapshot["cache"], slot)
        self.positions = self.positions.at[slot].set(snapshot["position"])
        self.active[slot] = True

    def release(self, slot: int) -> None:
        """Free a slot; paged slots return their pages to the pool for the
        next queued request."""
        self.active[slot] = False
        if self.paged and self.kv is not None:
            self.kv.release(slot)

    def step(self, tokens) -> np.ndarray:
        """One decode step for the whole batch; under paged KV the step
        first grows each active slot's page chain for the token about to be
        written, then scatters/gathers through the page table."""
        tokens = jnp.asarray(np.asarray(tokens, np.int32).reshape(-1, 1))
        if self.paged:
            pos_host = np.asarray(self.positions)
            for r in range(self.batch):
                if self.active[r]:
                    p = int(pos_host[r])
                    self.kv.ensure(r, p + 1)
                    # decode appending into a shared (aliased) page copies
                    # it off first — readers keep the original
                    self.kv.make_writable(r, p, p + 1)
            logits, ks, vs = self._paged_step(
                self.params, self.kv.k, self.kv.v, self.kv.table_device(),
                tokens, self.positions, jnp.asarray(self.active))
            self.kv.k, self.kv.v = list(ks), list(vs)
        else:
            logits, self.cache = self._step(self.params, self.cache, tokens,
                                            self.positions,
                                            jnp.asarray(self.active))
        # only active slots advance; freed slots idle at their last position
        self.positions = self.positions + jnp.asarray(
            self.active.astype(np.int32))
        return np.asarray(logits, np.float32)

    def stats(self) -> dict:
        """Uniform backend counters; resident weights never stall on expert
        transfers, so load_stall_s/overlap_fraction are 0.  kv_* keys report
        page-pool pressure (zeros under the dense allocator)."""
        s = {"backend": "dense", "batch": self.batch, "max_len": self.max_len,
             "load_stall_s": 0.0, "overlap_fraction": 0.0,
             "precision_downgrades": 0, "issue_reorders": 0,
             "link_utilization": 0.0, "per_stream_bytes": [],
             "kv_pages_used": 0, "kv_pages_total": 0,
             "kv_page_fraction": 0.0, "prefix_hit_tokens": 0,
             "cow_copies": 0, "aliased_page_fraction": 0.0}
        if self.paged and self.kv is not None:
            s.update(self.kv.stats())
        return s

    def close(self) -> None:
        """Uniform teardown hook: resident weights hold no staging threads,
        so this is a no-op (idempotent by construction)."""


# --------------------------------------------------------------------------
# HOBBIT offload backend
# --------------------------------------------------------------------------

class HobbitBackend:
    """`OffloadEngine` behind the protocol: batched mixed-precision decode
    with union-of-slots expert loading and a real (dense, compute-bound)
    prefill path.  `EngineConfig(paged_kv=True)` selects the paged KV
    layout; the engine then shares the same `PagedKVPool` / `ChunkedPrefill`
    machinery as `DenseBackend`."""

    def __init__(self, engine):
        self.engine = engine
        self.model = engine.model

    def start_batch(self, batch: int, max_len: int) -> None:
        """Allocate engine serving state (dense per-layer caches or the
        page pool) for `batch` slots."""
        self.engine.start_batch(batch, max_len)

    def prefill(self, prompts) -> np.ndarray:
        """Full-batch dense-compute prefill (prefill touches every expert
        anyway; the offload cache only serves decode)."""
        return self.engine.prefill_batch(prompts)

    def join(self, slot: int, prompt) -> np.ndarray:
        """Blocking mid-flight admission of one request into `slot` — the
        engine's `join` is itself a thin wrapper over the shared
        `_blocking_join` loop (one implementation, not three)."""
        return self.engine.join(slot, prompt)

    def join_begin(self, slot: int, prompt,
                   reserve_tokens: Optional[int] = None) -> None:
        """Start an incremental admission (chunked under paged KV)."""
        self.engine.join_begin(slot, prompt, reserve_tokens=reserve_tokens)

    def join_step(self) -> dict:
        """Advance every in-progress admission by one prefill chunk."""
        return self.engine.join_step()

    def can_admit(self, tokens: int, *, prompt=None) -> bool:
        """KV-capacity gate for admission (always True under dense KV; with
        `prompt`, paged engines price the request net of prefix sharing)."""
        return self.engine.can_admit(tokens, prompt=prompt)

    def pause(self, slot: int) -> dict:
        """Preempt `slot` mid-decode: snapshot its KV (dense rows or paged
        pages, prefix-sharing refcounts handled by the pool) to host and
        free the slot for a more urgent request."""
        return self.engine.pause(slot)

    def resume(self, slot: int, snapshot: dict) -> None:
        """Restore a paused request's KV and position into `slot`; decode
        continues logits-identical to the unpreempted run."""
        self.engine.resume(slot, snapshot)

    def release(self, slot: int) -> None:
        """Free a slot (and its KV pages under paged KV)."""
        self.engine.release(slot)

    def step(self, tokens) -> np.ndarray:
        """One batched HOBBIT decode step ((B,) tokens -> (B, V) logits)."""
        return self.engine.decode_step_batch(tokens)

    def stats(self) -> dict:
        """Engine counters (cache/loader/predictor/scheduler/KV-pool) tagged
        with the backend name."""
        s = dict(self.engine.stats())
        s["backend"] = "hobbit"
        return s

    def close(self) -> None:
        """Release the engine's staging worker threads (idempotent; the
        scheduler calls this on teardown)."""
        self.engine.close()


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Typed backend construction config — the ONE argument `make_backend`
    consumes (mirrored 1:1 by `launch/serve.py` flags).

    kind            "dense" (resident weights) or "hobbit" (offload engine)
    jit             jit the dense prefill/decode steps
    paged           paged KV layout (shared page pool) on either backend
    page_size       tokens per KV page
    kv_pages        pool size in pages (None: the dense equivalent)
    prefill_chunk   tokens per chunked-prefill call
    prefix_sharing  radix prefix cache over the paged pool
    engine          `core.EngineConfig` for kind="hobbit" (None: defaults);
                    `paged=True` overrides its paged-KV fields
    """
    kind: str = "dense"
    jit: bool = True
    paged: bool = False
    page_size: int = 64
    kv_pages: Optional[int] = None
    prefill_chunk: int = 64
    prefix_sharing: bool = True
    engine: Optional[object] = None


_UNSET = object()   # distinguishes "not passed" from any explicit value


def make_backend(kind, model: Model, params, *, engine_config=_UNSET,
                 jit=_UNSET, paged=_UNSET, page_size=_UNSET, kv_pages=_UNSET,
                 prefill_chunk=_UNSET, prefix_sharing=_UNSET):
    """Factory for launchers: ``make_backend(BackendConfig(...), model,
    params)``.  A bare string kind (``make_backend("dense", model, params)``)
    is accepted as shorthand for the all-defaults config; passing any of the
    historical keyword arguments is DEPRECATED (they are folded into a
    BackendConfig behind a DeprecationWarning and removed next release)."""
    legacy = {name: val for name, val in [
        ("engine", engine_config), ("jit", jit), ("paged", paged),
        ("page_size", page_size), ("kv_pages", kv_pages),
        ("prefill_chunk", prefill_chunk), ("prefix_sharing", prefix_sharing),
    ] if val is not _UNSET}
    if isinstance(kind, BackendConfig):
        if legacy:
            raise TypeError(
                "make_backend(BackendConfig(...)) takes no keyword "
                f"arguments; fold {sorted(legacy)} into the config")
        cfg = kind
    else:
        if legacy:
            warnings.warn(
                "make_backend(kind, ..., **kwargs) is deprecated; pass "
                "make_backend(BackendConfig(kind=..., ...), model, params)",
                DeprecationWarning, stacklevel=2)
        cfg = BackendConfig(kind=kind, **legacy)

    if cfg.kind == "dense":
        return DenseBackend(model, params, jit=cfg.jit, paged=cfg.paged,
                            page_size=cfg.page_size, kv_pages=cfg.kv_pages,
                            prefill_chunk=cfg.prefill_chunk,
                            prefix_sharing=cfg.prefix_sharing)
    if cfg.kind == "hobbit":
        from repro.core.engine import EngineConfig, OffloadEngine
        ecfg = cfg.engine or EngineConfig()
        if cfg.paged:
            ecfg = dataclasses.replace(ecfg, paged_kv=True,
                                       kv_page_size=cfg.page_size,
                                       kv_pages=cfg.kv_pages,
                                       prefill_chunk=cfg.prefill_chunk,
                                       prefix_sharing=cfg.prefix_sharing)
        eng = OffloadEngine(model, params, ecfg)
        return HobbitBackend(eng)
    raise ValueError(f"unknown backend kind: {cfg.kind!r}")


# --------------------------------------------------------------------------
# protocol-level helpers (generate / score_nll for any backend)
# --------------------------------------------------------------------------

def generate(backend: InferenceBackend, prompts, new_tokens: int, *,
             max_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0) -> GenerateResult:
    """Greedy/temperature generation through any backend.  prompts: (B, S)."""
    prompts = np.asarray(prompts, np.int32)
    b, s = prompts.shape
    max_len = max_len or (s + new_tokens + 1)
    backend.start_batch(b, max_len)

    t0 = time.time()
    lg = backend.prefill(prompts)
    t1 = time.time()

    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = np.asarray(sample_token(jnp.asarray(lg), key, temperature))
    for i in range(new_tokens):
        out.append(np.asarray(tok)[:, None])
        if i == new_tokens - 1:
            break
        key, sub = jax.random.split(key)
        lg = backend.step(tok)
        tok = np.asarray(sample_token(jnp.asarray(lg), sub, temperature))
    t2 = time.time()
    return GenerateResult(np.concatenate(out, axis=1), t1 - t0, t2 - t1,
                          new_tokens)


def score_nll(backend: InferenceBackend, tokens, *,
              max_len: Optional[int] = None) -> float:
    """Teacher-forced mean NLL through any backend's decode path (the first
    token enters via a 1-token join/prefill; every later token is a decode
    step, so offload backends are exercised on their serving path)."""
    tokens = [int(t) for t in np.asarray(tokens).reshape(-1)]
    max_len = max_len or (len(tokens) + 1)
    backend.start_batch(1, max_len)
    lg = backend.join(0, np.asarray(tokens[:1], np.int32))
    nll, n = 0.0, 0
    for t in tokens[1:]:
        p = np.asarray(lg, np.float64)
        p -= p.max()
        p -= np.log(np.exp(p).sum())
        nll -= p[t]
        n += 1
        lg = backend.step(np.asarray([t], np.int32))[0]
    return float(nll / max(n, 1))
