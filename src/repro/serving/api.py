"""Unified serving API: one `InferenceBackend` protocol in front of both the
dense (resident-weights) decode path and the HOBBIT mixed-precision expert
offloading engine, so schedulers, launchers, examples and benchmarks drive a
single interface regardless of where the experts live.

The protocol is slot-oriented to support *continuous batching*
(`serving.batching.BatchingServer`): a backend holds `batch` KV-cache slots,
a finished request `release()`s its slot mid-flight, and a queued request
`join()`s the freed slot at the next step without disturbing its neighbours.

    backend methods
    ---------------
    start_batch(batch, max_len)      allocate B slots (all marked active)
    prefill(prompts (B,S)) -> (B,V)  full-batch prefill, last-token logits
    join(slot, prompt (S,)) -> (V,)  admit one request into a slot mid-flight
    release(slot)                    free a slot (junk rows until next join)
    step(tokens (B,)) -> (B,V)       one decode step for the whole batch
    stats() -> dict                  backend-specific counters

Usage::

    from repro.serving.api import DenseBackend, HobbitBackend, generate
    from repro.core import EngineConfig, OffloadEngine

    backend = DenseBackend(model, params)                  # resident weights
    res = generate(backend, prompts, new_tokens=32)        # same helper...

    eng = OffloadEngine(model, params, EngineConfig(hi_slots=16, lo_slots=8))
    res = generate(HobbitBackend(eng), prompts, 32)        # ...either way

`generate` / `score_nll` here are thin helpers over the protocol; the
continuous-batching scheduler lives in `serving.batching`.
"""

from __future__ import annotations

import time
from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Batch, Model
from repro.serving.decode import (GenerateResult, make_prefill_step,
                                  sample_token)


@runtime_checkable
class InferenceBackend(Protocol):
    """Slot-oriented decode interface served by the continuous scheduler."""

    model: Model

    def start_batch(self, batch: int, max_len: int) -> None: ...

    def prefill(self, prompts: np.ndarray) -> np.ndarray: ...

    def join(self, slot: int, prompt: np.ndarray) -> np.ndarray: ...

    def release(self, slot: int) -> None: ...

    def step(self, tokens: np.ndarray) -> np.ndarray: ...

    def stats(self) -> dict: ...


# --------------------------------------------------------------------------
# dense (resident-weights) backend
# --------------------------------------------------------------------------

def _scatter_slot(dst_cache, src_cache, slot: int):
    """Write a batch=1 prefill cache into row `slot` of a batched cache.

    The nested decode-cache layout puts the batch axis at 0 for prefix/tail
    entries, 1 for scanned-block entries (stacked (num_blocks, B, ...)), and
    2 for the whisper enc_kv buffer."""

    def ax0(b, o):
        return b.at[slot].set(o[0].astype(b.dtype))

    def ax1(b, o):
        return b.at[:, slot].set(o[:, 0].astype(b.dtype))

    tmap = jax.tree_util.tree_map
    out = {
        "prefix": [tmap(ax0, b, o) for b, o in
                   zip(dst_cache["prefix"], src_cache["prefix"])],
        "blocks": [tmap(ax1, b, o) for b, o in
                   zip(dst_cache["blocks"], src_cache["blocks"])],
        "tail": [tmap(ax0, b, o) for b, o in
                 zip(dst_cache["tail"], src_cache["tail"])],
    }
    if "enc_kv" in dst_cache:
        out["enc_kv"] = dst_cache["enc_kv"].at[:, :, slot].set(
            src_cache["enc_kv"][:, :, 0].astype(dst_cache["enc_kv"].dtype))
    return out


class DenseBackend:
    """All weights resident on device; jitted prefill + decode step."""

    def __init__(self, model: Model, params, *, jit: bool = True):
        self.model = model
        self.params = params
        self._jit = jit

        def step(params, cache, tokens, positions, active):
            # active mask: released slots must not consume MoE dispatch
            # capacity (their junk rows would crowd live tokens at batch > 8)
            return model.decode_step(params, cache, tokens, positions,
                                     active=active)

        self._step = jax.jit(step, donate_argnums=1) if jit else step
        self._prefill_fns = {}          # max_len -> (jitted) prefill
        self.batch = 0
        self.max_len = 0

    def _prefill(self, max_len: int):
        if max_len not in self._prefill_fns:
            fn = make_prefill_step(self.model, max_len)
            self._prefill_fns[max_len] = jax.jit(fn) if self._jit else fn
        return self._prefill_fns[max_len]

    def start_batch(self, batch: int, max_len: int) -> None:
        self.batch, self.max_len = batch, max_len
        self.cache = self.model.init_cache(batch, max_len)
        self.positions = jnp.zeros((batch,), jnp.int32)
        self.active = np.ones((batch,), bool)

    def prefill(self, prompts) -> np.ndarray:
        prompts = jnp.asarray(np.asarray(prompts, np.int32))
        batch = Batch(tokens=prompts, loss_mask=jnp.ones(prompts.shape))
        logits, self.cache, self.positions = self._prefill(self.max_len)(
            self.params, batch)
        self.active[:] = True
        return np.asarray(logits, np.float32)

    def join(self, slot: int, prompt) -> np.ndarray:
        prompt = jnp.asarray(np.asarray(prompt, np.int32).reshape(1, -1))
        batch = Batch(tokens=prompt, loss_mask=jnp.ones(prompt.shape))
        logits, one_cache, positions = self._prefill(self.max_len)(
            self.params, batch)
        self.cache = _scatter_slot(self.cache, one_cache, slot)
        self.positions = self.positions.at[slot].set(int(positions[0]))
        self.active[slot] = True
        return np.asarray(logits[0], np.float32)

    def release(self, slot: int) -> None:
        self.active[slot] = False

    def step(self, tokens) -> np.ndarray:
        tokens = jnp.asarray(np.asarray(tokens, np.int32).reshape(-1, 1))
        logits, self.cache = self._step(self.params, self.cache, tokens,
                                        self.positions,
                                        jnp.asarray(self.active))
        # only active slots advance; freed slots idle at their last position
        self.positions = self.positions + jnp.asarray(
            self.active.astype(np.int32))
        return np.asarray(logits, np.float32)

    def stats(self) -> dict:
        # load_stall_s / overlap_fraction are part of the uniform backend
        # stats contract (schedulers attribute stall to requests); resident
        # weights never stall on expert transfers
        return {"backend": "dense", "batch": self.batch,
                "max_len": self.max_len,
                "load_stall_s": 0.0, "overlap_fraction": 0.0}


# --------------------------------------------------------------------------
# HOBBIT offload backend
# --------------------------------------------------------------------------

class HobbitBackend:
    """`OffloadEngine` behind the protocol: batched mixed-precision decode
    with union-of-slots expert loading and a real (dense, compute-bound)
    prefill path."""

    def __init__(self, engine):
        self.engine = engine
        self.model = engine.model

    def start_batch(self, batch: int, max_len: int) -> None:
        self.engine.start_batch(batch, max_len)

    def prefill(self, prompts) -> np.ndarray:
        return self.engine.prefill_batch(prompts)

    def join(self, slot: int, prompt) -> np.ndarray:
        return self.engine.join(slot, prompt)

    def release(self, slot: int) -> None:
        self.engine.release(slot)

    def step(self, tokens) -> np.ndarray:
        return self.engine.decode_step_batch(tokens)

    def stats(self) -> dict:
        s = dict(self.engine.stats())
        s["backend"] = "hobbit"
        return s


def make_backend(kind: str, model: Model, params, *, engine_config=None,
                 jit: bool = True):
    """Factory for launchers: kind in {"dense", "hobbit"}."""
    if kind == "dense":
        return DenseBackend(model, params, jit=jit)
    if kind == "hobbit":
        from repro.core.engine import EngineConfig, OffloadEngine
        eng = OffloadEngine(model, params, engine_config or EngineConfig())
        return HobbitBackend(eng)
    raise ValueError(f"unknown backend kind: {kind!r}")


# --------------------------------------------------------------------------
# protocol-level helpers (generate / score_nll for any backend)
# --------------------------------------------------------------------------

def generate(backend: InferenceBackend, prompts, new_tokens: int, *,
             max_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0) -> GenerateResult:
    """Greedy/temperature generation through any backend.  prompts: (B, S)."""
    prompts = np.asarray(prompts, np.int32)
    b, s = prompts.shape
    max_len = max_len or (s + new_tokens + 1)
    backend.start_batch(b, max_len)

    t0 = time.time()
    lg = backend.prefill(prompts)
    t1 = time.time()

    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = np.asarray(sample_token(jnp.asarray(lg), key, temperature))
    for i in range(new_tokens):
        out.append(np.asarray(tok)[:, None])
        if i == new_tokens - 1:
            break
        key, sub = jax.random.split(key)
        lg = backend.step(tok)
        tok = np.asarray(sample_token(jnp.asarray(lg), sub, temperature))
    t2 = time.time()
    return GenerateResult(np.concatenate(out, axis=1), t1 - t0, t2 - t1,
                          new_tokens)


def score_nll(backend: InferenceBackend, tokens, *,
              max_len: Optional[int] = None) -> float:
    """Teacher-forced mean NLL through any backend's decode path (the first
    token enters via a 1-token join/prefill; every later token is a decode
    step, so offload backends are exercised on their serving path)."""
    tokens = [int(t) for t in np.asarray(tokens).reshape(-1)]
    max_len = max_len or (len(tokens) + 1)
    backend.start_batch(1, max_len)
    lg = backend.join(0, np.asarray(tokens[:1], np.int32))
    nll, n = 0.0, 0
    for t in tokens[1:]:
        p = np.asarray(lg, np.float64)
        p -= p.max()
        p -= np.log(np.exp(p).sum())
        nll -= p[t]
        n += 1
        lg = backend.step(np.asarray([t], np.int32))[0]
    return float(nll / max(n, 1))
