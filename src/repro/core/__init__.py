from repro.core.cache import CacheStarvation, CacheStats, MultidimensionalCache
from repro.core.engine import EngineConfig, OffloadEngine
from repro.core.loader import (AsyncExpertScheduler, DynamicExpertLoader,
                               LoadTask, StagingEngine, measure_link_bps)
from repro.core.policies import (FLD, LFU, LHU, LRU, MULTIDIM, NAMED_POLICIES,
                                 PolicyWeights)
from repro.core.predictor import AdaptiveExpertPredictor, gating_input_similarity
from repro.core.scoring import (PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                                calibrate_thresholds, gate_output_correlation,
                                precision_decisions, unimportance_scores)
from repro.core.simulator import (HARDWARE, HobbitSimConfig, JETSON_ORIN,
                                  OffloadSimulator, RTX4090, TPU_V5E_HOST,
                                  TraceLayer, cache_policy_penalty,
                                  simulate_systems)

__all__ = [
    "CacheStarvation", "CacheStats", "MultidimensionalCache", "EngineConfig",
    "OffloadEngine", "AsyncExpertScheduler", "StagingEngine",
    "DynamicExpertLoader", "LoadTask", "measure_link_bps",
    "FLD", "LFU", "LHU", "LRU", "MULTIDIM",
    "NAMED_POLICIES", "PolicyWeights", "AdaptiveExpertPredictor",
    "gating_input_similarity", "PREC_HI", "PREC_LO", "PREC_SKIP", "Thresholds",
    "calibrate_thresholds", "gate_output_correlation", "precision_decisions",
    "unimportance_scores", "HARDWARE", "HobbitSimConfig", "JETSON_ORIN",
    "OffloadSimulator", "RTX4090", "TPU_V5E_HOST", "TraceLayer",
    "cache_policy_penalty", "simulate_systems",
]
