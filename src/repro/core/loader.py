"""Token-level Dynamic Expert Loader (HOBBIT §3.2): Expert Scorer + Task
Queue + Expert Scheduler, with a multi-stream byte-budgeted staging engine.

On a cache miss the Expert Scorer turns gate magnitudes into per-expert
precision decisions (Eq. 2 + T1/T2); the scheduler executes load tasks,
fetching weights from host storage and admitting them into the cache (which
may evict).  Two schedulers exist:

  * ``DynamicExpertLoader.drain`` — the original synchronous scheduler (one
    fetch per task on the caller's thread).  Kept as the reference path and
    for the engine's legacy per-expert decode.
  * ``StagingEngine`` — the wall-clock-real scheduler: PREFETCH tasks
    reserve their cache slot immediately (in-flight reservation, so nothing
    can race them) and stage their weight bytes on N background streams
    (default: one hi-precision, one lo-precision) that share a modeled H2D
    link budget; a ``wait(layer)`` barrier commits staged writes before the
    layer that needs them reads the pools.  ON_DEMAND tasks stay blocking
    but are batched into a single scatter per pool tensor (``commit_fn``).
    ``StagingEngine(streams=1, ordered=True)`` reproduces the PR-2
    single-worker FIFO scheduler exactly (the parity reference);
    ``AsyncExpertScheduler`` remains as that configuration's alias.

Issue policy of the budgeted engine (``ordered=False``): queued jobs carry
``(layer, expert, precision, bytes, gate_score)``; each stream issues
**biggest-gate-first within the nearest-deadline layer**, and a queued (not
in-flight) hi-precision job is preempted by a lo-precision replacement when
the remaining link budget before the layer's ``wait()`` deadline —
``(deadline_layer - current_layer) * per_layer_s * link_bps`` minus bytes
already issued and not yet landed — cannot fit the hi copy.  This is the
paper's token-level dynamic precision decision made at *issue* time under
link contention rather than only at request time; the engine's compute path
consumes the downgrade by serving the affected expert from the lo pool.

A downgrade is meant to be *temporary*: when ``_pump()`` finds no queued
deadline work (twice in a row) and a hi stream fully idle, the idle-link
**upgrade pass** (``_pump_upgrades``, on by default; ``upgrade=False`` keeps
the PR-4 per-token semantics bit-identical) re-issues hi copies for
lo-substituted experts — hottest Eq. 3 cache priority first, at most one in
flight per stream — landing them via the precision-keyed in-flight
reservation next to the resident lo copy.  The compute path serves the lo
stand-in (counted in ``served_lo_expert_steps``) until the hi bytes commit,
then switches back to hi.  The substitution therefore lasts exactly as long
as the link stays saturated — while every pump still carries deadline work,
hi reloads for substituted keys are deliberately suppressed (re-adding the
bytes the preemption shed would stall the very barriers the downgrade
protects; under the PR-4 per-token semantics the same sustained contention
re-downgrades the same hot experts every token anyway) — and ends at the
first idle window, so a token-level precision decision can outlive its
token only while the link has no spare capacity to undo it, with the
exposure always visible in ``served_lo_expert_steps``.

StagingEngine lifecycle of one prefetched expert::

    submit_prefetch(layer, experts, decisions, gates)  [main thread]
        -> cache.admit() assigns a slot NOW            "reserve"
        -> cache.begin_inflight(key, slot)             eviction-proof
        -> job queued per stream; _pump() issues the best job when its
           stream is free (possibly downgrading hi -> lo under budget)
        -> stream executor stages host bytes           overlaps compute
    wait(layer)  (barrier before the layer runs)      [main thread]
        -> pending jobs for `layer` are force-issued, futures awaited
           (blocks only if the copy is late -> stall_s)
        -> cache.end_inflight(key)                     "commit" begins
        -> commit_fn(entries): ONE batched scatter per pool tensor
    (wait_all()/flush() at sequence boundaries commit leftovers without
    attributing stall)

Invariants: cache metadata is touched ONLY on the main thread (admission,
reservation, downgrade cancellation all happen at submit/pump/wait time);
the background workers see host storage and private staging buffers, never
the pools; an in-flight entry owns its slot from submit to commit (or until
a downgrade cancels it before issue), so a staged write can never land on a
reassigned slot (see core/cache.py for the reservation state machine).  The
staging engine shares the loader's cache and byte/load counters so
`engine.stats()` is one source of truth either way.  Metric definitions:
docs/METRICS.md; system map: docs/ARCHITECTURE.md.
"""

from __future__ import annotations

import dataclasses
import time
import weakref
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Deque, List, Optional, Set, Tuple

import numpy as np

from repro.core.cache import CacheStarvation, MultidimensionalCache
from repro.core.scoring import (PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                                precision_decisions)

ON_DEMAND, PREFETCH, UPGRADE = "on_demand", "prefetch", "upgrade"


def measure_link_bps(nbytes: int = 1 << 22, repeats: int = 3) -> float:
    """Measure the host-side copy bandwidth (bytes/s) used as the modeled
    H2D link rate when `EngineConfig.link_gbps` is not set.

    On this CPU-only container the "link" is a memcpy; on a real GPU host
    this would be a pinned-memory H2D timing loop.  The result only feeds
    the staging engine's issue-time budget accounting, never a sleep."""
    src = np.ones(nbytes, np.uint8)
    dst = np.empty_like(src)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return nbytes / max(best, 1e-9)


@dataclasses.dataclass
class LoadTask:
    """One expert transfer request (the paper's Task Queue entry)."""
    layer: int
    expert: int
    precision: int              # PREC_HI | PREC_LO
    reason: str                 # ON_DEMAND | PREFETCH
    bytes: int = 0              # filled by the scheduler from the cost model
    gate: float = 0.0           # routing weight that requested this expert


@dataclasses.dataclass
class LoadReport:
    """Outcome of scoring one (layer, slot) expert set."""
    tasks: List[LoadTask]
    skipped: List[int]          # expert ids skipped this layer (score > T2)
    hit_slots: List[Tuple[int, int, int]]   # (expert, precision, slot)


class DynamicExpertLoader:
    """Expert Scorer + Task Queue + the synchronous reference scheduler."""

    def __init__(self, cache: MultidimensionalCache, th: Thresholds,
                 fetch_fn: Callable[[int, int, int, int], None],
                 bytes_fn: Callable[[int], int]):
        """fetch_fn(layer, expert, precision, slot): writes the expert weights
        into the assigned device pool slot (engine-provided closure).
        bytes_fn(precision) -> transfer size."""
        self.cache = cache
        self.th = th
        self.fetch_fn = fetch_fn
        self.bytes_fn = bytes_fn
        self.queue: Deque[LoadTask] = deque()
        self.loaded_bytes = 0
        self.n_loads = {PREC_HI: 0, PREC_LO: 0}
        self.n_skips = 0

    # ---------------- Expert Scorer ----------------
    def new_layer(self):
        """Reset hard pins at a layer boundary.  Batched decoding calls this
        once per layer, then scores every slot's expert set with
        ``clear_pins=False`` so the union of all slots' experts stays
        protected while the layer executes."""
        self.cache.hard_pinned.clear()

    def score_and_enqueue(self, layer: int, experts: List[int],
                          gate_vals: np.ndarray, *,
                          clear_pins: bool = True) -> LoadReport:
        """Handle the on-demand expert set of one MoE layer for one token
        (one batch slot)."""
        dec = precision_decisions(gate_vals, self.th)
        # hard pins protect only the layer being executed; earlier layers'
        # experts already ran and may be evicted again
        if clear_pins:
            self.cache.hard_pinned.clear()
        tasks, skipped, hits = [], [], []
        for e, d, g in zip(experts, dec, gate_vals):
            if d == PREC_SKIP:
                skipped.append(e)
                self.n_skips += 1
                continue
            is_hi = d == PREC_HI
            # the experts of the layer being executed must never be evicted
            # by a concurrent prefetch admission
            self.cache.pin((layer, e), is_hi, hard=True)
            slot = self.cache.probe((layer, e), is_hi)
            if slot is not None:
                hits.append((e, d, slot))
            else:
                t = LoadTask(layer, e, int(d), ON_DEMAND, self.bytes_fn(int(d)),
                             float(g))
                tasks.append(t)
                self.queue.append(t)
        return LoadReport(tasks, skipped, hits)

    def enqueue_prefetch(self, layer: int, experts: List[int],
                         decisions: np.ndarray):
        """Queue prefetch tasks for a future layer (synchronous path)."""
        for e, d in zip(experts, decisions):
            if d == PREC_SKIP:
                continue
            if self.cache.lookup((layer, e), d == PREC_HI) is None:
                self.queue.append(
                    LoadTask(layer, e, int(d), PREFETCH, self.bytes_fn(int(d))))

    def take_queued(self) -> List[LoadTask]:
        """Hand the queued tasks to an external scheduler (clears the queue)."""
        tasks = list(self.queue)
        self.queue.clear()
        return tasks

    # ---------------- Expert Scheduler ----------------
    def drain(self, current_layer: int) -> List[Tuple[LoadTask, int]]:
        """Execute all queued tasks (on-demand first).  Returns
        [(task, slot)] in execution order."""
        done = []
        ordered = sorted(self.queue, key=lambda t: t.reason != ON_DEMAND)
        self.queue.clear()
        for t in ordered:
            is_hi = t.precision == PREC_HI
            if self.cache.lookup((t.layer, t.expert), is_hi) is not None:
                continue  # raced: already resident (e.g. dup prefetch)
            slot, _evicted = self.cache.admit((t.layer, t.expert), is_hi,
                                              current_layer)
            self.fetch_fn(t.layer, t.expert, t.precision, slot)
            self.loaded_bytes += t.bytes
            self.n_loads[t.precision] += 1
            done.append((t, slot))
        return done


# --------------------------------------------------------------------------
# multi-stream staging engine (byte-budgeted issue under a modeled H2D link)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _PrefetchJob:
    """One FIFO batch job of the ordered (PR-2 parity) path."""
    tasks: List[Tuple[LoadTask, int]]       # (task, reserved slot)
    future: Future                          # -> (staged, t_start, t_end)
    t_submit: float


@dataclasses.dataclass
class StagingJob:
    """One queued/issued transfer of the budgeted multi-stream path."""
    task: LoadTask
    slot: int
    seq: int                                # global submit order (FIFO tie)
    stream: int
    future: Optional[Future] = None         # set at issue time


class StagingEngine:
    """Executes load tasks so prefetch copies overlap compute in wall clock,
    issuing them over N streams under a shared modeled H2D link budget.

    Division of labour with the engine:
      stage_fn(layer, expert, precision) -> staged host buffers (the
          host-side gather — the expensive part of the transfer — safe to run
          on a background thread because it only *reads* host storage).
      commit_fn(entries) with entries = [(task, slot, staged)] -> writes all
          staged buffers into the device pools, one scatter per pool tensor
          (main thread only, so pool arrays are never mutated concurrently
          with compute).

    Streams map to independent copy engines: hi-precision jobs issue on the
    first half of the streams, lo-precision jobs on the second half (with
    ``streams=2`` that is the paper-natural one-hi/one-lo split).  Each
    stream serializes its own copies; issue *order* within a stream is
    biggest-gate-first within the nearest-deadline layer.  The shared link
    budget (``link_bps``, measured at startup or configured) is consulted at
    issue time: a queued hi job whose bytes no longer fit before its layer's
    ``wait()`` deadline is preempted by a lo replacement (recorded in
    ``downgraded`` for the engine's compute path) — in-flight copies are
    never interrupted.  With ``emulate_link=True`` each staged copy also
    *occupies* the modeled link for bytes/link_bps seconds, so wall-clock
    stall numbers on this CPU-only container reflect link contention the
    way the simulator's timeline does.

    Cache metadata is only ever touched on the main thread: prefetch
    admission happens at submit time (with an in-flight reservation so
    lookup/eviction can't race it), downgrades cancel-and-readmit at pump
    time, and the background threads see nothing but host storage and their
    private staging buffers.
    """

    def __init__(self, loader: DynamicExpertLoader,
                 stage_fn: Callable[[int, int, int], dict],
                 commit_fn: Callable[[List[Tuple[LoadTask, int, dict]]], None],
                 *, streams: int = 2, ordered: bool = False,
                 link_bps: Optional[float] = None, emulate_link: bool = False,
                 upgrade: bool = True):
        self.loader = loader
        self.cache = loader.cache
        self.stage_fn = stage_fn
        self.commit_fn = commit_fn
        self.streams = max(1, int(streams))
        self.ordered = bool(ordered)
        # idle-link upgrade pass: re-issue hi copies for lo-substituted
        # (downgraded) experts when a hi stream has leftover link budget.
        # Only meaningful on the budgeted path — the ordered parity scheduler
        # never downgrades, so it never has anything to upgrade.
        self.upgrade = bool(upgrade) and not self.ordered
        self.link_bps = float(link_bps) if link_bps else 0.0
        self.emulate_link = bool(emulate_link) and self.link_bps > 0
        self._pools = [ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix=f"expert-stage{i}")
                       for i in range(self.streams)]
        # release the worker threads when the scheduler (engine) is collected
        self._finalizer = weakref.finalize(
            self, StagingEngine._shutdown_pools, self._pools)
        # owner: main-thread — the zero-lock scheduler queues: submit,
        # issue, collect and land all run on the caller's thread; the
        # stream executors only ever copy bytes (see class docstring)
        self._jobs: List[_PrefetchJob] = []     # owner: main-thread
        self._pending: List[StagingJob] = []    # owner: main-thread
        self._issued: List[StagingJob] = []     # owner: main-thread
        self._seq = 0
        self._rr = {True: 0, False: 0}              # round-robin per class
        # deadline clock (engine hints): current layer + per-layer seconds
        self._clock_layer = 0
        self._layer_s = 0.0         # compute-only window (downgrade budget)
        self._period_s = 0.0        # full layer period incl. load (stream feed)
        # consecutive pumps that found no queued deadline work (upgrade
        # hysteresis: see _pump_upgrades)
        self._idle_pumps = 0
        # issue-time downgrades the compute path should serve from lo
        # (per-token markers, retired each layer — the PR-4 semantics the
        # upgrade-off path keeps bit-identical)
        # owner: main-thread
        self.downgraded: Set[Tuple[int, int]] = set()
        # persistent downgrade substitutions: keys whose hi copy was
        # preempted and whose lo copy stands in for it until an upgrade
        # lands a hi copy next to it (or the lo copy is evicted / flushed).
        # The upgrade pass draws its candidates from here.
        # owner: main-thread
        self.lo_substituted: Set[Tuple[int, int]] = set()
        # observability (engine.stats() reads these)
        self.stall_s = 0.0              # wall time load work blocked compute
        self.copy_s = 0.0               # total staging-copy busy time
        self.overlap_s = 0.0            # portion of copy_s hidden by compute
        self.n_prefetch_jobs = 0
        self.n_dropped_prefetch = 0     # dropped for slot pressure
        self.issue_reorders = 0         # jobs issued ahead of an older one
        self.precision_downgrades = 0   # queued hi jobs preempted to lo
        self.upgrades = 0               # idle-link hi re-copies issued
        self.upgrade_bytes = 0          # bytes those re-copies moved
        self.served_lo_expert_steps = 0  # expert-steps computed from the lo
        #                                  pool in place of a hi decision
        #                                  (the accuracy-exposure proxy)
        self.per_stream_bytes = [0] * self.streams
        self._modeled_transfer_s = 0.0  # issued bytes / link_bps
        self._t_first_issue: Optional[float] = None
        self._t_last_commit: Optional[float] = None

    @staticmethod
    def _shutdown_pools(pools):
        """Finalizer target: release every stream's worker thread."""
        for p in pools:
            p.shutdown(wait=False)

    def _stream_of(self, precision: int) -> int:
        """Map a job's precision class to a stream: hi jobs round-robin over
        the first half of the streams, lo jobs over the second half."""
        if self.streams == 1:
            return 0
        is_hi = precision == PREC_HI
        n_hi = (self.streams + 1) // 2
        lo0, n_lo = n_hi, self.streams - n_hi
        self._rr[is_hi] += 1
        if is_hi:
            return self._rr[True] % n_hi
        return lo0 + self._rr[False] % n_lo

    # ---------------- prefetch (async, multi-stream) ----------------
    def set_deadline_clock(self, current_layer: int, per_layer_s: float,
                           period_s: Optional[float] = None):
        """Engine hint from the layer schedule: the decode loop is at
        `current_layer` and one layer takes ~`per_layer_s` of compute, so a
        job for layer L has a `(L - current_layer) * per_layer_s` window of
        link time it can hide before its `wait()` deadline (anything beyond
        that window becomes stall — the downgrade budget).  `period_s` is
        the full layer period *including* load time: the issue pump runs
        once per layer, so each stream is kept fed with one period's worth
        of link bytes to bridge the gap between pumps."""
        self._clock_layer = int(current_layer)
        self._layer_s = float(per_layer_s)
        self._period_s = float(period_s if period_s else per_layer_s)

    def submit_prefetch(self, layer: int, experts: List[int],
                        decisions: np.ndarray, *, current_layer: int,
                        gates: Optional[np.ndarray] = None) -> int:
        """Reserve slots and queue staging copies for predicted experts of a
        future layer.  Returns the number of tasks actually submitted."""
        if gates is None:
            gates = np.zeros(len(experts))
        tasks: List[Tuple[LoadTask, int]] = []
        for e, d, g in zip(experts, decisions, gates):
            if d == PREC_SKIP:
                continue
            is_hi = d == PREC_HI
            key = (layer, int(e))
            if self.cache.lookup(key, is_hi) is not None:
                continue                      # resident or already in flight
            if (is_hi and self.upgrade
                    and self.serves_lo_downgrade(layer, int(e))):
                # lo-substituted expert: its promotion belongs to the
                # idle-link upgrade pass, not the deadline path — a deadline
                # hi prefetch here would re-add the bytes the downgrade shed
                # and stall the wait() barrier the substitution exists to
                # protect
                continue
            if not self.cache.can_admit(is_hi):
                self.n_dropped_prefetch += 1  # slot pressure: skip, don't block
                continue
            slot, _ = self.cache.admit(key, is_hi, current_layer)
            self.cache.begin_inflight(key, is_hi, slot)
            t = LoadTask(layer, int(e), int(d), PREFETCH,
                         self.loader.bytes_fn(int(d)), float(g))
            tasks.append((t, slot))
        if not tasks:
            return 0
        if self.ordered:
            # PR-2 parity path: ONE batched FIFO job per submit call on the
            # single worker, bit-identical to the original scheduler
            for t, _ in tasks:
                self.per_stream_bytes[0] += t.bytes
                if self.link_bps > 0:
                    self._modeled_transfer_s += t.bytes / self.link_bps
            if self._t_first_issue is None:
                self._t_first_issue = time.perf_counter()
            fut = self._pools[0].submit(self._stage_batch,
                                        [t for t, _ in tasks])
            self._jobs.append(_PrefetchJob(tasks, fut, time.perf_counter()))
            self.n_prefetch_jobs += 1
            return len(tasks)
        for t, slot in tasks:
            self._pending.append(StagingJob(t, slot, self._seq,
                                            self._stream_of(t.precision)))
            self._seq += 1
            self.n_prefetch_jobs += 1
        self._pump()
        return len(tasks)

    def _emulate_copy(self, t_start: float, nbytes: int):
        """Occupy the modeled link for the remainder of `nbytes`'s transfer
        time (copy work already done since `t_start` counts against it).
        No-op unless link emulation is on."""
        if not self.emulate_link:
            return
        remain = nbytes / self.link_bps - (time.perf_counter() - t_start)
        if remain > 0:
            time.sleep(remain)

    def _stage_batch(self, tasks: List[LoadTask]):
        """Worker body of one ordered-path batch job (each copy occupies the
        single stream for bytes/link_bps when the link is emulated, so the
        FIFO baseline pays the same modeled link as the budgeted path)."""
        t0 = time.perf_counter()
        staged = []
        for t in tasks:
            tc = time.perf_counter()
            staged.append(self.stage_fn(t.layer, t.expert, t.precision))
            self._emulate_copy(tc, t.bytes)
        return staged, t0, time.perf_counter()

    def _stage_one(self, task: LoadTask):
        """Worker body of one budgeted-path job (one expert copy); with link
        emulation on, the copy occupies its stream for bytes/link_bps."""
        t0 = time.perf_counter()
        staged = self.stage_fn(task.layer, task.expert, task.precision)
        self._emulate_copy(t0, task.bytes)
        return staged, t0, time.perf_counter()

    # ---------------- budgeted issue ----------------
    # The compute-window estimate feeding the budget is a noisy EMA; only
    # issue a hi copy when it fits with 2x headroom, so the hi-vs-lo issue
    # decision doesn't flicker with scheduler jitter (a hi copy that barely
    # fits on paper almost never lands in time on a contended link).
    BUDGET_SAFETY = 0.5

    def _budget_bytes(self, deadline_layer: int) -> float:
        """Modeled link bytes transferable before `deadline_layer`'s wait(),
        discounted by BUDGET_SAFETY to absorb compute-window estimate noise."""
        gap = max(0, deadline_layer - self._clock_layer)
        return gap * self._layer_s * self.link_bps * self.BUDGET_SAFETY

    def _issued_backlog_bytes(self) -> int:
        """Bytes issued to any stream whose copy has not finished yet,
        excluding UPGRADE-reason copies: those are background work a
        deadline prefetch queues behind for at most one transfer, and
        counting them against the deadline budget would let an idle-window
        upgrade demote the very next deadline hi copy — re-creating the
        substitution the pass just repaired."""
        return sum(j.task.bytes for j in self._issued
                   if not j.future.done() and j.task.reason != UPGRADE)

    def _try_downgrade(self, job: StagingJob) -> Optional[StagingJob]:
        """Preempt a queued hi job whose bytes no longer fit the remaining
        link budget before its deadline: cancel the hi reservation and (when
        the lo pool can take it) re-reserve a lo replacement.  Returns the
        replacement job, or None when the job was dropped outright (lo copy
        already resident/in flight, or lo pool full)."""
        key = (job.task.layer, job.task.expert)
        self.cache.cancel_inflight(key, True)
        if self.cache.lookup(key, False) is not None:
            # lo already resident or in flight: the downgrade is served
            self.precision_downgrades += 1
            self.downgraded.add(key)
            if self.upgrade:
                self.lo_substituted.add(key)
            return None
        if not self.cache.can_admit(False):
            # no lo slot either: this is a plain drop, not a downgrade —
            # the layer will blocking-load hi on demand
            self.n_dropped_prefetch += 1
            return None
        self.precision_downgrades += 1
        self.downgraded.add(key)
        if self.upgrade:
            # with the upgrade pass off (PR-4 parity) the set would only
            # accumulate dead state nothing reads until flush()
            self.lo_substituted.add(key)
        slot, _ = self.cache.admit(key, False, self._clock_layer)
        self.cache.begin_inflight(key, False, slot)
        t = LoadTask(job.task.layer, job.task.expert, PREC_LO, PREFETCH,
                     self.loader.bytes_fn(PREC_LO), job.task.gate)
        rep = StagingJob(t, slot, self._seq, self._stream_of(PREC_LO))
        self._seq += 1
        return rep

    def _issue(self, job: StagingJob):
        """Hand one job to its stream's executor and account the issue."""
        job.future = self._pools[job.stream].submit(self._stage_one, job.task)
        self._issued.append(job)
        self.per_stream_bytes[job.stream] += job.task.bytes
        if self.link_bps > 0:
            self._modeled_transfer_s += job.task.bytes / self.link_bps
        if self._t_first_issue is None:
            self._t_first_issue = time.perf_counter()

    def _pump(self, *, force_layer: Optional[int] = None):
        """Issue queued jobs onto their streams (and every queued job
        targeting `force_layer`, ahead of a wait barrier).  Issue order per
        stream: nearest deadline layer first, biggest gate within it, then
        FIFO.  Each stream is kept fed with at most ~one layer's worth of
        link bytes (`link_bps * per_layer_s`); the rest stays queued here,
        where it can still be reordered — and where a queued hi job that no
        longer fits the link budget before its deadline is downgraded to a
        lo replacement.  In-flight copies are never preempted.  Once every
        queued deadline job is placed, leftover stream budget goes to the
        idle-link upgrade pass (`_pump_upgrades`)."""
        if self.ordered:
            return
        had_deadline_work = bool(self._pending)
        # per-stream issued-but-unfinished bytes (the stream's fed backlog)
        backlog = [0] * self.streams
        for j in self._issued:
            if not j.future.done():
                backlog[j.stream] += j.task.bytes
        # No feed estimate (no deadline clock yet, or an unmodeled link)
        # means *unlimited* feed: every queued job issues immediately.  A
        # zero here would degenerate the threshold below to one byte and
        # serialize each stream to a single outstanding copy.
        feed = (self.link_bps * max(self._period_s, self._layer_s)
                if self.link_bps > 0 and self._layer_s > 0
                else float("inf"))
        progress = True
        while progress and self._pending:
            progress = False
            for stream in range(self.streams):
                cands = [j for j in self._pending if j.stream == stream]
                if not cands:
                    continue
                forced = (force_layer is not None
                          and any(j.task.layer == force_layer for j in cands))
                if backlog[stream] >= max(feed, 1.0) and not forced:
                    continue            # stream fed; keep the rest reorderable
                if forced:
                    cands = [j for j in cands if j.task.layer == force_layer]
                best = min(cands,
                           key=lambda j: (j.task.layer, -j.task.gate, j.seq))
                if best.seq != min(j.seq for j in self._pending
                                   if j.stream == stream):
                    self.issue_reorders += 1
                self._pending.remove(best)
                # budget preemption applies only while the deadline is still
                # ahead (gap >= 1 layer): a job collected by its own wait()
                # barrier must issue as requested — the downgrade decision
                # belongs to the contention window before the deadline
                if (best.task.precision == PREC_HI and self.link_bps > 0
                        and self._layer_s > 0 and not forced
                        and best.task.layer > self._clock_layer):
                    budget = self._budget_bytes(best.task.layer)
                    if self._issued_backlog_bytes() + best.task.bytes > budget:
                        rep = self._try_downgrade(best)
                        if rep is not None:
                            self._pending.append(rep)
                        progress = True
                        continue
                self._issue(best)
                backlog[best.stream] += best.task.bytes
                progress = True
        self._pump_upgrades(backlog, had_deadline_work=had_deadline_work)

    def _pump_upgrades(self, backlog: List[int], *,
                       had_deadline_work: bool = False):
        """Idle-link upgrade pass (ROADMAP's upgrade-in-place): when no
        queued deadline work remains and a hi stream is fully idle,
        re-issue hi copies for lo-substituted experts — hottest Eq. 3 cache
        priority first, at most one in flight per stream.  Upgrade jobs are
        created directly at issue time (never queued), so a deadline
        prefetch submitted afterwards is always pumped first: an upgrade
        can only ride link time that would otherwise idle, a deadline copy
        arriving mid-upgrade waits at most one transfer, and the `wait()`
        barrier never blocks on one.  The hi copy lands via the normal
        precision-keyed in-flight reservation *next to* the resident lo
        copy; once committed, `serves_lo_downgrade` flips off and the
        compute path switches back to hi.

        Hysteresis: upgrades wait for TWO consecutive pumps that saw no
        deadline work at all (queued at entry or still queued now).  During
        a contention burst the pump's queue drains and refills every layer,
        and an upgrade issued into such a momentary gap occupies its stream
        just as the next layer's deadline prefetches arrive — the
        hysteresis keeps the pass out of the burst entirely and costs one
        pump cycle of recovery latency once the link genuinely idles."""
        if self._pending or had_deadline_work:
            self._idle_pumps = 0
            return
        self._idle_pumps += 1
        if not self.upgrade or self._idle_pumps < 2:
            return
        cands = []
        for key in list(self.lo_substituted):
            if self.cache.lookup(key, False) is None:
                # the lo stand-in was evicted: nothing to upgrade in place
                self.lo_substituted.discard(key)
                continue
            if (self.cache.lookup(key, True) is not None
                    or self.cache.is_inflight(key, True)):
                continue                # hi already landed or landing
            if self.cache.is_inflight(key, False):
                # the lo replacement itself is still in flight: re-issuing
                # the hi bytes now would undo the preemption that shed them
                continue
            cands.append(key)
        if not cands:
            return
        # fleet-blended cache priority (cache.priority): a fleet-hot expert
        # is re-promoted before one only this sequence has touched
        prio = lambda k: self.cache.priority(k, self._clock_layer)  # noqa: E731
        cands.sort(key=lambda k: -prio(k))
        hi_bytes = self.loader.bytes_fn(PREC_HI)
        n_hi = 1 if self.streams == 1 else (self.streams + 1) // 2
        for key in cands:
            # at most ONE upgrade in flight per stream, issued onto the
            # first IDLE hi stream (not the round-robin pick, which could
            # map a candidate to a busy stream while another hi stream
            # idles): an in-flight copy is never preempted, so a deadline
            # prefetch arriving mid-upgrade waits at most one transfer.
            # Deliberately NOT feed-gated: in the offload regime one hi
            # copy often exceeds a layer-period of link bytes, and a feed
            # veto would starve re-promotion forever on a fully idle link —
            # the single-copy cap IS the budget bound
            stream = next((s for s in range(n_hi) if backlog[s] == 0), None)
            if stream is None:
                break                   # every hi stream busy this pump
            if not self.cache.can_admit(True):
                break                   # hi pool has no evictable slot
            # an upgrade must never evict a hi resident at least as hot as
            # the expert it promotes: that trades one exposure for another
            # and feeds an evict -> miss -> downgrade -> upgrade churn
            # cycle under a tight hi pool
            victim_p = self.cache.peek_victim_priority(True,
                                                       self._clock_layer)
            if victim_p is not None and victim_p >= prio(key):
                break                   # candidates are priority-sorted
            slot, _ = self.cache.admit(key, True, self._clock_layer)
            self.cache.begin_inflight(key, True, slot)
            t = LoadTask(key[0], key[1], PREC_HI, UPGRADE, hi_bytes)
            job = StagingJob(t, slot, self._seq, stream)
            self._seq += 1
            self._issue(job)
            backlog[stream] += hi_bytes
            self.upgrades += 1
            self.upgrade_bytes += hi_bytes

    # ---------------- barriers ----------------
    def _collect_batch(self, job: _PrefetchJob, entries: List,
                       *, blocking_for_layer: bool):
        """Await one ordered-path batch job and queue its landed entries."""
        t_wait = time.perf_counter()
        staged, t0, t1 = job.future.result()
        if blocking_for_layer:
            self.stall_s += max(0.0, time.perf_counter() - t_wait)
        busy = max(0.0, t1 - t0)
        self.copy_s += busy
        self.overlap_s += min(busy, max(0.0, t_wait - t0))
        for (task, slot), buf in zip(job.tasks, staged):
            self._land(task, slot, buf, entries, stream=0)

    def _collect_job(self, job: StagingJob, entries: List,
                     *, blocking_for_layer: bool):
        """Await one budgeted-path job and queue its landed entry."""
        t_wait = time.perf_counter()
        staged, t0, t1 = job.future.result()
        if blocking_for_layer:
            self.stall_s += max(0.0, time.perf_counter() - t_wait)
        busy = max(0.0, t1 - t0)
        self.copy_s += busy
        self.overlap_s += min(busy, max(0.0, t_wait - t0))
        self._land(job.task, job.slot, staged, entries, stream=job.stream)

    def _land(self, task: LoadTask, slot: int, buf, entries: List, *,
              stream: int):
        """Clear the in-flight reservation and queue the staged buffer for
        the batched commit (skipping entries whose reservation was flushed
        between submit and commit)."""
        is_hi = task.precision == PREC_HI
        self.cache.end_inflight((task.layer, task.expert), is_hi)
        # the reservation may have been flushed by a new_sequence between
        # submit and commit; only write slots the entry still owns
        if self.cache.lookup((task.layer, task.expert), is_hi) == slot:
            entries.append((task, slot, buf))
            self.loader.loaded_bytes += task.bytes
            self.loader.n_loads[task.precision] += 1
            if is_hi:
                # a landed hi copy ends any lo substitution for this expert:
                # the compute path must serve hi, not a stale downgrade marker
                self.lo_substituted.discard((task.layer, task.expert))
                self.downgraded.discard((task.layer, task.expert))

    def wait(self, layer: int):
        """Barrier before computing `layer`: commit every finished job, and
        block on (then commit) any queued or in-flight job that targets
        `layer`.  All collected jobs land in ONE batched pool scatter.
        Upgrade re-copies never block the barrier — they are background
        work; the layer keeps serving the lo stand-in until the hi copy has
        actually committed."""
        entries: List = []
        if self.ordered:
            remaining = []
            for job in self._jobs:
                needed = any(t.layer == layer for t, _ in job.tasks)
                if needed or job.future.done():
                    self._collect_batch(job, entries,
                                        blocking_for_layer=needed)
                else:
                    remaining.append(job)
            self._jobs = remaining
        else:
            self._pump(force_layer=layer)
            remaining = []
            for job in self._issued:
                needed = (job.task.layer == layer
                          and job.task.reason != UPGRADE)
                if needed or job.future.done():
                    self._collect_job(job, entries, blocking_for_layer=needed)
                else:
                    remaining.append(job)
            self._issued = remaining
            self._pump()
        if entries:
            self.commit_fn(entries)
            self._t_last_commit = time.perf_counter()

    def wait_all(self):
        """Commit every queued and in-flight job without attributing stall
        (sequence/batch boundary, not a compute barrier)."""
        entries: List = []
        for job in self._jobs:
            self._collect_batch(job, entries, blocking_for_layer=False)
        self._jobs = []
        while self._pending or self._issued:
            for stream in range(self.streams):
                cands = [j for j in self._pending if j.stream == stream]
                for j in sorted(cands, key=lambda j: (j.task.layer,
                                                      -j.task.gate, j.seq)):
                    self._pending.remove(j)
                    self._issue(j)
            for job in self._issued:
                self._collect_job(job, entries, blocking_for_layer=False)
            self._issued = []
        if entries:
            self.commit_fn(entries)
            self._t_last_commit = time.perf_counter()

    def flush(self):
        """Commit everything in flight (sequence/batch boundary)."""
        self.wait_all()
        self.downgraded.clear()
        self.lo_substituted.clear()

    def retire_layer(self, layer: int):
        """Drop per-token downgrade markers once `layer`'s compute consumed
        them.  With the upgrade pass OFF this restores the PR-4 contract —
        a later decode step's hi request for the same expert blocking-loads
        hi again rather than silently keep serving lo.  With the upgrade
        pass ON the substitution instead persists in `lo_substituted` until
        a background hi re-copy lands (`serves_lo_downgrade` tracks that),
        keeping the promotion off the critical path."""
        self.downgraded = {k for k in self.downgraded if k[0] != layer}

    def serves_lo_downgrade(self, layer: int, expert: int) -> bool:
        """True when (layer, expert)'s hi copy was downgraded away and its
        lo stand-in is resident — the compute path should read the lo pool
        instead of blocking on an on-demand hi load.

        Upgrade pass ON: the substitution persists across decode steps and
        ends the moment a hi copy has fully landed next to the lo one (hi
        resident and no longer in flight) or the lo copy is evicted.
        Upgrade pass OFF (PR-4 parity): only the per-token `downgraded`
        markers count, retired each layer by `retire_layer`."""
        key = (layer, expert)
        if self.upgrade:
            if key not in self.lo_substituted:
                return False
            if self.cache.lookup(key, False) is None:
                self.lo_substituted.discard(key)    # lo stand-in evicted
                return False
            if (self.cache.lookup(key, True) is not None
                    and not self.cache.is_inflight(key, True)):
                # upgrade complete: hi bytes committed beside the lo copy
                self.lo_substituted.discard(key)
                return False
            return True
        return (key in self.downgraded
                and self.cache.lookup(key, False) is not None)

    # ---------------- on-demand (blocking, batched) ----------------
    def drain_on_demand(self, tasks: List[LoadTask],
                        current_layer: int) -> List[Tuple[LoadTask, int]]:
        """Execute the current layer's miss set: one staging gather per task
        on the caller's thread (these block compute — that's the stall the
        stats record; under link emulation each copy also occupies the link
        for bytes/rate) and a single batched commit.  Hi tasks whose expert
        was downgraded at issue time (lo replacement resident) are skipped —
        the compute path serves them from the lo pool.  Misses stay on the
        caller's thread rather than the prefetch streams on purpose: they
        are due *now*, and queueing them behind speculative future-layer
        copies would invert the deadline order the pump maintains."""
        # cheap skip checks run BEFORE the stall timer starts: a layer whose
        # miss set is empty or fully resident/downgraded must contribute
        # exactly 0.0 stall, not a timer epsilon per layer (which drifts
        # load_stall_s upward on hit-heavy runs and pollutes the bench gate)
        todo = []
        for t in tasks:
            is_hi = t.precision == PREC_HI
            if is_hi and self.serves_lo_downgrade(t.layer, t.expert):
                continue  # issue-time downgrade: compute reads the lo copy
            if self.cache.lookup((t.layer, t.expert), is_hi) is not None:
                continue  # duplicate across batch slots / raced with prefetch
            todo.append(t)
        if not todo:
            return []
        t_start = time.perf_counter()
        entries, done = [], []
        for t in todo:
            is_hi = t.precision == PREC_HI
            key = (t.layer, t.expert)
            if self.cache.lookup(key, is_hi) is not None:
                continue  # duplicate within this very miss set
            try:
                slot, _ = self.cache.admit(key, is_hi, current_layer)
            except CacheStarvation:
                # every candidate victim is an in-flight prefetch: land them,
                # clearing their reservations, then retry
                self.wait_all()
                slot, _ = self.cache.admit(key, is_hi, current_layer)
            tc = time.perf_counter()
            buf = self.stage_fn(t.layer, t.expert, t.precision)
            self._emulate_copy(tc, t.bytes)
            entries.append((t, slot, buf))
            self.loader.loaded_bytes += t.bytes
            self.loader.n_loads[t.precision] += 1
            # on-demand copies occupy the modeled link like any other
            # transfer; without this, miss-heavy runs under-report
            # link_utilization vs the simulator's timeline
            if self.link_bps > 0:
                self._modeled_transfer_s += t.bytes / self.link_bps
                if self._t_first_issue is None:
                    self._t_first_issue = tc
            done.append((t, slot))
        if entries:
            self.commit_fn(entries)
            self._t_last_commit = time.perf_counter()
        self.stall_s += time.perf_counter() - t_start
        return done

    # ---------------- observability ----------------
    def link_utilization(self) -> float:
        """Share of the submit→last-commit window the modeled link spent
        busy (issued bytes / link_bps over the wall-clock window)."""
        if (self._t_first_issue is None or self._t_last_commit is None
                or self.link_bps <= 0):
            return 0.0
        window = self._t_last_commit - self._t_first_issue
        if window <= 0:
            return 0.0
        return min(1.0, self._modeled_transfer_s / window)

    def stats(self) -> dict:
        """JSON-serializable staging counters (see docs/METRICS.md)."""
        return {
            "load_stall_s": self.stall_s,
            "copy_s": self.copy_s,
            "overlap_s": self.overlap_s,
            "overlap_fraction": (self.overlap_s / self.copy_s
                                 if self.copy_s > 0 else 0.0),
            "prefetch_jobs": self.n_prefetch_jobs,
            "dropped_prefetch": self.n_dropped_prefetch,
            "streams": self.streams,
            "per_stream_bytes": list(self.per_stream_bytes),
            "issue_reorders": self.issue_reorders,
            "precision_downgrades": self.precision_downgrades,
            "upgrades": self.upgrades,
            "upgrade_bytes": self.upgrade_bytes,
            "served_lo_expert_steps": self.served_lo_expert_steps,
            "link_utilization": self.link_utilization(),
            "link_gbps": self.link_bps / 1e9,
        }

    def shutdown(self):
        """Release every stream's worker thread (idempotent)."""
        self._finalizer()


class AsyncExpertScheduler(StagingEngine):
    """Compatibility alias: the PR-2 single-worker FIFO scheduler is exactly
    ``StagingEngine(streams=1, ordered=True)`` (no link budget, no
    downgrades, batch jobs issued in submit order)."""

    def __init__(self, loader: DynamicExpertLoader,
                 stage_fn: Callable[[int, int, int], dict],
                 commit_fn: Callable[[List[Tuple[LoadTask, int, dict]]], None],
                 *, max_workers: int = 1):
        """`max_workers` is accepted for API compatibility (the ordered path
        always serializes on one worker, as PR 2 did)."""
        del max_workers
        super().__init__(loader, stage_fn, commit_fn, streams=1, ordered=True)
