"""Token-level Dynamic Expert Loader (HOBBIT §3.2): Expert Scorer + Task
Queue + Expert Scheduler.

On a cache miss the Expert Scorer turns gate magnitudes into per-expert
precision decisions (Eq. 2 + T1/T2); the scheduler drains the queue,
fetching weights from host storage via a caller-provided fetch function and
admitting them into the cache (which may evict).  On-demand tasks are
blocking for the current layer; prefetch tasks are overlapped (their cost is
accounted to the simulated timeline, not the critical path, when they finish
before the layer that needs them begins — see simulator.py).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

import numpy as np

from repro.core.cache import MultidimensionalCache
from repro.core.scoring import (PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                                precision_decisions)

ON_DEMAND, PREFETCH = "on_demand", "prefetch"


@dataclasses.dataclass
class LoadTask:
    layer: int
    expert: int
    precision: int              # PREC_HI | PREC_LO
    reason: str                 # ON_DEMAND | PREFETCH
    bytes: int = 0              # filled by the scheduler from the cost model


@dataclasses.dataclass
class LoadReport:
    tasks: List[LoadTask]
    skipped: List[int]          # expert ids skipped this layer (score > T2)
    hit_slots: List[Tuple[int, int, int]]   # (expert, precision, slot)


class DynamicExpertLoader:
    def __init__(self, cache: MultidimensionalCache, th: Thresholds,
                 fetch_fn: Callable[[int, int, int, int], None],
                 bytes_fn: Callable[[int], int]):
        """fetch_fn(layer, expert, precision, slot): writes the expert weights
        into the assigned device pool slot (engine-provided closure).
        bytes_fn(precision) -> transfer size."""
        self.cache = cache
        self.th = th
        self.fetch_fn = fetch_fn
        self.bytes_fn = bytes_fn
        self.queue: Deque[LoadTask] = deque()
        self.loaded_bytes = 0
        self.n_loads = {PREC_HI: 0, PREC_LO: 0}
        self.n_skips = 0

    # ---------------- Expert Scorer ----------------
    def new_layer(self):
        """Reset hard pins at a layer boundary.  Batched decoding calls this
        once per layer, then scores every slot's expert set with
        ``clear_pins=False`` so the union of all slots' experts stays
        protected while the layer executes."""
        self.cache.hard_pinned.clear()

    def score_and_enqueue(self, layer: int, experts: List[int],
                          gate_vals: np.ndarray, *,
                          clear_pins: bool = True) -> LoadReport:
        """Handle the on-demand expert set of one MoE layer for one token
        (one batch slot)."""
        dec = precision_decisions(gate_vals, self.th)
        # hard pins protect only the layer being executed; earlier layers'
        # experts already ran and may be evicted again
        if clear_pins:
            self.cache.hard_pinned.clear()
        tasks, skipped, hits = [], [], []
        for e, d in zip(experts, dec):
            if d == PREC_SKIP:
                skipped.append(e)
                self.n_skips += 1
                continue
            is_hi = d == PREC_HI
            # the experts of the layer being executed must never be evicted
            # by a concurrent prefetch admission
            self.cache.pin((layer, e), is_hi, hard=True)
            slot = self.cache.probe((layer, e), is_hi)
            if slot is not None:
                hits.append((e, d, slot))
            else:
                t = LoadTask(layer, e, int(d), ON_DEMAND, self.bytes_fn(int(d)))
                tasks.append(t)
                self.queue.append(t)
        return LoadReport(tasks, skipped, hits)

    def enqueue_prefetch(self, layer: int, experts: List[int],
                         decisions: np.ndarray):
        for e, d in zip(experts, decisions):
            if d == PREC_SKIP:
                continue
            if self.cache.lookup((layer, e), d == PREC_HI) is None:
                self.queue.append(
                    LoadTask(layer, e, int(d), PREFETCH, self.bytes_fn(int(d))))

    # ---------------- Expert Scheduler ----------------
    def drain(self, current_layer: int) -> List[Tuple[LoadTask, int]]:
        """Execute all queued tasks (on-demand first).  Returns
        [(task, slot)] in execution order."""
        done = []
        ordered = sorted(self.queue, key=lambda t: t.reason != ON_DEMAND)
        self.queue.clear()
        for t in ordered:
            is_hi = t.precision == PREC_HI
            if self.cache.lookup((t.layer, t.expert), is_hi) is not None:
                continue  # raced: already resident (e.g. dup prefetch)
            slot, _evicted = self.cache.admit((t.layer, t.expert), is_hi,
                                              current_layer)
            self.fetch_fn(t.layer, t.expert, t.precision, slot)
            self.loaded_bytes += t.bytes
            self.n_loads[t.precision] += 1
            done.append((t, slot))
        return done
