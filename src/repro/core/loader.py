"""Token-level Dynamic Expert Loader (HOBBIT §3.2): Expert Scorer + Task
Queue + Expert Scheduler.

On a cache miss the Expert Scorer turns gate magnitudes into per-expert
precision decisions (Eq. 2 + T1/T2); the scheduler executes load tasks,
fetching weights from host storage and admitting them into the cache (which
may evict).  Two schedulers exist:

  * ``DynamicExpertLoader.drain`` — the original synchronous scheduler (one
    fetch per task on the caller's thread).  Kept as the reference path and
    for the engine's legacy per-expert decode.
  * ``AsyncExpertScheduler`` — the wall-clock-real scheduler: PREFETCH tasks
    reserve their cache slot immediately (in-flight reservation, so nothing
    can race them) and stage their weight bytes on a background executor
    while the current layer computes (double-buffered staging); a
    ``wait(layer)`` barrier commits staged writes before the layer that
    needs them reads the pools.  ON_DEMAND tasks stay blocking but are
    batched into a single scatter per pool tensor (``commit_fn``).

AsyncExpertScheduler lifecycle of one prefetched expert::

    submit_prefetch(layer, experts, decisions)        [main thread]
        -> cache.admit() assigns a slot NOW            "reserve"
        -> cache.begin_inflight(key, slot)             eviction-proof
        -> executor stages host bytes in background    overlaps compute
    wait(layer)  (barrier before the layer runs)      [main thread]
        -> future.result() (blocks only if the copy is late -> stall_s)
        -> cache.end_inflight(key)                     "commit" begins
        -> commit_fn(entries): ONE batched scatter per pool tensor
    (wait_all()/flush() at sequence boundaries commit leftovers without
    attributing stall)

Invariants: cache metadata is touched ONLY on the main thread; the
background worker sees host storage and private staging buffers, never the
pools; an in-flight entry owns its slot from submit to commit, so a staged
write can never land on a reassigned slot (see core/cache.py for the
reservation state machine).  The async scheduler shares the loader's cache
and byte/load counters so `engine.stats()` is one source of truth either
way.  Metric definitions: docs/METRICS.md; system map:
docs/ARCHITECTURE.md.
"""

from __future__ import annotations

import dataclasses
import time
import weakref
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cache import CacheStarvation, MultidimensionalCache
from repro.core.scoring import (PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                                precision_decisions)

ON_DEMAND, PREFETCH = "on_demand", "prefetch"


@dataclasses.dataclass
class LoadTask:
    layer: int
    expert: int
    precision: int              # PREC_HI | PREC_LO
    reason: str                 # ON_DEMAND | PREFETCH
    bytes: int = 0              # filled by the scheduler from the cost model


@dataclasses.dataclass
class LoadReport:
    tasks: List[LoadTask]
    skipped: List[int]          # expert ids skipped this layer (score > T2)
    hit_slots: List[Tuple[int, int, int]]   # (expert, precision, slot)


class DynamicExpertLoader:
    def __init__(self, cache: MultidimensionalCache, th: Thresholds,
                 fetch_fn: Callable[[int, int, int, int], None],
                 bytes_fn: Callable[[int], int]):
        """fetch_fn(layer, expert, precision, slot): writes the expert weights
        into the assigned device pool slot (engine-provided closure).
        bytes_fn(precision) -> transfer size."""
        self.cache = cache
        self.th = th
        self.fetch_fn = fetch_fn
        self.bytes_fn = bytes_fn
        self.queue: Deque[LoadTask] = deque()
        self.loaded_bytes = 0
        self.n_loads = {PREC_HI: 0, PREC_LO: 0}
        self.n_skips = 0

    # ---------------- Expert Scorer ----------------
    def new_layer(self):
        """Reset hard pins at a layer boundary.  Batched decoding calls this
        once per layer, then scores every slot's expert set with
        ``clear_pins=False`` so the union of all slots' experts stays
        protected while the layer executes."""
        self.cache.hard_pinned.clear()

    def score_and_enqueue(self, layer: int, experts: List[int],
                          gate_vals: np.ndarray, *,
                          clear_pins: bool = True) -> LoadReport:
        """Handle the on-demand expert set of one MoE layer for one token
        (one batch slot)."""
        dec = precision_decisions(gate_vals, self.th)
        # hard pins protect only the layer being executed; earlier layers'
        # experts already ran and may be evicted again
        if clear_pins:
            self.cache.hard_pinned.clear()
        tasks, skipped, hits = [], [], []
        for e, d in zip(experts, dec):
            if d == PREC_SKIP:
                skipped.append(e)
                self.n_skips += 1
                continue
            is_hi = d == PREC_HI
            # the experts of the layer being executed must never be evicted
            # by a concurrent prefetch admission
            self.cache.pin((layer, e), is_hi, hard=True)
            slot = self.cache.probe((layer, e), is_hi)
            if slot is not None:
                hits.append((e, d, slot))
            else:
                t = LoadTask(layer, e, int(d), ON_DEMAND, self.bytes_fn(int(d)))
                tasks.append(t)
                self.queue.append(t)
        return LoadReport(tasks, skipped, hits)

    def enqueue_prefetch(self, layer: int, experts: List[int],
                         decisions: np.ndarray):
        for e, d in zip(experts, decisions):
            if d == PREC_SKIP:
                continue
            if self.cache.lookup((layer, e), d == PREC_HI) is None:
                self.queue.append(
                    LoadTask(layer, e, int(d), PREFETCH, self.bytes_fn(int(d))))

    def take_queued(self) -> List[LoadTask]:
        """Hand the queued tasks to an external scheduler (clears the queue)."""
        tasks = list(self.queue)
        self.queue.clear()
        return tasks

    # ---------------- Expert Scheduler ----------------
    def drain(self, current_layer: int) -> List[Tuple[LoadTask, int]]:
        """Execute all queued tasks (on-demand first).  Returns
        [(task, slot)] in execution order."""
        done = []
        ordered = sorted(self.queue, key=lambda t: t.reason != ON_DEMAND)
        self.queue.clear()
        for t in ordered:
            is_hi = t.precision == PREC_HI
            if self.cache.lookup((t.layer, t.expert), is_hi) is not None:
                continue  # raced: already resident (e.g. dup prefetch)
            slot, _evicted = self.cache.admit((t.layer, t.expert), is_hi,
                                              current_layer)
            self.fetch_fn(t.layer, t.expert, t.precision, slot)
            self.loaded_bytes += t.bytes
            self.n_loads[t.precision] += 1
            done.append((t, slot))
        return done


# --------------------------------------------------------------------------
# asynchronous scheduler (double-buffered prefetch staging)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _PrefetchJob:
    tasks: List[Tuple[LoadTask, int]]       # (task, reserved slot)
    future: Future                          # -> (staged, t_start, t_end)
    t_submit: float


class AsyncExpertScheduler:
    """Executes load tasks so that prefetch copies overlap compute in wall
    clock.

    Division of labour with the engine:
      stage_fn(layer, expert, precision) -> staged host buffers (the
          host-side gather — the expensive part of the transfer — safe to run
          on a background thread because it only *reads* host storage).
      commit_fn(entries) with entries = [(task, slot, staged)] -> writes all
          staged buffers into the device pools, one scatter per pool tensor
          (main thread only, so pool arrays are never mutated concurrently
          with compute).

    Cache metadata is only ever touched on the main thread: prefetch
    admission happens at submit time (with an in-flight reservation so
    lookup/eviction can't race it); the background thread sees nothing but
    host storage and its private staging buffers.
    """

    def __init__(self, loader: DynamicExpertLoader,
                 stage_fn: Callable[[int, int, int], dict],
                 commit_fn: Callable[[List[Tuple[LoadTask, int, dict]]], None],
                 *, max_workers: int = 1):
        self.loader = loader
        self.cache = loader.cache
        self.stage_fn = stage_fn
        self.commit_fn = commit_fn
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="expert-prefetch")
        # release the worker thread when the scheduler (engine) is collected
        self._finalizer = weakref.finalize(self, self._pool.shutdown, False)
        self._jobs: List[_PrefetchJob] = []
        # observability (engine.stats() reads these)
        self.stall_s = 0.0              # wall time load work blocked compute
        self.copy_s = 0.0               # total staging-copy busy time
        self.overlap_s = 0.0            # portion of copy_s hidden by compute
        self.n_prefetch_jobs = 0
        self.n_dropped_prefetch = 0     # dropped for slot pressure

    # ---------------- prefetch (async, double-buffered) ----------------
    def submit_prefetch(self, layer: int, experts: List[int],
                        decisions: np.ndarray, *, current_layer: int) -> int:
        """Reserve slots and start staging copies for predicted experts of a
        future layer.  Returns the number of tasks actually submitted."""
        tasks: List[Tuple[LoadTask, int]] = []
        for e, d in zip(experts, decisions):
            if d == PREC_SKIP:
                continue
            is_hi = d == PREC_HI
            key = (layer, int(e))
            if self.cache.lookup(key, is_hi) is not None:
                continue                      # resident or already in flight
            if not self.cache.can_admit(is_hi):
                self.n_dropped_prefetch += 1  # slot pressure: skip, don't block
                continue
            slot, _ = self.cache.admit(key, is_hi, current_layer)
            self.cache.begin_inflight(key, is_hi, slot)
            t = LoadTask(layer, int(e), int(d), PREFETCH,
                         self.loader.bytes_fn(int(d)))
            tasks.append((t, slot))
        if tasks:
            fut = self._pool.submit(self._stage_job, [t for t, _ in tasks])
            self._jobs.append(_PrefetchJob(tasks, fut, time.perf_counter()))
            self.n_prefetch_jobs += 1
        return len(tasks)

    def _stage_job(self, tasks: List[LoadTask]):
        t0 = time.perf_counter()
        staged = [self.stage_fn(t.layer, t.expert, t.precision) for t in tasks]
        return staged, t0, time.perf_counter()

    # ---------------- barriers ----------------
    def _collect_job(self, job: _PrefetchJob, entries: List,
                     *, blocking_for_layer: bool):
        t_wait = time.perf_counter()
        staged, t0, t1 = job.future.result()
        if blocking_for_layer:
            self.stall_s += max(0.0, time.perf_counter() - t_wait)
        busy = max(0.0, t1 - t0)
        self.copy_s += busy
        self.overlap_s += min(busy, max(0.0, t_wait - t0))
        for (task, slot), buf in zip(job.tasks, staged):
            is_hi = task.precision == PREC_HI
            self.cache.end_inflight((task.layer, task.expert), is_hi)
            # the reservation may have been flushed by a new_sequence between
            # submit and commit; only write slots the entry still owns
            if self.cache.lookup((task.layer, task.expert), is_hi) == slot:
                entries.append((task, slot, buf))
                self.loader.loaded_bytes += task.bytes
                self.loader.n_loads[task.precision] += 1

    def wait(self, layer: int):
        """Barrier before computing `layer`: commit every finished job, and
        block on (then commit) any in-flight job that targets `layer`.  All
        collected jobs land in ONE batched pool scatter."""
        remaining, entries = [], []
        for job in self._jobs:
            needed = any(t.layer == layer for t, _ in job.tasks)
            if needed or job.future.done():
                self._collect_job(job, entries, blocking_for_layer=needed)
            else:
                remaining.append(job)
        self._jobs = remaining
        if entries:
            self.commit_fn(entries)

    def wait_all(self):
        entries = []
        for job in self._jobs:
            self._collect_job(job, entries, blocking_for_layer=False)
        self._jobs = []
        if entries:
            self.commit_fn(entries)

    def flush(self):
        """Commit everything in flight (sequence/batch boundary)."""
        self.wait_all()

    # ---------------- on-demand (blocking, batched) ----------------
    def drain_on_demand(self, tasks: List[LoadTask],
                        current_layer: int) -> List[Tuple[LoadTask, int]]:
        """Execute the current layer's miss set: one staging gather per task
        on the caller's thread (these block compute — that's the stall the
        stats record) and a single batched commit."""
        t_start = time.perf_counter()
        entries, done = [], []
        for t in tasks:
            is_hi = t.precision == PREC_HI
            key = (t.layer, t.expert)
            if self.cache.lookup(key, is_hi) is not None:
                continue  # duplicate across batch slots / raced with prefetch
            try:
                slot, _ = self.cache.admit(key, is_hi, current_layer)
            except CacheStarvation:
                # every candidate victim is an in-flight prefetch: land them,
                # clearing their reservations, then retry
                self.wait_all()
                slot, _ = self.cache.admit(key, is_hi, current_layer)
            entries.append((t, slot, self.stage_fn(t.layer, t.expert,
                                                   t.precision)))
            self.loader.loaded_bytes += t.bytes
            self.loader.n_loads[t.precision] += 1
            done.append((t, slot))
        if entries:
            self.commit_fn(entries)
        self.stall_s += time.perf_counter() - t_start
        return done

    # ---------------- observability ----------------
    def stats(self) -> dict:
        return {
            "load_stall_s": self.stall_s,
            "copy_s": self.copy_s,
            "overlap_s": self.overlap_s,
            "overlap_fraction": (self.overlap_s / self.copy_s
                                 if self.copy_s > 0 else 0.0),
            "prefetch_jobs": self.n_prefetch_jobs,
            "dropped_prefetch": self.n_dropped_prefetch,
        }

    def shutdown(self):
        self._finalizer()
