"""Sequence-level multidimensional expert caching policy (HOBBIT §3.4).

Priority of expert t (higher = keep):

    p_t = w_lru * R_t/T + w_lfu * F_t/T + w_lhu * H_t/T + w_fld * fld_t   (Eq. 3)
    fld_t = 1 - ((l_t - l_i + L) % L) / L

R_t last-used token index, F_t sequence-level use count, H_t sequence-level
*high-precision* use count, T current token counter, l_i the layer currently
executing, L total layers.  LRU/LFU/LHU/FLD are the corner cases of the
weight vector; records reset at sequence boundaries (sequence-level policy).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

ExpertKey = Tuple[int, int]  # (layer, expert)


@dataclasses.dataclass(frozen=True)
class PolicyWeights:
    lru: float = 0.25
    lfu: float = 0.25
    lhu: float = 0.25
    fld: float = 0.25

    def __post_init__(self):
        tot = self.lru + self.lfu + self.lhu + self.fld
        assert abs(tot - 1.0) < 1e-6, f"weights must sum to 1, got {tot}"


LRU = PolicyWeights(1.0, 0.0, 0.0, 0.0)
LFU = PolicyWeights(0.0, 1.0, 0.0, 0.0)
LHU = PolicyWeights(0.0, 0.0, 1.0, 0.0)
FLD = PolicyWeights(0.0, 0.0, 0.0, 1.0)
# default blend; benchmarks/cache_policies.py tunes this on a calibration set
MULTIDIM = PolicyWeights(0.35, 0.25, 0.25, 0.15)

NAMED_POLICIES = {"lru": LRU, "lfu": LFU, "lhu": LHU, "fld": FLD,
                  "multidim": MULTIDIM}


class PolicyRecords:
    """Per-expert usage records for Eq. 3 (host-side, O(1) per event)."""

    def __init__(self, num_layers: int):
        self.num_layers = num_layers
        self.reset()

    def reset(self):
        """Called at each new sequence (sequence-level records)."""
        self.t = 1
        self.last_used: Dict[ExpertKey, int] = {}
        self.freq: Dict[ExpertKey, int] = {}
        self.hi_freq: Dict[ExpertKey, int] = {}

    def advance_token(self):
        self.t += 1

    def on_use(self, key: ExpertKey, high_precision: bool):
        self.last_used[key] = self.t
        self.freq[key] = self.freq.get(key, 0) + 1
        if high_precision:
            self.hi_freq[key] = self.hi_freq.get(key, 0) + 1

    def priority(self, key: ExpertKey, w: PolicyWeights, current_layer: int) -> float:
        t = max(self.t, 1)
        p_lru = self.last_used.get(key, 0) / t
        p_lfu = self.freq.get(key, 0) / t
        p_lhu = self.hi_freq.get(key, 0) / t
        l = self.num_layers
        p_fld = 1.0 - (((key[0] - current_layer + l) % l) / l)
        return w.lru * p_lru + w.lfu * p_lfu + w.lhu * p_lhu + w.fld * p_fld
