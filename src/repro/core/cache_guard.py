"""TSan-lite runtime complement to the static thread-confinement checker.

`InstrumentedCache` is a drop-in `MultidimensionalCache` that records the
thread calling every metadata *mutator* (the methods annotated
``# owner: main-thread`` in core/cache.py) and raises
`ThreadConfinementError` the moment one runs off the owner thread.  The
static checker (tools/analysis/thread_confinement.py) proves the absence of
*provable* call paths; this guard catches anything the AST cannot see —
callables smuggled through data structures, monkeypatching, future
refactors that defeat resolution.

The test suite enables it globally: tests/conftest.py patches
``repro.core.engine.MultidimensionalCache`` to this class (autouse), so the
whole staging/engine suite doubles as a race-detection run.  Overhead is one
`threading.current_thread()` per metadata mutation — nanoseconds against a
staging copy.

The *owner* is the thread that constructed the cache (the engine builds its
cache on the serving thread).  `mutation_log` keeps the most recent
mutations (bounded) so a failure's context is inspectable in the traceback /
debugger.
"""

from __future__ import annotations

import collections
import functools
import threading

from repro.core.cache import MultidimensionalCache

# the cache-metadata mutators confined to the owner thread — keep in sync
# with the `# owner: main-thread` annotations in core/cache.py (the static
# checker derives its set from those annotations; this one instruments them
# at runtime)
GUARDED_METHODS = (
    "new_sequence", "advance_token", "pin", "begin_inflight", "end_inflight",
    "cancel_inflight", "probe", "admit",
)

_LOG_BOUND = 256


class ThreadConfinementError(AssertionError):
    """A cache-metadata mutator ran on a thread other than the owner."""


class InstrumentedCache(MultidimensionalCache):
    """`MultidimensionalCache` that asserts mutator thread confinement."""

    def __init__(self, *args, **kwargs):
        # set before super().__init__ so guarded calls during construction
        # (there are none today, but subclasses may add some) already check
        self._owner_thread = threading.current_thread()
        self.mutation_log = collections.deque(maxlen=_LOG_BOUND)
        super().__init__(*args, **kwargs)

    def _assert_owner(self, method: str):
        t = threading.current_thread()
        self.mutation_log.append((method, t.name))
        if t is not self._owner_thread:
            raise ThreadConfinementError(
                f"MultidimensionalCache.{method}() called on thread "
                f"{t.name!r} but cache metadata is owned by "
                f"{self._owner_thread.name!r} (see the thread-confinement "
                "invariant in core/loader.py and docs/ANALYSIS.md)")


def _guard(name):
    orig = getattr(MultidimensionalCache, name)

    @functools.wraps(orig)
    def wrapper(self, *args, **kwargs):
        self._assert_owner(name)
        return orig(self, *args, **kwargs)

    return wrapper


for _name in GUARDED_METHODS:
    setattr(InstrumentedCache, _name, _guard(_name))
del _name
