"""Trace-driven latency simulator for expert-offloading systems.

This container has no GPU/TPU+PCIe pair to measure, so — exactly like the
paper's own Fig. 9 analysis — we model the decode timeline analytically and
drive it with *real routing traces* recorded from a trained MoE model.

The cost model has three knobs (defaults = the paper's RTX 4090 group):
    link_gbps      host->device expert-fetch bandwidth (PCIe 4.0: 32 GB/s)
    compute_s      per-layer GPU compute time (paper measures ~3 ms/layer on
                   a 4090 for Mixtral; scaled by expert size)
    expert_bytes   per-precision expert size (from quant.expert_nbytes)

Systems modeled (the paper's baselines):
    dense_layerwise   llama.cpp-style: stream every expert of every layer
    on_demand         MoE-Offloading-style: LRU cache, fetch fp16 on miss
    prefetch_lru      MoE-Infinity-style: LRU cache + next-layer prefetch
                      (fp16, non-interruptible mispredictions — Fig. 9c)
    hobbit            mixed-precision loading + adaptive prefetch +
                      multidimensional cache
Ablations are expressed by toggling HobbitSimConfig fields (Fig. 16/17/18).

A trace is a list of tokens; each token is a list over MoE layers of
  TraceLayer(experts, gate_vals, pred_experts, pred_gate_vals)
where pred_* come from the *previous* layer's adaptive predictor output.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cache import MultidimensionalCache
from repro.core.policies import LRU, MULTIDIM, PolicyWeights
from repro.core.scoring import (PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                                precision_decisions)


@dataclasses.dataclass
class TraceLayer:
    experts: List[int]                       # actual top-k (descending gate)
    gate_vals: np.ndarray                    # their gate magnitudes
    pred_experts: Optional[List[int]] = None # predictor output for THIS layer
    pred_gate_vals: Optional[np.ndarray] = None


Trace = List[List[TraceLayer]]  # [token][moe_layer]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    link_gbps: float = 32.0                   # PCIe 4.0 (RTX 4090 group)
    compute_s_per_layer: float = 3e-3         # paper §2.1: ~3ms/layer on 4090
    lo_compute_discount: float = 1.0          # fused dequant GEMM ~= same time

    def load_s(self, nbytes: int) -> float:
        return nbytes / (self.link_gbps * 1e9)


RTX4090 = HardwareModel("rtx4090", link_gbps=32.0, compute_s_per_layer=3e-3)
JETSON_ORIN = HardwareModel("jetson_orin", link_gbps=7.0,
                            compute_s_per_layer=9e-3)
TPU_V5E_HOST = HardwareModel("tpu_v5e_host", link_gbps=32.0,
                             compute_s_per_layer=1.5e-3)

HARDWARE = {h.name: h for h in (RTX4090, JETSON_ORIN, TPU_V5E_HOST)}


@dataclasses.dataclass(frozen=True)
class HobbitSimConfig:
    thresholds: Thresholds = Thresholds(0.6, 0.9)
    dynamic_loading: bool = True              # False -> always fp16 (ablation)
    prefetch: bool = True
    # beyond-paper: only issue a prefetch when the predictor's top-1
    # probability clears this bar (0 = paper-faithful, always prefetch).
    # Mispredicted transfers are non-interruptible (Fig. 9), so gating by
    # confidence removes most of the wrong-expert link occupancy.
    prefetch_conf: float = 0.0
    policy: PolicyWeights = MULTIDIM
    hi_slots: int = 64
    lo_slots: int = 32
    hi_bytes: int = 0                         # filled by caller
    lo_bytes: int = 0
    # multi-stream staging (mirrors core/loader.py StagingEngine so the
    # simulated overlap_fraction stays comparable to the wall-clock one):
    # streams=1 keeps the single-DMA-engine timeline of the paper's Fig. 9;
    # streams>=2 gives hi- and lo-precision transfers their own copy engine.
    streams: int = 1
    # ordered=True issues prefetch transfers in prediction order (paper
    # baseline); False issues biggest-gate-first and preempts a queued hi
    # transfer with a lo replacement when the link cannot move the hi bytes
    # before the target layer's compute starts (issue-time downgrade).
    ordered: bool = True
    # idle-link upgrade pass (mirrors StagingEngine._pump_upgrades so the
    # simulated upgrade behavior stays comparable to wall clock): with
    # ordered=False, a downgraded expert keeps serving its lo stand-in
    # (counted in served_lo_expert_steps) and hi re-copies are issued for
    # the hottest lo-substituted experts into hi-stream idle time that ends
    # before the layer's compute does — never delaying a deadline transfer.
    # False restores the per-token PR-4 semantics (next hi use blocking-
    # loads hi on demand).
    upgrade: bool = True


class OffloadSimulator:
    """Simulates one system's decode timeline over a trace."""

    def __init__(self, system: str, num_layers: int, hw: HardwareModel,
                 cfg: HobbitSimConfig):
        self.system = system
        self.hw = hw
        self.cfg = cfg
        self.num_layers = num_layers
        weights = cfg.policy if system == "hobbit" else LRU
        self.cache = MultidimensionalCache(num_layers, cfg.hi_slots,
                                           cfg.lo_slots if system == "hobbit" else 0,
                                           weights)
        self.pending_prefetch_done_at = 0.0
        self._nstreams = max(1, int(cfg.streams))
        self._stall_s = 0.0
        self._transfer_s = 0.0
        self._per_stream_bytes = [0] * self._nstreams
        self._downgrades = 0
        self._reorders = 0
        # idle-link upgrade pass state (budgeted path only)
        self._upgrade = bool(cfg.upgrade) and not cfg.ordered
        self._lo_sub: set = set()       # downgraded keys served from lo
        self._upgrades = 0
        self._upgrade_bytes = 0
        self._served_lo = 0

    def _bytes(self, prec: int) -> int:
        return self.cfg.hi_bytes if prec == PREC_HI else self.cfg.lo_bytes

    def _stream_of(self, prec: int) -> int:
        """hi transfers ride stream 0, lo transfers the second stream (the
        StagingEngine's one-hi/one-lo split); streams=1 shares one engine."""
        return 0 if (prec == PREC_HI or self._nstreams == 1) else 1

    # ------------------------------------------------------------------
    def run(self, trace: Trace, *, reset_per_sequence: bool = True) -> Dict:
        t = 0.0
        per_token = []
        self.cache.new_sequence()
        self._stall_s = 0.0         # transfer time on the critical path
        self._transfer_s = 0.0      # total link-busy time issued
        self._per_stream_bytes = [0] * self._nstreams
        self._downgrades = 0
        self._reorders = 0
        self._lo_sub = set()
        self._upgrades = 0
        self._upgrade_bytes = 0
        self._served_lo = 0
        for token in trace:
            t0 = t
            self.cache.advance_token()
            t = self._run_token(token, t)
            per_token.append(t - t0)
        # same accounting the engine reports for the real wall clock:
        # overlap_fraction = share of transfer time hidden behind compute;
        # link_utilization = share of the timeline the modeled link was busy
        overlap = (max(0.0, 1.0 - self._stall_s / self._transfer_s)
                   if self._transfer_s > 0 else 0.0)
        return {
            "total_s": t,
            "tok_per_s": len(trace) / t if t > 0 else float("inf"),
            "per_token_s": per_token,
            "stats": self.cache.stats,
            "cache": self.cache.stats.to_dict(),
            "load_stall_s": self._stall_s,
            "overlap_fraction": overlap,
            "per_stream_bytes": list(self._per_stream_bytes),
            "issue_reorders": self._reorders,
            "precision_downgrades": self._downgrades,
            "upgrades": self._upgrades,
            "upgrade_bytes": self._upgrade_bytes,
            "served_lo_expert_steps": self._served_lo,
            "link_utilization": (min(1.0, self._transfer_s / t)
                                 if t > 0 else 0.0),
        }

    def _issue(self, link_free: List[float], t: float, prec: int) -> float:
        """Occupy `prec`'s stream for one transfer issued at `t`; returns the
        time the transfer lands."""
        s = self._stream_of(prec)
        dur = self.hw.load_s(self._bytes(prec))
        link_free[s] = max(link_free[s], t) + dur
        self._transfer_s += dur
        self._per_stream_bytes[s] += self._bytes(prec)
        return link_free[s]

    # ------------------------------------------------------------------
    def _run_token(self, token: List[TraceLayer], t: float) -> float:
        """Timeline semantics (Fig. 9, extended to N streams): each stream is
        one DMA engine serializing its own transfers (`link_free[s]`; hi
        transfers on stream 0, lo on stream 1 when streams >= 2); on-demand
        loads block the layer start; prefetch for layer l+1 is issued when
        layer l's compute *starts* and overlaps with it; in-flight (possibly
        wrong) prefetches are non-interruptible — layer l+1's on-demand loads
        queue behind them on their stream.  With ``ordered=False`` prefetch
        transfers issue biggest-gate-first and a queued hi transfer that
        cannot land before the target layer's compute begins is downgraded to
        its lo replacement (the StagingEngine's issue-time precision
        decision)."""
        link_free = [t] * self._nstreams
        for li, tl in enumerate(token):
            # -------- on-demand fetches (block the layer) --------
            if self.system == "dense_layerwise":
                need = self.hw.load_s(self.cfg.hi_bytes) * self._experts_per_layer(token)
                end = max(link_free[0], t) + need
                link_free[0] = end
                self._transfer_s += need
                self._per_stream_bytes[0] += (self.cfg.hi_bytes
                                              * self._experts_per_layer(token))
                self._stall_s += end - t
                t = end
            else:
                if self.system == "hobbit" and self.cfg.dynamic_loading:
                    dec = precision_decisions(tl.gate_vals, self.cfg.thresholds)
                else:
                    dec = np.full(len(tl.experts), PREC_HI)
                for e, d in zip(tl.experts, dec):
                    if d == PREC_SKIP:
                        continue
                    is_hi = d == PREC_HI
                    self.cache.pin((li, e), is_hi)
                    slot = self.cache.probe((li, e), is_hi)
                    if (slot is None and is_hi and self._upgrade
                            and (li, e) in self._lo_sub):
                        if self.cache.lookup((li, e), False) is not None:
                            # persistent downgrade substitution: serve the
                            # lo stand-in until an upgrade lands hi
                            self.cache.pin((li, e), False)
                            self.cache.records.on_use((li, e), False)
                            self._served_lo += 1
                            continue
                        self._lo_sub.discard((li, e))   # lo evicted: reload
                    if slot is None:
                        end = self._issue(link_free, t, int(d))
                        self._stall_s += end - t
                        t = end                    # on-demand load blocks
                        self.cache.admit((li, e), is_hi, li)

            # -------- compute; prefetch for the NEXT layer overlaps --------
            compute_end = t + self.hw.compute_s_per_layer
            prefetch_on = (self.system == "prefetch_lru"
                           or (self.system == "hobbit" and self.cfg.prefetch))
            nxt = token[li + 1] if li + 1 < len(token) else None
            if (prefetch_on and nxt is not None
                    and nxt.pred_experts is not None
                    and (self.cfg.prefetch_conf <= 0.0
                         or (nxt.pred_gate_vals is not None
                             and float(np.max(nxt.pred_gate_vals))
                             >= self.cfg.prefetch_conf))):
                if self.system == "hobbit" and self.cfg.dynamic_loading:
                    pdec = precision_decisions(nxt.pred_gate_vals,
                                               self.cfg.thresholds)
                else:
                    pdec = np.full(len(nxt.pred_experts), PREC_HI)
                gates = (np.asarray(nxt.pred_gate_vals, float)
                         if nxt.pred_gate_vals is not None
                         else np.zeros(len(nxt.pred_experts)))
                # only pairs that will actually issue a transfer take part
                # in the gate sort: counting inversions over skipped or
                # already-resident predictions would report phantom
                # issue_reorders the engine's metric never counts
                pairs = [(e, d, g, i) for i, (e, d, g) in
                         enumerate(zip(nxt.pred_experts, pdec, gates))
                         if d != PREC_SKIP
                         and self.cache.lookup((li + 1, e),
                                               d == PREC_HI) is None]
                if not self.cfg.ordered:
                    issue_order = sorted(pairs, key=lambda p: (-p[2], p[3]))
                    # inversions the gate sort introduced vs prediction order
                    self._reorders += sum(
                        1 for i, p in enumerate(issue_order)
                        if any(q[3] < p[3] for q in issue_order[i + 1:]))
                    pairs = issue_order
                for e, d, _g, _i in pairs:
                    if d == PREC_SKIP:
                        continue
                    is_hi = d == PREC_HI
                    if (not self.cfg.ordered and is_hi
                            and self.cache.lookup((li + 1, e), True) is None):
                        # issue-time budget check: can the hi bytes land
                        # before layer li+1's compute starts, given what is
                        # already queued on the hi stream?
                        s = self._stream_of(PREC_HI)
                        queue_s = max(0.0, link_free[s] - t)
                        if (queue_s + self.hw.load_s(self.cfg.hi_bytes)
                                > compute_end - t):
                            self._downgrades += 1
                            d, is_hi = PREC_LO, False
                            if self._upgrade:
                                self._lo_sub.add((li + 1, e))
                    if self.cache.lookup((li + 1, e), is_hi) is None:
                        # issued at compute start, overlapped; occupies its
                        # stream (no immediate stall — if it is still in
                        # flight when the next layer's on-demand loads queue
                        # behind it, the wait surfaces there as stall)
                        self._issue(link_free, t, int(d))
                        self.cache.admit((li + 1, e), is_hi, li)
                        self.cache.pin((li + 1, e), is_hi)
            if self._upgrade and self.system == "hobbit":
                self._issue_upgrades(link_free, t, compute_end, li)
            t = compute_end
        return t

    def _issue_upgrades(self, link_free: List[float], t: float,
                        compute_end: float, li: int):
        """Idle-link upgrade pass on the simulated timeline (the
        StagingEngine rule): ONE hi re-copy per idle window — the analogue
        of the engine's one-in-flight-per-stream cap — for the hottest
        lo-substituted expert, issued only into hi-stream idle time that
        ends before this layer's compute does, so a deadline transfer is
        never delayed."""
        s = self._stream_of(PREC_HI)
        dur = self.hw.load_s(self.cfg.hi_bytes)
        cands = []
        for key in list(self._lo_sub):
            if self.cache.lookup(key, False) is None:
                self._lo_sub.discard(key)       # lo stand-in evicted
                continue
            if self.cache.lookup(key, True) is not None:
                self._lo_sub.discard(key)       # hi already resident
                continue
            cands.append(key)
        # fleet-blended cache priority (cache.priority — identical to the
        # per-sequence Eq. 3 score when no fleet heat map is attached)
        prio = lambda k: self.cache.priority(k, li)  # noqa: E731
        cands.sort(key=lambda k: -prio(k))
        for key in cands:
            if max(link_free[s], t) + dur > compute_end:
                break                           # no idle budget left
            # never evict a hi resident at least as hot as the promoted
            # expert (same churn guard as StagingEngine._pump_upgrades,
            # compared against the real eviction policy)
            victim_p = self.cache.peek_victim_priority(True, li)
            if victim_p is not None and victim_p >= prio(key):
                break                           # candidates priority-sorted
            self._issue(link_free, t, PREC_HI)
            self.cache.admit(key, True, li)
            self.cache.pin(key, True)
            self._lo_sub.discard(key)
            self._upgrades += 1
            self._upgrade_bytes += self.cfg.hi_bytes
            break                               # one re-copy per idle window

    def _experts_per_layer(self, token) -> int:
        # dense_layerwise streams every expert; infer expert count from trace
        mx = 0
        for tl in token:
            mx = max(mx, max(tl.experts) + 1)
        return mx


def simulate_systems(trace: Trace, num_layers: int, hw: HardwareModel,
                     cfg: HobbitSimConfig,
                     systems: Sequence[str] = ("dense_layerwise", "on_demand",
                                               "prefetch_lru", "hobbit")) -> Dict[str, Dict]:
    out = {}
    for s in systems:
        out[s] = OffloadSimulator(s, num_layers, hw, cfg).run(trace)
    return out


# ----------------------------------------------------------------------
# serving timeline: SLO scheduling on a deterministic virtual clock
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TimelineConfig:
    """Virtual-clock serving model for `ServingTimeline` (the scheduling
    analogue of `HardwareModel`): slot and KV capacity plus throughput
    knobs, all deterministic, so scheduling policies are compared on an
    exactly reproducible timeline."""
    slots: int = 4                     # concurrent serving slots
    kv_tokens: int = 4096              # total KV token budget (page pool)
    prefill_tok_s: float = 4096.0      # prefill throughput, tokens/s
    decode_step_s: float = 0.05        # one decode step (= 1 token/request)
    policy: str = "slo"                # "fifo" | "slo"
    aging_s: float = 10.0              # starvation-bounding aging period
    preempt_margin: float = 1.0        # effective-priority gap to preempt


class ServingTimeline:
    """Deterministic virtual-clock replay of a `serving.workload` trace
    under one scheduling policy — the simulator half of the live
    `BatchingServer` (same admission ordering and preemption rule via the
    shared `serving.workload` policy helpers, same stats keys), used to
    search scheduling policies and to CI-gate SLO attainment.

    FIFO admits strictly in arrival order with head-of-line blocking (the
    pre-PR-9 scheduler).  SLO admits in `slo_urgency` order and may
    preempt: when the most urgent queued request does not fit, the
    lowest-effective-priority decoding victim whose eviction makes it fit
    — and whose effective priority trails by more than `preempt_margin` —
    is paused and requeued (its prefill/decode progress is kept, like the
    live pause/resume snapshot path).  Aging (one priority level per
    `aging_s` waited) bounds starvation: a request of priority p waiting
    `(p_max - p + margin) * aging_s` outranks every fresh arrival, so
    `starved` counts requests whose admission wait exceeded that bound
    plus one aging period of slack."""

    def __init__(self, cfg: TimelineConfig):
        self.cfg = cfg

    def run(self, trace) -> Dict:
        from repro.serving.workload import effective_priority, slo_urgency
        cfg = self.cfg
        reqs = [{
            "rid": w.rid, "arrival": float(w.arrival_s),
            "plen": int(len(w.prompt)), "new": int(w.max_new_tokens),
            "prio": int(w.priority), "ttft": w.ttft_slo_s,
            "tpot": w.tpot_slo_s,
            "kv": int(len(w.prompt)) + int(w.max_new_tokens) + 1,
            "state": "queued", "prefilled": 0, "decoded": 0,
            "admitted": None, "first": None, "done": None,
        } for w in trace]
        order = sorted(range(len(reqs)), key=lambda i: reqs[i]["arrival"])
        queue: List[int] = []
        running: List[int] = []
        kv_used = 0
        preemptions = 0
        t, ai, done_n = 0.0, 0, 0
        tick = cfg.decode_step_s

        def fits(r) -> bool:
            return (len(running) < cfg.slots
                    and kv_used + r["kv"] <= cfg.kv_tokens)

        def admit(i: int, now: float):
            nonlocal kv_used
            r = reqs[i]
            if r["admitted"] is None:
                r["admitted"] = now
            r["state"] = "decode" if r["prefilled"] >= r["plen"] else "prefill"
            kv_used += r["kv"]
            running.append(i)

        for _ in range(1_000_000):
            if done_n >= len(reqs):
                break
            while ai < len(order) and reqs[order[ai]]["arrival"] <= t:
                queue.append(order[ai])
                ai += 1
            if not running and not queue and ai < len(order):
                t = reqs[order[ai]]["arrival"]      # fast-forward idle time
                continue
            # ---- admission ----
            if cfg.policy == "fifo":
                queue.sort(key=lambda i: (reqs[i]["arrival"], i))
                while queue and fits(reqs[queue[0]]):
                    admit(queue.pop(0), t)          # head-of-line blocking
            else:
                queue.sort(key=lambda i: slo_urgency(
                    reqs[i]["prio"], reqs[i]["arrival"], reqs[i]["ttft"], t,
                    cfg.aging_s))
                rest = []
                for i in queue:
                    if fits(reqs[i]):
                        admit(i, t)
                    else:
                        rest.append(i)
                queue = rest
                if queue:
                    # preempt-and-requeue for the most urgent non-fitting
                    # request: lowest-effective-priority decoding victim
                    # whose slot+pages make it fit, margin-guarded
                    top = queue[0]
                    eff = lambda i: effective_priority(  # noqa: E731
                        reqs[i]["prio"], reqs[i]["arrival"], t, cfg.aging_s)
                    cands = [i for i in running if reqs[i]["state"] == "decode"]
                    if cands:
                        victim = min(cands, key=eff)
                        v = reqs[victim]
                        if (eff(victim) + cfg.preempt_margin < eff(top)
                                and kv_used - v["kv"] + reqs[top]["kv"]
                                <= cfg.kv_tokens):
                            running.remove(victim)
                            kv_used -= v["kv"]
                            v["state"] = "queued"   # progress kept (snapshot)
                            queue.append(victim)
                            preemptions += 1
                            admit(queue.pop(0), t)
            # ---- one tick of service ----
            t_end = t + tick
            budget = cfg.prefill_tok_s * tick       # prefill tokens this tick
            for i in list(running):
                r = reqs[i]
                if r["state"] == "prefill":
                    r["prefilled"] = min(r["plen"],
                                         r["prefilled"] + int(budget))
                    if r["prefilled"] >= r["plen"]:
                        # prefill's last-token logits ARE the first token
                        r["state"] = "decode"
                        r["first"] = t_end
                        r["decoded"] = 1
                elif r["state"] == "decode":
                    if r["first"] is None:
                        r["first"] = t_end
                    r["decoded"] += 1
                if r["decoded"] >= r["new"]:
                    r["state"] = "done"
                    r["done"] = t_end
                    running.remove(i)
                    kv_used -= r["kv"]
                    done_n += 1
            t = t_end

        # ---- metrics (same keys the live BatchingServer.stats() reports) --
        p_max = max((r["prio"] for r in reqs), default=0)
        ttfts, met, declared, starved = [], 0, 0, 0
        for r in reqs:
            ttft = (r["first"] - r["arrival"]) if r["first"] is not None \
                else float("inf")
            ttfts.append(ttft)
            wait = (r["admitted"] - r["arrival"]) if r["admitted"] is not None \
                else float("inf")
            bound = (p_max - r["prio"] + cfg.preempt_margin + 1) * cfg.aging_s
            if wait > bound:
                starved += 1
            if r["ttft"] is None and r["tpot"] is None:
                continue
            declared += 1
            ok = r["done"] is not None
            if ok and r["ttft"] is not None:
                ok = ttft <= r["ttft"]
            if ok and r["tpot"] is not None and r["decoded"] > 1:
                ok = ((r["done"] - r["first"]) / (r["decoded"] - 1)
                      <= r["tpot"])
            met += int(ok)
        return {
            "policy": cfg.policy,
            "completed": done_n,
            "slo_attainment": (met / declared) if declared else 1.0,
            "p99_ttft_s": float(np.percentile(ttfts, 99)) if ttfts else 0.0,
            "mean_ttft_s": (float(np.mean([x for x in ttfts
                                           if np.isfinite(x)]))
                            if any(np.isfinite(x) for x in ttfts) else 0.0),
            "preemptions": preemptions,
            "starved": starved,
            "requests": [{k: r[k] for k in
                          ("rid", "arrival", "admitted", "first", "done",
                           "prio", "decoded")} for r in reqs],
        }


def cache_policy_penalty(trace: Trace, num_layers: int, weights: PolicyWeights,
                         hi_slots: int, lo_slots: int, th: Thresholds,
                         lo_cost_ratio: float = 0.25,
                         sequence_level: bool = True,
                         sequence_breaks: Optional[List[int]] = None) -> float:
    """Replay a trace through the mixed-precision cache under a policy and
    return the paper's miss *penalty* metric (Fig. 18)."""
    cache = MultidimensionalCache(num_layers, hi_slots, lo_slots, weights)
    cache.new_sequence()
    breaks = set(sequence_breaks or [])
    for ti, token in enumerate(trace):
        if sequence_level and ti in breaks:
            cache.new_sequence()
        cache.advance_token()
        for li, tl in enumerate(token):
            dec = precision_decisions(tl.gate_vals, th)
            for e, d in zip(tl.experts, dec):
                if d == PREC_SKIP:
                    continue
                is_hi = d == PREC_HI
                if cache.probe((li, e), is_hi) is None:
                    cache.admit((li, e), is_hi, li)
    return cache.stats.miss_penalty(lo_cost_ratio)
