"""Fleet-wide decayed expert heat (cross-request caching prior).

HOBBIT's multidimensional cache (paper §3.4, Eq. 3) scores experts from a
purely per-sequence view: `PolicyRecords` resets at every `new_sequence()`,
so each newly admitted request rediscovers expert popularity from scratch.
Under multi-tenant traffic the routing distribution is heavily shared
across requests (the DyMoE cross-request orchestration observation), so
the *fleet* already knows which experts are hot before a request routes
its first token.

`FleetHeat` is that prior: an exponentially decayed heat map over
`(layer, expert)` keys, fed by every request's routing decisions
(`observe`, weighted by gate magnitude) and decayed once per retired
request (`retire_request`).  `MultidimensionalCache.priority()` blends the
normalized heat into the Eq. 3 priority with weight `fleet_weight`, so
eviction (`_select_victim`), the upgrade pass's churn guard
(`peek_victim_priority`) and the idle-link upgrade ordering all prefer
experts the fleet keeps using — and a freshly admitted request starts from
the fleet's working set instead of a cold cache.

The map is engine-lifetime state: it deliberately survives
`cache.new_sequence()` (which resets only the per-sequence records), which
is exactly what makes it a cross-request prior.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

ExpertKey = Tuple[int, int]  # (layer, expert) — matches core/policies.py


class FleetHeat:
    """Decayed cross-request expert popularity.

    decay   multiplier applied to every key's heat when a request retires
            (per-request half-life ~= ln(2)/ln(1/decay) requests)
    floor   heat below which a key is pruned from the map after decay
    """

    def __init__(self, decay: float = 0.9, floor: float = 1e-3):
        assert 0.0 < decay < 1.0, "decay must be in (0, 1)"
        self.decay = float(decay)
        self.floor = float(floor)
        self._heat: Dict[ExpertKey, float] = {}   # owner: main-thread
        self._max = 0.0                           # owner: main-thread
        self.requests_retired = 0                 # owner: main-thread
        self.observations = 0                     # owner: main-thread

    # ------------------------------------------------------------------
    # owner: main-thread
    def observe(self, key: ExpertKey, weight: float = 1.0) -> None:
        """Record one routing decision for `key` (weight = gate magnitude)."""
        h = self._heat.get(key, 0.0) + float(weight)
        self._heat[key] = h
        if h > self._max:
            self._max = h
        self.observations += 1

    # owner: main-thread
    def retire_request(self) -> None:
        """Decay every key once (called when a request retires/releases)."""
        self.requests_retired += 1
        if not self._heat:
            return
        d, floor = self.decay, self.floor
        self._heat = {k: v * d for k, v in self._heat.items() if v * d > floor}
        self._max = max(self._heat.values()) if self._heat else 0.0

    # ------------------------------------------------------------------
    def score(self, key: ExpertKey) -> float:
        """Normalized heat in [0, 1] (1 = the fleet's hottest expert)."""
        if self._max <= 0.0:
            return 0.0
        return self._heat.get(key, 0.0) / self._max

    def is_warm(self, key: ExpertKey) -> bool:
        """True when the fleet has live (un-decayed-away) heat for `key`."""
        return self._heat.get(key, 0.0) > 0.0

    def layer_prior(self, layer: int, num_experts: int) -> np.ndarray:
        """Per-expert prior for one layer, normalized to sum 1 (uniform when
        the fleet is cold) — the predictor-blend input."""
        p = np.array([self._heat.get((layer, e), 0.0)
                      for e in range(num_experts)], dtype=np.float64)
        s = p.sum()
        if s <= 0.0:
            return np.full(num_experts, 1.0 / num_experts)
        return p / s

    def __len__(self) -> int:
        return len(self._heat)
