"""HOBBIT OffloadEngine: serve a real JAX MoE model with host-resident
experts, a device-resident mixed-precision expert cache, the dynamic loader,
the adaptive predictor and the multidimensional cache manager — the full
system of Fig. 4, with *real numerics* (mixed-precision expert substitution
actually changes the computed logits; accuracy benchmarks measure that).

Scope: decoder-only MoE models whose body layers are all (attn + MoE FFN) —
the paper's model class (Mixtral / Phi-MoE shapes, smoke-scaled here).

On this CPU-only container "device" and "host" share silicon, so wall-clock
transfer times are meaningless; the engine therefore (a) performs the real
cache/loader mechanics and numerics, and (b) records a routing trace that
core.simulator replays against hardware cost models for latency numbers —
the same separation the paper uses for its Fig. 9 analysis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import MultidimensionalCache
from repro.core.loader import DynamicExpertLoader
from repro.core.policies import MULTIDIM, PolicyWeights
from repro.core.predictor import AdaptiveExpertPredictor
from repro.core.scoring import (PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                                precision_decisions)
from repro.core.simulator import TraceLayer
from repro.models import layers as L
from repro.models import unstack_layers
from repro.models.model import Batch, Model
from repro.quant.quantize import QTensor, dequantize, expert_nbytes, quantize


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    hi_slots: int = 16
    lo_slots: int = 8
    thresholds: Thresholds = Thresholds(0.6, 0.9)
    policy: PolicyWeights = MULTIDIM
    prefetch_p: int = 2
    lo_bits: int = 4
    group_size: int = 64
    dynamic_loading: bool = True     # ablation switch (Fig. 16)
    prefetch: bool = True            # ablation switch (Fig. 17)
    compute_mode: str = "device"     # device | host (CPU-helper mode §4)


class OffloadEngine:
    def __init__(self, model: Model, params, ecfg: EngineConfig):
        cfg = model.cfg
        assert cfg.moe is not None, "OffloadEngine requires a MoE model"
        self.model = model
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.dtype = L._dt(cfg)

        flat = unstack_layers(cfg, params)
        self.layer_params = flat
        self.moe_layers = [i for i, m in enumerate(cfg.layer_is_moe()) if m]
        self.num_moe_layers = len(self.moe_layers)

        mc = cfg.moe
        d, f, e = cfg.d_model, mc.d_ff_expert, mc.num_experts
        wi_cols = 2 * f if cfg.ffn_activation == "swiglu" else f

        # ---- host storage: hi (dense) + lo (quantized) versions ----
        self.storage_hi: List[Dict[str, np.ndarray]] = []
        self.storage_lo: List[Dict[str, QTensor]] = []
        self.routers: List[np.ndarray] = []
        for li in self.moe_layers:
            ffn = flat[li]["ffn"]
            wi = np.asarray(ffn["experts"]["wi"], np.float32)  # (E, D, wi_cols)
            wo = np.asarray(ffn["experts"]["wo"], np.float32)  # (E, F, D)
            self.storage_hi.append({"wi": wi, "wo": wo})
            self.storage_lo.append({
                "wi": quantize(jnp.asarray(wi), bits=ecfg.lo_bits,
                               group_size=ecfg.group_size),
                "wo": quantize(jnp.asarray(wo), bits=ecfg.lo_bits,
                               group_size=ecfg.group_size),
            })
            self.routers.append(np.asarray(ffn["router"], np.float32))

        # ---- device pools ----
        self.pool_hi = {
            "wi": jnp.zeros((ecfg.hi_slots, d, wi_cols), self.dtype),
            "wo": jnp.zeros((ecfg.hi_slots, f, d), self.dtype),
        }
        qi, qo = self.storage_lo[0]["wi"], self.storage_lo[0]["wo"]
        self.pool_lo = {
            "wi_data": jnp.zeros((ecfg.lo_slots, *qi.data.shape[1:]), jnp.int8),
            "wi_scale": jnp.zeros((ecfg.lo_slots, *qi.scale.shape[1:]), jnp.float32),
            "wo_data": jnp.zeros((ecfg.lo_slots, *qo.data.shape[1:]), jnp.int8),
            "wo_scale": jnp.zeros((ecfg.lo_slots, *qo.scale.shape[1:]), jnp.float32),
        }
        self._qmeta = dict(bits=ecfg.lo_bits, group_size=ecfg.group_size, orig_k=0)

        # ---- manager / loader / predictor ----
        self.cache = MultidimensionalCache(self.num_moe_layers, ecfg.hi_slots,
                                           ecfg.lo_slots, ecfg.policy)
        hi_b = expert_nbytes(d, f, 16)
        lo_b = expert_nbytes(d, f, ecfg.lo_bits, group_size=ecfg.group_size)
        self.expert_bytes = {PREC_HI: hi_b, PREC_LO: lo_b}
        self.loader = DynamicExpertLoader(
            self.cache, ecfg.thresholds if ecfg.dynamic_loading
            else Thresholds(1.0, 1.0),
            self._fetch, lambda prec: self.expert_bytes[prec])
        self.predictor = AdaptiveExpertPredictor(
            self.routers, mc.top_k, p=ecfg.prefetch_p)

        # pending predictions: (Prediction, made_at_layer, batch_slot)
        self._pending_preds: List = []
        self.trace: List[List[TraceLayer]] = []
        self._jit_cache: Dict[str, callable] = {}
        self.batch = 1
        self.max_len = 0
        self.active = np.ones((1,), bool)

    # ------------------------------------------------------------------
    # device transfer
    # ------------------------------------------------------------------
    def _fetch(self, moe_idx: int, expert: int, precision: int, slot: int):
        """Write one expert's weights into a pool slot (the 'cudaMemcpy')."""
        if precision == PREC_HI:
            src = self.storage_hi[moe_idx]
            self.pool_hi["wi"] = self.pool_hi["wi"].at[slot].set(
                jnp.asarray(src["wi"][expert], self.dtype))
            self.pool_hi["wo"] = self.pool_hi["wo"].at[slot].set(
                jnp.asarray(src["wo"][expert], self.dtype))
        else:
            src = self.storage_lo[moe_idx]
            self.pool_lo["wi_data"] = self.pool_lo["wi_data"].at[slot].set(
                src["wi"].data[expert])
            self.pool_lo["wi_scale"] = self.pool_lo["wi_scale"].at[slot].set(
                src["wi"].scale[expert])
            self.pool_lo["wo_data"] = self.pool_lo["wo_data"].at[slot].set(
                src["wo"].data[expert])
            self.pool_lo["wo_scale"] = self.pool_lo["wo_scale"].at[slot].set(
                src["wo"].scale[expert])

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _attn_step(self, p, x, cache, positions):
        cfg = self.cfg
        h = L.apply_norm(p["pre_norm"], x, cfg)
        out, new_cache = L.attn_decode(p["attn"], h, cache, positions, cfg, "attn")
        return x + out, new_cache

    def _ffn_input(self, p, x):
        return L.apply_norm(p["ffn_norm"], x, self.cfg)

    def _hi_expert(self, wi, wo, h):
        cfg = self.cfg
        z = h @ wi
        if cfg.ffn_activation == "swiglu":
            g, u = jnp.split(z, 2, axis=-1)
            z = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        else:
            z = jax.nn.gelu(z.astype(jnp.float32)).astype(h.dtype)
        return z @ wo

    def _lo_expert(self, wi_data, wi_scale, wo_data, wo_scale, h):
        cfg = self.cfg
        mc = cfg.moe
        d, f = cfg.d_model, mc.d_ff_expert
        qi = QTensor(wi_data, wi_scale, self.ecfg.lo_bits, self.ecfg.group_size, d)
        qo = QTensor(wo_data, wo_scale, self.ecfg.lo_bits, self.ecfg.group_size, f)
        z = (h.astype(jnp.float32) @ dequantize(qi)).astype(h.dtype)
        if cfg.ffn_activation == "swiglu":
            g, u = jnp.split(z, 2, axis=-1)
            z = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        else:
            z = jax.nn.gelu(z.astype(jnp.float32)).astype(h.dtype)
        return (z.astype(jnp.float32) @ dequantize(qo)).astype(h.dtype)

    def _jit(self, name, fn):
        if name not in self._jit_cache:
            self._jit_cache[name] = jax.jit(fn)
        return self._jit_cache[name]

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def start_batch(self, batch: int, max_len: int):
        """Allocate per-slot KV caches and reset serving state for a new
        (possibly multi-request) batch.  All slots start active; continuous-
        batching schedulers toggle individual slots via join()/release()."""
        self.batch = batch
        self.max_len = max_len
        self.cache.new_sequence()
        self.kv_cache = [
            {"k": jnp.zeros((batch, max_len, self.cfg.num_kv_heads,
                             self.cfg.resolved_head_dim), self.dtype),
             "v": jnp.zeros((batch, max_len, self.cfg.num_kv_heads,
                             self.cfg.resolved_head_dim), self.dtype)}
            for _ in range(self.cfg.num_layers)]
        self.positions = jnp.zeros((batch,), jnp.int32)
        self.active = np.ones((batch,), bool)
        self.trace = []
        self._pending_preds = []        # (Prediction, made_at_layer, slot)

    def start_sequence(self, max_len: int, batch: int = 1):
        self.start_batch(batch, max_len)

    # ---------------- prefill / slot admission ----------------
    def _prefill_fn(self):
        key = ("prefill", self.max_len)
        if key not in self._jit_cache:
            max_len = self.max_len
            self._jit_cache[key] = jax.jit(
                lambda p, b: self.model.prefill(p, b, max_len))
        return self._jit_cache[key]

    def _flat_decode_cache(self, cache):
        """Flatten model.prefill's nested cache into the engine's per-layer
        list.  Valid for the engine's model class: every layer is a full-
        window "attn" + MoE block, so every entry is a max_len k/v pair."""
        cfg = self.cfg
        assert all(k == "attn" for k in cfg.layer_kinds()), cfg.layer_kinds()
        flat = [dict(c) for c in cache["prefix"]]
        for bi in range(cfg.num_blocks):
            for j in range(cfg.period):
                flat.append(jax.tree_util.tree_map(lambda a: a[bi],
                                                   cache["blocks"][j]))
        flat.extend(dict(c) for c in cache["tail"])
        return flat

    def prefill_batch(self, prompts) -> np.ndarray:
        """Real prefill: run the whole prompt batch through the dense model
        in one jitted call (prefill is compute-bound and touches every expert
        anyway — the offload cache only serves the decode phase, matching the
        paper's deployment), then adopt the KV cache in the engine's
        per-layer layout.  Returns last-token logits (B, V)."""
        prompts = np.asarray(prompts, np.int32)
        b, s = prompts.shape
        assert b == self.batch, (b, self.batch)
        batch = Batch(tokens=jnp.asarray(prompts),
                      loss_mask=jnp.ones((b, s), jnp.float32))
        logits, cache, positions = self._prefill_fn()(self.params, batch)
        self.kv_cache = self._flat_decode_cache(cache)
        self.positions = positions
        self.active[:] = True
        return np.asarray(logits, np.float32)

    def join(self, slot: int, prompt) -> np.ndarray:
        """Admit one request into a free slot mid-flight: batch=1 prefill,
        scatter its KV into the slot's cache rows.  Returns logits (V,)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert 0 <= slot < self.batch, (slot, self.batch)
        batch = Batch(tokens=jnp.asarray(prompt[None]),
                      loss_mask=jnp.ones((1, len(prompt)), jnp.float32))
        logits, cache, positions = self._prefill_fn()(self.params, batch)
        one = self._flat_decode_cache(cache)
        for li in range(self.cfg.num_layers):
            self.kv_cache[li] = jax.tree_util.tree_map(
                lambda dst, src: dst.at[slot].set(src[0].astype(dst.dtype)),
                self.kv_cache[li], one[li])
        self.positions = self.positions.at[slot].set(int(positions[0]))
        self.active[slot] = True
        self._pending_preds = [pp for pp in self._pending_preds
                               if pp[2] != slot]
        return np.asarray(logits[0], np.float32)

    def release(self, slot: int):
        """Free a slot (its KV rows become junk until the next join)."""
        self.active[slot] = False
        self._pending_preds = [pp for pp in self._pending_preds
                               if pp[2] != slot]

    # ---------------- batched HOBBIT decode ----------------
    def decode_step_batch(self, tokens) -> np.ndarray:
        """One batched HOBBIT decode step.  tokens: (B,) int32; returns
        logits (B, V).  Inactive slots ride through attention (their rows
        are junk and cheap) but take no part in gating, expert loading,
        expert compute, the trace, or position advancement.  Expert loading
        is the union of all active slots' demands; precision decisions stay
        per-slot, so each slot's numerics match its own batch=1 run."""
        cfg, ecfg, mc = self.cfg, self.ecfg, self.cfg.moe
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        assert tokens.shape[0] == self.batch, (tokens.shape, self.batch)
        rows = [r for r in range(self.batch) if self.active[r]]
        self.cache.advance_token()
        tok = jnp.asarray(tokens[:, None])
        x = jnp.take(self.params["embed"], tok, axis=0)
        if cfg.scale_embedding:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)

        attn_step = self._jit("attn", self._attn_step)
        ffn_in = self._jit("ffn_in", self._ffn_input)
        hi_exp = self._jit("hi", self._hi_expert)
        lo_exp = self._jit("lo", self._lo_expert)

        row_trace = {r: [] for r in rows}
        for mi, li in enumerate(self.moe_layers):
            p = self.layer_params[li]
            x, self.kv_cache[li] = attn_step(p, x, self.kv_cache[li], self.positions)
            h = ffn_in(p, x)                                   # (B,1,D)
            h_host = np.asarray(h[:, 0], np.float32)           # (B,D)

            # ---- gate (the paper's Expert Scorer input), per slot ----
            tops: Dict[int, np.ndarray] = {}
            gates: Dict[int, np.ndarray] = {}
            for r in rows:
                logits = h_host[r] @ self.routers[mi]
                probs = np.exp(logits - logits.max())
                probs /= probs.sum()
                tops[r] = np.argsort(-probs)[: mc.top_k]
                gates[r] = probs[tops[r]]

            # ---- score accuracy of earlier predictions for this layer ----
            still_pending = []
            for pred, made_at, r in self._pending_preds:
                if pred.layer == mi:
                    if r in tops:
                        self.predictor.record_accuracy(pred, tops[r].tolist(),
                                                       mi - made_at)
                elif pred.layer > mi:
                    still_pending.append((pred, made_at, r))
            self._pending_preds = still_pending

            # ---- adaptive prefetch for subsequent layers (§3.3) ----
            pred_entry: Dict[int, object] = {}
            if ecfg.prefetch:
                for r in rows:
                    walk = self.predictor.adaptive_walk(h_host[r], mi,
                                                        self.cache, self.loader.th)
                    for pr, dec in walk:
                        self.loader.enqueue_prefetch(pr.layer, pr.experts, dec)
                        self._pending_preds.append((pr, mi, r))
                        pred_entry[r] = pr
                    # also record plain next-layer prediction for trace/sim
                    nxt = self.predictor.predict_layers(h_host[r], mi, 1)
                    if nxt:
                        self._pending_preds.append((nxt[0], mi, r))
                        pred_entry[r] = nxt[0]

            # ---- on-demand scoring + loading (union over slots) ----
            self.loader.new_layer()
            for r in rows:
                self.loader.score_and_enqueue(mi, tops[r].tolist(), gates[r],
                                              clear_pins=False)
            self.loader.drain(mi)

            # ---- expert compute from cache slots, per slot ----
            y_rows = []
            for r in range(self.batch):
                if r not in row_trace:
                    y_rows.append(jnp.zeros_like(h[r : r + 1]))
                    continue
                hr = h[r : r + 1]
                dec = precision_decisions(gates[r], self.loader.th)
                y = jnp.zeros_like(hr)
                wsum = 0.0
                for e, d_, w in zip(tops[r], dec, gates[r]):
                    if d_ == PREC_SKIP:
                        continue
                    is_hi = d_ == PREC_HI
                    slot = self.cache.lookup((mi, e), is_hi)
                    if slot is None:
                        # a same-layer neighbour's admission evicted this
                        # expert (union demand > pool) — reload on demand,
                        # and count the re-fetch as a miss so hit_ratio
                        # reflects real traffic under contention
                        if is_hi:
                            self.cache.stats.misses_hi += 1
                        else:
                            self.cache.stats.misses_lo += 1
                        slot, _ = self.cache.admit((mi, int(e)), is_hi, mi)
                        self._fetch(mi, int(e), int(d_), slot)
                        self.loader.loaded_bytes += self.expert_bytes[int(d_)]
                        self.loader.n_loads[int(d_)] += 1
                    if self.ecfg.compute_mode == "host":
                        out = self._host_expert(mi, int(e), d_,
                                                np.asarray(hr, np.float32))
                        out = jnp.asarray(out, hr.dtype)
                    elif is_hi:
                        out = hi_exp(self.pool_hi["wi"][slot],
                                     self.pool_hi["wo"][slot], hr)
                    else:
                        out = lo_exp(self.pool_lo["wi_data"][slot],
                                     self.pool_lo["wi_scale"][slot],
                                     self.pool_lo["wo_data"][slot],
                                     self.pool_lo["wo_scale"][slot], hr)
                    y = y + float(w) * out.astype(jnp.float32)
                    wsum += float(w)
                if wsum > 0:
                    y = y / wsum                                # renormalize (skips)
                y_rows.append(y)
                pe = pred_entry.get(r)
                row_trace[r].append(TraceLayer(
                    experts=tops[r].tolist(), gate_vals=gates[r],
                    pred_experts=pe.experts if (pe and pe.layer == mi + 1) else None,
                    pred_gate_vals=pe.gate_vals if (pe and pe.layer == mi + 1) else None))
            x = x + jnp.concatenate(y_rows, axis=0).astype(x.dtype)

        self.positions = self.positions + jnp.asarray(
            self.active.astype(np.int32))
        for r in rows:
            self.trace.append(row_trace[r])
        lg = self.model.logits(self.params, x)[:, 0]
        return np.asarray(lg, np.float32)

    def decode_token(self, token: int) -> np.ndarray:
        """One HOBBIT decode step (batch=1 legacy API).  Returns logits (V,)."""
        assert self.batch == 1, "decode_token is batch=1; use decode_step_batch"
        return self.decode_step_batch(np.asarray([int(token)], np.int32))[0]

    def _host_expert(self, mi, e, d_, h):
        """CPU-GPU cooperative mode (§4): run the expert on host weights."""
        cfg = self.cfg
        if d_ == PREC_HI:
            wi = self.storage_hi[mi]["wi"][e]
            wo = self.storage_hi[mi]["wo"][e]
        else:
            wi = np.asarray(dequantize(jax.tree_util.tree_map(
                lambda a: a[e], self.storage_lo[mi]["wi"])))
            wo = np.asarray(dequantize(jax.tree_util.tree_map(
                lambda a: a[e], self.storage_lo[mi]["wo"])))
        z = h @ wi
        if cfg.ffn_activation == "swiglu":
            g, u = np.split(z, 2, axis=-1)
            z = (g / (1 + np.exp(-g))) * u
        else:
            z = 0.5 * z * (1 + np.tanh(np.sqrt(2 / np.pi) * (z + 0.044715 * z**3)))
        return z @ wo

    # ------------------------------------------------------------------
    # evaluation helpers
    # ------------------------------------------------------------------
    def generate(self, prompt: List[int], new_tokens: int,
                 max_len: Optional[int] = None) -> List[int]:
        max_len = max_len or (len(prompt) + new_tokens + 1)
        self.start_sequence(max_len)
        lg = None
        for t in prompt:
            lg = self.decode_token(int(t))
        out = []
        for _ in range(new_tokens):
            nxt = int(np.argmax(lg))
            out.append(nxt)
            lg = self.decode_token(nxt)
        return out

    def score_nll(self, tokens: List[int], max_len: Optional[int] = None) -> float:
        """Teacher-forced mean NLL through the offload path (accuracy evals)."""
        max_len = max_len or (len(tokens) + 1)
        self.start_sequence(max_len)
        nll, n = 0.0, 0
        lg = self.decode_token(int(tokens[0]))
        for t in tokens[1:]:
            p = lg - lg.max()
            p = p - np.log(np.exp(p).sum())
            nll -= p[int(t)]
            n += 1
            lg = self.decode_token(int(t))
        return nll / max(n, 1)

    def stats(self) -> Dict:
        return {
            "cache": self.cache.stats,
            "loads_hi": self.loader.n_loads[PREC_HI],
            "loads_lo": self.loader.n_loads[PREC_LO],
            "skips": self.loader.n_skips,
            "loaded_bytes": self.loader.loaded_bytes,
            "pred_accuracy": self.predictor.accuracy(),
        }
