"""HOBBIT OffloadEngine: serve a real JAX MoE model with host-resident
experts, a device-resident mixed-precision expert cache, the dynamic loader,
the adaptive predictor and the multidimensional cache manager — the full
system of Fig. 4, with *real numerics* (mixed-precision expert substitution
actually changes the computed logits; accuracy benchmarks measure that).

Scope: decoder-only MoE models whose body layers are all (attn + MoE FFN) —
the paper's model class (Mixtral / Phi-MoE shapes, smoke-scaled here).

On this CPU-only container "device" and "host" share silicon, so wall-clock
transfer times are meaningless; the engine therefore (a) performs the real
cache/loader mechanics and numerics, and (b) records a routing trace that
core.simulator replays against hardware cost models for latency numbers —
the same separation the paper uses for its Fig. 9 analysis.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import MultidimensionalCache
from repro.core.fleet_heat import FleetHeat
from repro.core.loader import (ON_DEMAND, DynamicExpertLoader, LoadTask,
                               StagingEngine, measure_link_bps)
from repro.core.policies import MULTIDIM, PolicyWeights
from repro.core.predictor import AdaptiveExpertPredictor
from repro.core.scoring import (PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                                precision_decisions)
from repro.core.simulator import TraceLayer
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import unstack_layers
from repro.models.model import Batch, Model
from repro.quant.quantize import QTensor, dequantize, expert_nbytes, quantize


def _np_qtensor(q: QTensor) -> QTensor:
    """Move a QTensor's leaves to host numpy (read-only expert storage)."""
    return QTensor(data=np.asarray(q.data), scale=np.asarray(q.scale),
                   bits=q.bits, group_size=q.group_size, orig_k=q.orig_k)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    hi_slots: int = 16
    lo_slots: int = 8
    thresholds: Thresholds = Thresholds(0.6, 0.9)
    policy: PolicyWeights = MULTIDIM
    prefetch_p: int = 2
    lo_bits: int = 4
    group_size: int = 64
    dynamic_loading: bool = True     # ablation switch (Fig. 16)
    prefetch: bool = True            # ablation switch (Fig. 17)
    compute_mode: str = "device"     # device | host (CPU-helper mode §4)
    # grouped decode: one batched gating matmul + one batched hi GEMM + one
    # batched lo dequant-GEMM per MoE layer instead of O(batch*top_k) tiny
    # per-expert dispatches.  False selects the original per-expert path
    # (the parity reference; also used automatically in host compute mode).
    grouped: bool = True
    # stage prefetch copies on a background executor so they overlap compute
    # in wall clock (double-buffered).  False drains them synchronously.
    async_prefetch: bool = True
    # multi-stream staging (core/loader.py StagingEngine): number of copy
    # streams sharing the modeled H2D link (default one hi- + one lo-
    # precision stream).  `ordered=True` with `streams=1` reproduces the
    # PR-2 single-worker FIFO scheduler bit-for-bit (the parity reference);
    # ordered=False issues biggest-gate-first within the nearest-deadline
    # layer and may downgrade queued hi copies to lo under link pressure.
    streams: int = 2
    ordered: bool = False
    # idle-link upgrade pass: re-issue hi copies for experts whose hi
    # prefetch was downgraded to lo under link pressure, hottest first,
    # whenever the hi stream is idle and no deadline work is queued — the
    # lo stand-in keeps serving (served_lo_expert_steps counts the
    # exposure) until the hi copy lands, then compute switches back to hi.
    # The substitution persists for as long as the link stays saturated
    # (hi reloads for substituted keys are suppressed so they can't stall
    # deadline barriers) and is undone at the first idle window.  False
    # restores the PR-4 per-token semantics bit-identically: a downgrade
    # serves lo for its own step only and the next step's hi request
    # blocking-loads hi on demand.
    upgrade: bool = True
    # modeled H2D link bandwidth in GB/s.  None measures the host copy rate
    # at startup (budget accounting only); an explicit value additionally
    # *emulates* the link — each staged copy occupies its stream for
    # bytes/link seconds — so contended-link behavior is measurable on this
    # CPU-only container (benchmarks/decode_speedup.py uses this).
    link_gbps: Optional[float] = None
    # paged KV cache: slots draw kv_page_size-token pages from a shared pool
    # of kv_pages pages (None = the dense equivalent, batch*ceil(max_len/
    # page)) instead of each slot allocating max_len up front; prompts then
    # prefill in prefill_chunk-token chunks (see models/kv_pages.py).
    paged_kv: bool = False
    kv_page_size: int = 64
    kv_pages: Optional[int] = None
    prefill_chunk: int = 64
    # prefix sharing (paged KV only): admissions alias trie-matched prompt
    # prefix pages across slots with copy-on-write on divergence; admission
    # then only charges the unshared suffix (see models/kv_pages.py)
    prefix_sharing: bool = True


def pad_pow2(pairs):
    """Repeat the last (slot, buffer) pair up to a power-of-two count: the
    duplicate write is idempotent and caps commit-scatter retraces at
    log(pool) shapes.  Module-level so the trace-time auditor
    (tools/analysis/entrypoints.py) builds its variant-budget shape set with
    the exact padding the production commit path uses."""
    n = 1 << (len(pairs) - 1).bit_length()
    return pairs + [pairs[-1]] * (n - len(pairs))


class OffloadEngine:
    def __init__(self, model: Model, params, ecfg: EngineConfig):
        cfg = model.cfg
        assert cfg.moe is not None, "OffloadEngine requires a MoE model"
        self.model = model
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.dtype = L._dt(cfg)

        flat = unstack_layers(cfg, params)
        self.layer_params = flat
        self.moe_layers = [i for i, m in enumerate(cfg.layer_is_moe()) if m]
        self.num_moe_layers = len(self.moe_layers)

        mc = cfg.moe
        d, f, e = cfg.d_model, mc.d_ff_expert, mc.num_experts
        wi_cols = 2 * f if cfg.ffn_activation == "swiglu" else f

        # ---- host storage: hi (dense) + lo (quantized) versions ----
        self.storage_hi: List[Dict[str, np.ndarray]] = []
        self.storage_lo: List[Dict[str, QTensor]] = []
        self.routers: List[np.ndarray] = []
        for li in self.moe_layers:
            ffn = flat[li]["ffn"]
            wi = np.asarray(ffn["experts"]["wi"], np.float32)  # (E, D, wi_cols)
            wo = np.asarray(ffn["experts"]["wo"], np.float32)  # (E, F, D)
            self.storage_hi.append({"wi": wi, "wo": wo})
            # host storage lives in numpy so background staging threads never
            # issue device computations
            self.storage_lo.append({
                "wi": _np_qtensor(quantize(jnp.asarray(wi), bits=ecfg.lo_bits,
                                           group_size=ecfg.group_size)),
                "wo": _np_qtensor(quantize(jnp.asarray(wo), bits=ecfg.lo_bits,
                                           group_size=ecfg.group_size)),
            })
            self.routers.append(np.asarray(ffn["router"], np.float32))
        # routers pre-stacked on device for the grouped (B,D)@(D,E) gating
        self.routers_dev = jnp.asarray(np.stack(self.routers))   # (L, D, E)

        # ---- device pools ----
        # owner: main-thread — device pool handles are rebound on
        # commit; a background rebind would race the dispatch gather
        self.pool_hi = {
            "wi": jnp.zeros((ecfg.hi_slots, d, wi_cols), self.dtype),
            "wo": jnp.zeros((ecfg.hi_slots, f, d), self.dtype),
        }
        qi, qo = self.storage_lo[0]["wi"], self.storage_lo[0]["wo"]
        # owner: main-thread
        self.pool_lo = {
            "wi_data": jnp.zeros((ecfg.lo_slots, *qi.data.shape[1:]), jnp.int8),
            "wi_scale": jnp.zeros((ecfg.lo_slots, *qi.scale.shape[1:]), jnp.float32),
            "wo_data": jnp.zeros((ecfg.lo_slots, *qo.data.shape[1:]), jnp.int8),
            "wo_scale": jnp.zeros((ecfg.lo_slots, *qo.scale.shape[1:]), jnp.float32),
        }
        self._qmeta = dict(bits=ecfg.lo_bits, group_size=ecfg.group_size, orig_k=0)

        # ---- manager / loader / predictor ----
        # owner: main-thread
        # fleet heat is engine-lifetime (survives cache.new_sequence()): the
        # cross-request expert prior blended into the Eq. 3 cache priorities
        self.fleet = FleetHeat()
        self.cache = MultidimensionalCache(self.num_moe_layers, ecfg.hi_slots,
                                           ecfg.lo_slots, ecfg.policy,
                                           fleet=self.fleet)
        hi_b = expert_nbytes(d, f, 16)
        lo_b = expert_nbytes(d, f, ecfg.lo_bits, group_size=ecfg.group_size)
        self.expert_bytes = {PREC_HI: hi_b, PREC_LO: lo_b}
        self.loader = DynamicExpertLoader(
            self.cache, ecfg.thresholds if ecfg.dynamic_loading
            else Thresholds(1.0, 1.0),
            self._fetch, lambda prec: self.expert_bytes[prec])
        link_bps = (ecfg.link_gbps * 1e9 if ecfg.link_gbps
                    else measure_link_bps())
        self.scheduler = StagingEngine(
            self.loader, self._stage, self._commit_staged,
            streams=ecfg.streams, ordered=ecfg.ordered, link_bps=link_bps,
            emulate_link=ecfg.link_gbps is not None, upgrade=ecfg.upgrade)
        self.predictor = AdaptiveExpertPredictor(
            self.routers, mc.top_k, p=ecfg.prefetch_p, fleet=self.fleet)

        # pending predictions: (Prediction, made_at_layer, batch_slot)
        self._pending_preds: List = []
        self.trace: List[List[TraceLayer]] = []
        self._jit_cache: Dict[str, callable] = {}
        self._gating_s = 0.0
        self._expert_dispatches = 0     # grouped-path compute dispatches
        self._union_reloads = 0         # same-layer contention re-fetches
        self._layer_s_ema = 0.0         # per-layer compute EMA (deadline hints)
        self._layer_period_ema = 0.0    # full layer period EMA (stream feed)
        self._closed = False
        self._ovf_np = None             # lazy overflow staging buffers
        self.batch = 1
        self.max_len = 0
        self.active = np.ones((1,), bool)
        self.kv_pool = None             # PagedKVPool when ecfg.paged_kv
        self._admission = None          # ChunkedPrefill when ecfg.paged_kv
        self._pending_joins = {}        # dense-path incremental admissions
        self._unclaimed_joins = {}      # finished during a blocking join()

    # ------------------------------------------------------------------
    # device transfer
    # ------------------------------------------------------------------
    def _fetch(self, moe_idx: int, expert: int, precision: int, slot: int):
        """Write one expert's weights into a pool slot (the 'cudaMemcpy')."""
        if precision == PREC_HI:
            src = self.storage_hi[moe_idx]
            self.pool_hi["wi"] = self.pool_hi["wi"].at[slot].set(
                jnp.asarray(src["wi"][expert], self.dtype))
            self.pool_hi["wo"] = self.pool_hi["wo"].at[slot].set(
                jnp.asarray(src["wo"][expert], self.dtype))
        else:
            src = self.storage_lo[moe_idx]
            self.pool_lo["wi_data"] = self.pool_lo["wi_data"].at[slot].set(
                src["wi"].data[expert])
            self.pool_lo["wi_scale"] = self.pool_lo["wi_scale"].at[slot].set(
                src["wi"].scale[expert])
            self.pool_lo["wo_data"] = self.pool_lo["wo_data"].at[slot].set(
                src["wo"].data[expert])
            self.pool_lo["wo_scale"] = self.pool_lo["wo_scale"].at[slot].set(
                src["wo"].scale[expert])

    def _stage(self, moe_idx: int, expert: int, precision: int) -> dict:
        """Gather one expert's weight bytes from host storage into staging
        buffers (the host half of the transfer).  Read-only on shared state,
        so the async scheduler may run it on a background thread."""
        if precision == PREC_HI:
            src = self.storage_hi[moe_idx]
            return {"wi": np.ascontiguousarray(src["wi"][expert]),
                    "wo": np.ascontiguousarray(src["wo"][expert])}
        src = self.storage_lo[moe_idx]
        return {"wi_data": np.ascontiguousarray(src["wi"].data[expert]),
                "wi_scale": np.ascontiguousarray(src["wi"].scale[expert]),
                "wo_data": np.ascontiguousarray(src["wo"].data[expert]),
                "wo_scale": np.ascontiguousarray(src["wo"].scale[expert])}

    def _scatter_fn(self, n_tensors: int):
        """Jitted multi-tensor slot scatter (eager `.at[].set` pays ~ms of
        python dispatch per call on CPU; the jitted version is the single
        fused update the issue's `_fetch_many` contract asks for)."""
        key = ("scatter", n_tensors)
        if key not in self._jit_cache:
            def scatter(pools, idx, values):
                return [p.at[idx].set(v.astype(p.dtype))
                        for p, v in zip(pools, values)]
            # donate the pool buffers: callers rebind the pools to the
            # returned arrays immediately, so keeping the inputs alive would
            # hold two full copies of every expert pool per commit
            self._jit_cache[key] = jax.jit(scatter, donate_argnums=0)
        return self._jit_cache[key]

    def _commit_staged(self, entries):
        """Write staged buffers into the device pools: ONE `.at[idx].set`
        scatter per pool tensor regardless of how many experts landed.
        entries: [(task_like_with_precision, slot, staged_dict)]."""
        hi = [(s, buf) for t, s, buf in entries if t.precision == PREC_HI]
        lo = [(s, buf) for t, s, buf in entries if t.precision != PREC_HI]
        hi = pad_pow2(hi) if hi else hi
        lo = pad_pow2(lo) if lo else lo
        if hi:
            idx = jnp.asarray([s for s, _ in hi], jnp.int32)
            new = self._scatter_fn(2)(
                [self.pool_hi["wi"], self.pool_hi["wo"]], idx,
                [jnp.asarray(np.stack([b["wi"] for _, b in hi])),
                 jnp.asarray(np.stack([b["wo"] for _, b in hi]))])
            self.pool_hi["wi"], self.pool_hi["wo"] = new
        if lo:
            idx = jnp.asarray([s for s, _ in lo], jnp.int32)
            names = ("wi_data", "wi_scale", "wo_data", "wo_scale")
            new = self._scatter_fn(4)(
                [self.pool_lo[n] for n in names], idx,
                [jnp.asarray(np.stack([b[n] for _, b in lo])) for n in names])
            for n, v in zip(names, new):
                self.pool_lo[n] = v

    def _overflow_buffers(self, pp: int) -> Dict[str, np.ndarray]:
        """Reusable host staging buffers for union-overflow experts (cache
        smaller than a layer's union demand).  Stale entries from earlier
        layers are never addressed: overflow slot indices are only assigned
        to entries written this layer."""
        if self._ovf_np is None or self._ovf_np["hi_wi"].shape[0] < pp:
            qi, qo = self.storage_lo[0]["wi"], self.storage_lo[0]["wo"]
            d, f = self.cfg.d_model, self.cfg.moe.d_ff_expert
            wi_cols = self.storage_hi[0]["wi"].shape[-1]
            self._ovf_np = {
                "hi_wi": np.zeros((pp, d, wi_cols), np.float32),
                "hi_wo": np.zeros((pp, f, d), np.float32),
                "lo_wi_data": np.zeros((pp, *qi.data.shape[1:]), np.int8),
                "lo_wi_scale": np.zeros((pp, *qi.scale.shape[1:]), np.float32),
                "lo_wo_data": np.zeros((pp, *qo.data.shape[1:]), np.int8),
                "lo_wo_scale": np.zeros((pp, *qo.scale.shape[1:]), np.float32),
            }
        return self._ovf_np

    def _fetch_many(self, items: List[Tuple[int, int, int, int]]):
        """Blocking batched fetch into admitted pool slots: items =
        [(moe_idx, expert, precision, slot)], one scatter per pool tensor.
        The decode hot paths go through `_stage`/`_commit_staged` directly
        (async prefetch, batched on-demand drain, overflow staging); this is
        the standalone batched-fetch entry point for warmup/pre-population
        and tests."""
        entries = []
        for mi, e, prec, slot in items:
            t = LoadTask(mi, e, int(prec), ON_DEMAND, self.expert_bytes[int(prec)])
            entries.append((t, slot, self._stage(mi, e, int(prec))))
            self.loader.loaded_bytes += t.bytes
            self.loader.n_loads[t.precision] += 1
        self._commit_staged(entries)

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _attn_step(self, p, x, cache, positions):
        cfg = self.cfg
        h = L.apply_norm(p["pre_norm"], x, cfg)
        out, new_cache = L.attn_decode(p["attn"], h, cache, positions, cfg, "attn")
        return x + out, new_cache

    def _attn_step_paged(self, p, x, kp, vp, table, positions, active):
        """Paged-KV attention step: same residual math as `_attn_step`, but
        K/V scatter/gather through the shared page pool."""
        cfg = self.cfg
        h = L.apply_norm(p["pre_norm"], x, cfg)
        out, kp, vp = L.paged_attn_decode(p["attn"], h, kp, vp, table,
                                          positions, active, cfg)
        return x + out, kp, vp

    def _attn_layer(self, li: int, x, *, table=None, active_dev=None):
        """Run layer li's attention against whichever KV layout is active,
        updating the layout's state in place.  Returns the residual stream."""
        p = self.layer_params[li]
        if self.ecfg.paged_kv:
            # page buffers donated: rebound to the outputs right below
            fn = self._jit("attn_paged", self._attn_step_paged,
                           donate=(2, 3))
            x, kp, vp = fn(p, x, self.kv_pool.k[li], self.kv_pool.v[li],
                           table, self.positions, active_dev)
            self.kv_pool.k[li], self.kv_pool.v[li] = kp, vp
            return x
        fn = self._jit("attn", self._attn_step)
        x, self.kv_cache[li] = fn(p, x, self.kv_cache[li], self.positions)
        return x

    def _paged_step_prologue(self, rows):
        """Grow every active slot's page chain for the token about to be
        written (copying shared pages off their sharers first — decode
        appending into an aliased prefix page must not corrupt it) and
        export the page table once per step."""
        pos = np.asarray(self.positions)
        for r in rows:
            p = int(pos[r])
            self.kv_pool.ensure(r, p + 1)
            self.kv_pool.make_writable(r, p, p + 1)
        return self.kv_pool.table_device(), jnp.asarray(self.active)

    def _ffn_input(self, p, x):
        return L.apply_norm(p["ffn_norm"], x, self.cfg)

    def _hi_expert(self, wi, wo, h):
        cfg = self.cfg
        z = h @ wi
        if cfg.ffn_activation == "swiglu":
            g, u = jnp.split(z, 2, axis=-1)
            z = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        else:
            z = jax.nn.gelu(z.astype(jnp.float32)).astype(h.dtype)
        return z @ wo

    def _lo_expert(self, wi_data, wi_scale, wo_data, wo_scale, h):
        cfg = self.cfg
        mc = cfg.moe
        d, f = cfg.d_model, mc.d_ff_expert
        qi = QTensor(wi_data, wi_scale, self.ecfg.lo_bits, self.ecfg.group_size, d)
        qo = QTensor(wo_data, wo_scale, self.ecfg.lo_bits, self.ecfg.group_size, f)
        z = (h.astype(jnp.float32) @ dequantize(qi)).astype(h.dtype)
        if cfg.ffn_activation == "swiglu":
            g, u = jnp.split(z, 2, axis=-1)
            z = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        else:
            z = jax.nn.gelu(z.astype(jnp.float32)).astype(h.dtype)
        return (z.astype(jnp.float32) @ dequantize(qo)).astype(h.dtype)

    def _activate(self, z):
        cfg = self.cfg
        if cfg.ffn_activation == "swiglu":
            g, u = jnp.split(z, 2, axis=-1)
            return jax.nn.silu(g.astype(jnp.float32)).astype(z.dtype) * u
        return jax.nn.gelu(z.astype(jnp.float32)).astype(z.dtype)

    def _grouped_ffn(self, hi_wi, hi_wo, lo_wi_data, lo_wi_scale, lo_wo_data,
                     lo_wo_scale, ovf_hi_wi, ovf_hi_wo, ovf_lo_wi_data,
                     ovf_lo_wi_scale, ovf_lo_wo_data, ovf_lo_wo_scale, h,
                     hi_rows, hi_ranks, hi_slot, lo_rows, lo_ranks, lo_slot,
                     w_hi, w_lo):
        """All active (row, expert) pairs of one MoE layer in two batched
        dispatches: one hi GEMM over the gathered hi-pool slots and one lo
        dequant-GEMM over the gathered lo-pool slots.  Index arrays have
        fixed length P = batch * top_k (padded entries carry row == batch,
        which the gather clips and the scatter drops), so each batch size
        compiles exactly once.  Hi-pair outputs land in a (B, K, D) grid at
        unique (row, rank) cells — combine order is fixed by the rank axis,
        keeping per-slot numerics independent of neighbouring slots; the lo
        half fuses GEMM + gated combine in `kops.grouped_dequant_combine`
        (pair rows are emitted non-decreasing by the builder below, the
        kernel's scatter contract).

        The ovf_* buffers carry union-overflow experts (cache smaller than
        the layer's union demand at batch > 1): they are appended after the
        pool slots, so slot index >= pool size addresses the overflow buffer
        and pairs never evict a slot a neighbouring pair already claimed."""
        ecfg = self.ecfg
        b, _, d = h.shape
        k = w_hi.shape[1]
        hs = h[:, 0]                                        # (B, D)
        # ---- one batched hi GEMM ----
        all_hi_wi = jnp.concatenate([hi_wi, ovf_hi_wi], axis=0)
        all_hi_wo = jnp.concatenate([hi_wo, ovf_hi_wo], axis=0)
        xh = hs[jnp.clip(hi_rows, 0, b - 1)]                # (P, D)
        z = jnp.einsum("pd,pdc->pc", xh, all_hi_wi[hi_slot])
        out_hi = jnp.einsum("pf,pfd->pd", self._activate(z), all_hi_wo[hi_slot])
        # ---- one batched lo dequant-GEMM ----
        all_lo = [jnp.concatenate([a, o], axis=0) for a, o in (
            (lo_wi_data, ovf_lo_wi_data), (lo_wi_scale, ovf_lo_wi_scale),
            (lo_wo_data, ovf_lo_wo_data), (lo_wo_scale, ovf_lo_wo_scale))]
        xl = hs[jnp.clip(lo_rows, 0, b - 1)]
        zl = kops.grouped_dequant_matmul(
            xl, all_lo[0][lo_slot], all_lo[1][lo_slot],
            bits=ecfg.lo_bits, group_size=ecfg.group_size).astype(hs.dtype)
        # second lo GEMM fused with the gated per-row combine: pad pairs
        # (row == b) carry weight 0 and are dropped in-kernel
        lo_w_pair = jnp.where(
            lo_rows < b, w_lo[jnp.clip(lo_rows, 0, b - 1), lo_ranks], 0.0)
        y_lo = kops.grouped_dequant_combine(
            self._activate(zl), all_lo[2][lo_slot], all_lo[3][lo_slot],
            lo_rows, lo_w_pair, bits=ecfg.lo_bits,
            group_size=ecfg.group_size, num_rows=b)         # (B, D) f32
        # ---- hi combine (unique (row, rank) cells; OOB pads dropped) ----
        grid = jnp.zeros((b, k, d), jnp.float32)
        grid = grid.at[hi_rows, hi_ranks].set(out_hi.astype(jnp.float32),
                                              mode="drop")
        y = (grid * w_hi[..., None]).sum(axis=1) + y_lo
        wsum = (w_hi + w_lo).sum(axis=1)[:, None]           # disjoint weights
        y = jnp.where(wsum > 0, y / jnp.where(wsum > 0, wsum, 1.0), 0.0)
        return y[:, None, :]                                # (B, 1, D)

    def _jit(self, name, fn, donate=()):
        if name not in self._jit_cache:
            self._jit_cache[name] = jax.jit(fn, donate_argnums=donate)
        return self._jit_cache[name]

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def start_batch(self, batch: int, max_len: int):
        """Allocate per-slot KV caches and reset serving state for a new
        (possibly multi-request) batch.  All slots start active; continuous-
        batching schedulers toggle individual slots via join()/release()."""
        self._check_open()
        self.batch = batch
        self.max_len = max_len
        self.scheduler.flush()          # land any cross-batch in-flight loads
        self.cache.new_sequence()
        if self.ecfg.paged_kv:
            from repro.models.kv_pages import ChunkedPrefill
            self.kv_cache = None
            self.kv_pool = self.model.init_cache(
                batch, max_len, paged=True,
                page_size=self.ecfg.kv_page_size,
                num_pages=self.ecfg.kv_pages,
                prefix_sharing=self.ecfg.prefix_sharing)
            self._admission = ChunkedPrefill(self.model, self.params,
                                             self.kv_pool,
                                             chunk=self.ecfg.prefill_chunk)
        else:
            self.kv_cache = [
                {"k": jnp.zeros((batch, max_len, self.cfg.num_kv_heads,
                                 self.cfg.resolved_head_dim), self.dtype),
                 "v": jnp.zeros((batch, max_len, self.cfg.num_kv_heads,
                                 self.cfg.resolved_head_dim), self.dtype)}
                for _ in range(self.cfg.num_layers)]
        self.positions = jnp.zeros((batch,), jnp.int32)
        self.active = np.ones((batch,), bool)
        self.trace = []
        self._pending_preds = []        # (Prediction, made_at_layer, slot)
        self._pending_joins = {}        # abandoned admissions don't leak
        self._unclaimed_joins = {}

    def start_sequence(self, max_len: int, batch: int = 1):
        self.start_batch(batch, max_len)

    # ---------------- prefill / slot admission ----------------
    def _prefill_fn(self):
        key = ("prefill", self.max_len)
        if key not in self._jit_cache:
            max_len = self.max_len
            self._jit_cache[key] = jax.jit(
                lambda p, b: self.model.prefill(p, b, max_len))
        return self._jit_cache[key]

    def _flat_decode_cache(self, cache):
        """Flatten model.prefill's nested cache into the engine's per-layer
        list.  Valid for the engine's model class: every layer is a full-
        window "attn" + MoE block, so every entry is a max_len k/v pair."""
        cfg = self.cfg
        assert all(k == "attn" for k in cfg.layer_kinds()), cfg.layer_kinds()
        flat = [dict(c) for c in cache["prefix"]]
        for bi in range(cfg.num_blocks):
            for j in range(cfg.period):
                flat.append(jax.tree_util.tree_map(lambda a, bi=bi: a[bi],
                                                   cache["blocks"][j]))
        flat.extend(dict(c) for c in cache["tail"])
        return flat

    def prefill_batch(self, prompts) -> np.ndarray:
        """Real prefill: run the whole prompt batch through the dense model
        in one jitted call (prefill is compute-bound and touches every expert
        anyway — the offload cache only serves the decode phase, matching the
        paper's deployment), then adopt the KV cache in the engine's
        per-layer layout.  Returns last-token logits (B, V)."""
        self._check_open()
        prompts = np.asarray(prompts, np.int32)
        b, s = prompts.shape
        assert b == self.batch, (b, self.batch)
        if self.ecfg.paged_kv:
            # chunked prefill through the page pool, still dense compute
            for r in range(b):
                self._admission.begin(r, prompts[r],
                                      reserve_tokens=self.max_len)
            done = {}
            while len(done) < b:
                done.update(self._admission.step())
            self.positions = jnp.full((b,), s, jnp.int32)
            self.active[:] = True
            return np.stack([done[r] for r in range(b)])
        batch = Batch(tokens=jnp.asarray(prompts),
                      loss_mask=jnp.ones((b, s), jnp.float32))
        logits, cache, positions = self._prefill_fn()(self.params, batch)
        self.kv_cache = self._flat_decode_cache(cache)
        self.positions = positions
        self.active[:] = True
        return np.asarray(logits, np.float32)

    def join(self, slot: int, prompt) -> np.ndarray:
        """Admit one request into a free slot mid-flight (blocking).  This is
        a documented thin wrapper over ``join_begin``/``join_step`` — the ONE
        blocking-join implementation lives in ``serving.api._blocking_join``
        and is shared by every backend.  Returns logits (V,)."""
        self._check_open()
        assert 0 <= slot < self.batch, (slot, self.batch)
        from repro.serving.api import _blocking_join
        return _blocking_join(self, slot, prompt)

    def _join_dense(self, slot: int, prompt) -> np.ndarray:
        """Dense-KV one-shot admission body: batch=1 prefill, KV scattered
        into the slot's cache rows.  Called from join_step."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        batch = Batch(tokens=jnp.asarray(prompt[None]),
                      loss_mask=jnp.ones((1, len(prompt)), jnp.float32))
        logits, cache, positions = self._prefill_fn()(self.params, batch)
        one = self._flat_decode_cache(cache)
        for li in range(self.cfg.num_layers):
            self.kv_cache[li] = jax.tree_util.tree_map(
                lambda dst, src: dst.at[slot].set(src[0].astype(dst.dtype)),
                self.kv_cache[li], one[li])
        self.positions = self.positions.at[slot].set(int(positions[0]))
        self.active[slot] = True
        self._pending_preds = [pp for pp in self._pending_preds
                               if pp[2] != slot]
        return np.asarray(logits[0], np.float32)

    def join_begin(self, slot: int, prompt, reserve_tokens=None):
        """Start an incremental admission into `slot`.  Paged KV: reserves
        pages for `reserve_tokens` (default max_len) and queues the prompt
        for chunked prefill.  Dense KV: stashes the prompt (join_step then
        runs the one-shot prefill)."""
        self._check_open()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if self.ecfg.paged_kv:
            self._admission.begin(slot, prompt,
                                  reserve_tokens=reserve_tokens or self.max_len)
        else:
            self._pending_joins[slot] = prompt

    def join_step(self) -> Dict[int, np.ndarray]:
        """Advance every in-progress admission one prefill chunk (ONE shared
        jitted call under paged KV); completed slots become active.  Returns
        {slot: last-token logits} — including logits another slot's blocking
        ``join`` finished but did not claim."""
        done: Dict[int, np.ndarray] = dict(self._unclaimed_joins)
        self._unclaimed_joins = {}
        if self.ecfg.paged_kv:
            done.update(self._admission.step())
            for slot in done:
                plen = int(self.kv_pool.lens[slot])
                self.positions = self.positions.at[slot].set(plen)
                self.active[slot] = True
                self._pending_preds = [pp for pp in self._pending_preds
                                       if pp[2] != slot]
            return done
        for slot, prompt in list(self._pending_joins.items()):
            del self._pending_joins[slot]
            done[slot] = self._join_dense(slot, prompt)
        return done

    def can_admit(self, tokens: int, *, prompt=None) -> bool:
        """KV-capacity admission gate: paged KV checks unreserved pages
        (with `prompt`, net of the best prefix-sharing plan — aliased
        prefix pages cost nothing); dense KV always admits (slots are
        pre-allocated to max_len)."""
        if self.ecfg.paged_kv and self.kv_pool is not None:
            return self.kv_pool.can_reserve(tokens, prompt=prompt)
        return True

    def release(self, slot: int):
        """Free a slot (its KV rows become junk until the next join; paged
        KV returns the slot's pages to the pool).  Retires the request from
        the fleet heat map (one decay tick — the cross-request prior ages
        by requests, not wall clock)."""
        self.active[slot] = False
        self._pending_preds = [pp for pp in self._pending_preds
                               if pp[2] != slot]
        if self.ecfg.paged_kv and self.kv_pool is not None:
            self.kv_pool.release(slot)
        self.fleet.retire_request()

    def pause(self, slot: int) -> Dict:
        """Preempt `slot` mid-decode: snapshot its KV state to host, then
        free the slot (paged KV returns its pages to the pool — the
        snapshot is taken FIRST, so aliased prefix pages are copied out
        while the remaining sharers keep the originals and their
        refcounts).  Returns the opaque snapshot for ``resume``.  The
        expert cache is untouched: it is shared, and the fleet heat map
        keeps the victim's experts warm for its return."""
        self._check_open()
        pos = int(np.asarray(self.positions)[slot])
        self._pending_preds = [pp for pp in self._pending_preds
                               if pp[2] != slot]
        if self.ecfg.paged_kv and self.kv_pool is not None:
            snap = self.kv_pool.snapshot_slot(slot)
            self.kv_pool.release(slot)
            self.active[slot] = False
            return {"layout": "paged", "position": pos, "kv": snap}
        rows = [{"k": np.asarray(c["k"][slot]), "v": np.asarray(c["v"][slot])}
                for c in self.kv_cache]
        self.active[slot] = False
        return {"layout": "dense", "position": pos, "cache": rows}

    def resume(self, slot: int, snapshot: Dict) -> None:
        """Reinstate a paused request into (a possibly different) `slot`
        from its ``pause`` snapshot; decode continues logits-identically.
        Paged KV raises PagePoolExhausted when the pool cannot host the
        snapshot right now (the scheduler keeps it and retries)."""
        self._check_open()
        if snapshot["layout"] == "paged":
            self.kv_pool.restore_slot(slot, snapshot["kv"])
        else:
            for li, row in enumerate(snapshot["cache"]):
                c = self.kv_cache[li]
                self.kv_cache[li] = {
                    "k": c["k"].at[slot].set(jnp.asarray(row["k"])),
                    "v": c["v"].at[slot].set(jnp.asarray(row["v"]))}
        self.positions = self.positions.at[slot].set(int(snapshot["position"]))
        self.active[slot] = True

    # ---------------- batched HOBBIT decode ----------------
    def decode_step_batch(self, tokens) -> np.ndarray:
        """One batched HOBBIT decode step.  tokens: (B,) int32; returns
        logits (B, V).  Inactive slots ride through attention (their rows
        are junk and cheap) but take no part in gating, expert loading,
        expert compute, the trace, or position advancement.  Expert loading
        is the union of all active slots' demands; precision decisions stay
        per-slot, so each slot's numerics match its own batch=1 run.

        Two implementations share this contract: the grouped path (default —
        one batched gating matmul, one hi GEMM and one lo dequant-GEMM per
        MoE layer, async double-buffered prefetch) and the per-expert
        reference path (``grouped=False`` or host compute mode), kept as the
        numerics baseline the parity tests compare against."""
        self._check_open()
        if self.ecfg.grouped and self.ecfg.compute_mode == "device":
            return self._decode_step_batch_grouped(tokens)
        return self._decode_step_batch_reference(tokens)

    # ---- shared per-layer bookkeeping ----
    def _score_pending_preds(self, mi: int, tops: Dict[int, np.ndarray]):
        """Score the accuracy of earlier predictions that targeted layer mi."""
        still_pending = []
        for pred, made_at, r in self._pending_preds:
            if pred.layer == mi:
                if r in tops:
                    self.predictor.record_accuracy(pred, tops[r].tolist(),
                                                   mi - made_at)
            elif pred.layer > mi:
                still_pending.append((pred, made_at, r))
        self._pending_preds = still_pending

    def _push_pending(self, pr, mi: int, r: int):
        """Record a pending prediction, keeping AT MOST ONE per (layer,
        slot): a newer prediction (made closer to the target layer, from
        fresher hidden state) replaces an older one, so record_accuracy
        scores each (layer, slot) exactly once."""
        self._pending_preds = [
            (p, m, rr) for p, m, rr in self._pending_preds
            if not (p.layer == pr.layer and rr == r)]
        self._pending_preds.append((pr, mi, r))

    def _prefetch_predictions(self, mi: int, rows, h_host, *,
                              use_async: bool) -> Dict[int, object]:
        """Adaptive prefetch for subsequent layers (§3.3).

        Pending-prediction bookkeeping is deduplicated: previously both the
        adaptive walk and the extra plain next-layer prediction appended an
        entry for the same (layer, slot), so record_accuracy could count a
        layer twice per slot and pred_entry[r] was silently overwritten.
        Now the walk's entry wins and the plain next-layer prediction (kept
        for the trace/simulator) is only recorded when the walk did not
        already cover layer mi+1."""
        pred_entry: Dict[int, object] = {}
        # merge all rows' predictions per target layer so the async scheduler
        # stages ONE job per layer instead of one tiny job per batch slot;
        # each (expert, precision) pair keeps the LARGEST gate any row gave
        # it — the staging engine issues biggest-gate-first under contention
        merged: Dict[int, List[Tuple[int, int]]] = {}
        gmax: Dict[Tuple[int, int, int], float] = {}
        for r in rows:
            walk = self.predictor.adaptive_walk(h_host[r], mi, self.cache,
                                                self.loader.th)
            walk_layers = set()
            for pr, dec in walk:
                pairs = merged.setdefault(pr.layer, [])
                for e, d, g in zip(pr.experts, dec, pr.gate_vals):
                    if (int(e), int(d)) not in pairs:
                        pairs.append((int(e), int(d)))
                    gk = (pr.layer, int(e), int(d))
                    gmax[gk] = max(gmax.get(gk, 0.0), float(g))
                self._push_pending(pr, mi, r)
                walk_layers.add(pr.layer)
                if pr.layer == mi + 1:
                    pred_entry[r] = pr
            if mi + 1 not in walk_layers:
                nxt = self.predictor.predict_layers(h_host[r], mi, 1)
                if nxt:
                    self._push_pending(nxt[0], mi, r)
                    pred_entry[r] = nxt[0]
        for layer, pairs in merged.items():
            experts = [e for e, _ in pairs]
            dec = np.asarray([d for _, d in pairs])
            gates = np.asarray([gmax[(layer, e, d)] for e, d in pairs])
            if use_async:
                self.scheduler.submit_prefetch(layer, experts, dec,
                                               current_layer=mi, gates=gates)
            else:
                self.loader.enqueue_prefetch(layer, experts, dec)
        return pred_entry

    def _trace_entry(self, mi, r, tops, gates, pred_entry) -> TraceLayer:
        pe = pred_entry.get(r)
        return TraceLayer(
            experts=tops[r].tolist(), gate_vals=gates[r],
            pred_experts=pe.experts if (pe and pe.layer == mi + 1) else None,
            pred_gate_vals=pe.gate_vals if (pe and pe.layer == mi + 1) else None)

    # ---- grouped implementation (the serving hot path) ----
    def _decode_step_batch_grouped(self, tokens) -> np.ndarray:
        cfg, ecfg, mc = self.cfg, self.ecfg, self.cfg.moe
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        assert tokens.shape[0] == self.batch, (tokens.shape, self.batch)
        b, k = self.batch, mc.top_k
        rows = [r for r in range(b) if self.active[r]]
        self.cache.advance_token()
        tok = jnp.asarray(tokens[:, None])
        x = jnp.take(self.params["embed"], tok, axis=0)
        if cfg.scale_embedding:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)

        ffn_in = self._jit("ffn_in", self._ffn_input)
        # fused router: stacked matmul + softmax + top-k in one dispatch
        # (kernels/ops.gating_topk; ref path on CPU, pallas on TPU)
        gate_fn = self._jit(
            "gate", lambda h2, w: kops.gating_topk(h2, w[None], top_k=k))
        grouped_ffn = self._jit("grouped_ffn", self._grouped_ffn)
        combine_fn = self._jit("residual_add",
                               lambda xx, yy: xx + yy.astype(xx.dtype))

        table = active_dev = None
        if ecfg.paged_kv:
            table, active_dev = self._paged_step_prologue(rows)
        row_trace = {r: [] for r in rows}
        for mi, li in enumerate(self.moe_layers):
            t_layer0 = time.perf_counter()
            # deadline hint: the staging engine budgets queued copies against
            # (target_layer - mi) * per-layer compute seconds of link time
            self.scheduler.set_deadline_clock(mi, self._layer_s_ema,
                                              self._layer_period_ema)
            p = self.layer_params[li]
            x = self._attn_layer(li, x, table=table, active_dev=active_dev)
            h = ffn_in(p, x)                                   # (B,1,D)

            # ---- gating: ONE (B,D)@(D,E) matmul from the stacked routers --
            h_host = np.asarray(h[:, 0], np.float32)           # (B,D)
            # (forcing h above keeps the pending attn/ffn-in compute out of
            # the gating timer)
            tg0 = time.perf_counter()
            _, vals_g, idx_g = gate_fn(h[:, 0], self.routers_dev[mi])
            vals_np = np.asarray(vals_g[0], np.float32)        # (B,K)
            idx_np = np.asarray(idx_g[0], np.int32)            # (B,K)
            self._gating_s += time.perf_counter() - tg0
            tops: Dict[int, np.ndarray] = {}
            gates: Dict[int, np.ndarray] = {}
            for r in rows:
                tops[r] = idx_np[r]
                gates[r] = vals_np[r]
                for e, g in zip(tops[r], gates[r]):
                    self.fleet.observe((mi, int(e)), float(g))

            self._score_pending_preds(mi, tops)

            # ---- on-demand scoring (union over slots) ----
            # Hard-pin this layer's experts BEFORE prefetch admission: async
            # submit_prefetch admits (and may evict) at submit time, so
            # without the pins it could evict a resident expert this very
            # layer is about to compute with.
            self.loader.new_layer()
            for r in rows:
                self.loader.score_and_enqueue(mi, tops[r].tolist(), gates[r],
                                              clear_pins=False)

            pred_entry = {}
            if ecfg.prefetch:
                pred_entry = self._prefetch_predictions(
                    mi, rows, h_host, use_async=ecfg.async_prefetch)

            # ---- loading ----
            t_load0 = time.perf_counter()
            if ecfg.async_prefetch:
                # barrier: land every prefetch targeting this layer (copies
                # have been staging in the background since they were
                # predicted), then blocking-load the residual miss set in one
                # batched transfer
                self.scheduler.wait(mi)
                self.scheduler.drain_on_demand(self.loader.take_queued(), mi)
            else:
                self.loader.drain(mi)
            t_load = time.perf_counter() - t_load0

            # ---- grouped expert compute: 1 hi + 1 lo dispatch ----
            # Union-overflow pairs (a same-layer neighbour's admission
            # evicted this expert: union demand > pool) ride in per-layer
            # overflow staging buffers appended after the pool slots instead
            # of re-admitting — re-admission could evict a slot an earlier
            # pair already claimed, corrupting its compute.  The re-fetch
            # still counts as a miss + load so hit_ratio reflects real
            # traffic under contention.
            pp = b * k
            hi_rows = np.full(pp, b, np.int32)
            hi_ranks = np.zeros(pp, np.int32)
            hi_slots = np.zeros(pp, np.int32)
            lo_rows = np.full(pp, b, np.int32)
            lo_ranks = np.zeros(pp, np.int32)
            lo_slots = np.zeros(pp, np.int32)
            w_hi = np.zeros((b, k), np.float32)
            w_lo = np.zeros((b, k), np.float32)
            ovf = self._overflow_buffers(pp)
            n_hi = n_lo = 0
            n_ovf_hi = n_ovf_lo = 0
            for r in rows:
                dec = precision_decisions(gates[r], self.loader.th)
                for j in range(k):
                    d_ = int(dec[j])
                    if d_ == PREC_SKIP:
                        continue
                    e = int(tops[r][j])
                    is_hi = d_ == PREC_HI
                    slot = self.cache.lookup((mi, e), is_hi)
                    if (slot is not None and is_hi
                            and self.cache.is_inflight((mi, e), True)):
                        # an upgrade re-copy owns the slot but its bytes are
                        # still landing (wait() never blocks on upgrades);
                        # the slot holds no hi weights yet
                        slot = None
                    if (slot is None and is_hi and ecfg.async_prefetch
                            and self.scheduler.serves_lo_downgrade(mi, e)):
                        # issue-time precision downgrade: the staging engine
                        # replaced this hi copy with a lo one under link
                        # pressure — compute from the lo pool until an
                        # idle-link upgrade lands the hi copy
                        is_hi = False
                        slot = self.cache.lookup((mi, e), False)
                        self.scheduler.served_lo_expert_steps += 1
                    if slot is None:
                        if is_hi:
                            self.cache.stats.misses_hi += 1
                        else:
                            self.cache.stats.misses_lo += 1
                        buf = self._stage(mi, e, d_)
                        if is_hi:
                            ovf["hi_wi"][n_ovf_hi] = buf["wi"]
                            ovf["hi_wo"][n_ovf_hi] = buf["wo"]
                            slot = self.ecfg.hi_slots + n_ovf_hi
                            n_ovf_hi += 1
                        else:
                            for name in ("wi_data", "wi_scale", "wo_data",
                                         "wo_scale"):
                                ovf[f"lo_{name}"][n_ovf_lo] = buf[name]
                            slot = self.ecfg.lo_slots + n_ovf_lo
                            n_ovf_lo += 1
                        self.loader.loaded_bytes += self.expert_bytes[d_]
                        self.loader.n_loads[d_] += 1
                        self._union_reloads += 1
                    if is_hi:
                        hi_rows[n_hi], hi_ranks[n_hi] = r, j
                        hi_slots[n_hi] = slot
                        w_hi[r, j] = gates[r][j]
                        n_hi += 1
                    else:
                        lo_rows[n_lo], lo_ranks[n_lo] = r, j
                        lo_slots[n_lo] = slot
                        w_lo[r, j] = gates[r][j]
                        n_lo += 1

            y = grouped_ffn(self.pool_hi["wi"], self.pool_hi["wo"],
                            self.pool_lo["wi_data"], self.pool_lo["wi_scale"],
                            self.pool_lo["wo_data"], self.pool_lo["wo_scale"],
                            jnp.asarray(ovf["hi_wi"], self.dtype),
                            jnp.asarray(ovf["hi_wo"], self.dtype),
                            jnp.asarray(ovf["lo_wi_data"]),
                            jnp.asarray(ovf["lo_wi_scale"]),
                            jnp.asarray(ovf["lo_wo_data"]),
                            jnp.asarray(ovf["lo_wo_scale"]),
                            h, jnp.asarray(hi_rows), jnp.asarray(hi_ranks),
                            jnp.asarray(hi_slots), jnp.asarray(lo_rows),
                            jnp.asarray(lo_ranks), jnp.asarray(lo_slots),
                            jnp.asarray(w_hi), jnp.asarray(w_lo))
            self._expert_dispatches += 1
            x = combine_fn(x, y)

            for r in rows:
                row_trace[r].append(self._trace_entry(mi, r, tops, gates,
                                                      pred_entry))
            # downgrade markers are per-token decisions: consumed this layer,
            # never carried into later steps' precision choices
            self.scheduler.retire_layer(mi)
            # per-layer compute EMA (loading time excluded) — the staging
            # engine's deadline clock budgets link bytes against it — and
            # full-period EMA (loading included) — its per-pump stream feed
            dt_full = time.perf_counter() - t_layer0
            dt = dt_full - t_load
            self._layer_s_ema = (dt if self._layer_s_ema == 0.0
                                 else 0.8 * self._layer_s_ema + 0.2 * dt)
            self._layer_period_ema = (
                dt_full if self._layer_period_ema == 0.0
                else 0.8 * self._layer_period_ema + 0.2 * dt_full)

        self.positions = self.positions + jnp.asarray(
            self.active.astype(np.int32))
        for r in rows:
            self.trace.append(row_trace[r])
        lg = self.model.logits(self.params, x)[:, 0]
        return np.asarray(lg, np.float32)

    # ---- per-expert reference implementation (parity baseline) ----
    def _decode_step_batch_reference(self, tokens) -> np.ndarray:
        cfg, ecfg, mc = self.cfg, self.ecfg, self.cfg.moe
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        assert tokens.shape[0] == self.batch, (tokens.shape, self.batch)
        rows = [r for r in range(self.batch) if self.active[r]]
        self.cache.advance_token()
        tok = jnp.asarray(tokens[:, None])
        x = jnp.take(self.params["embed"], tok, axis=0)
        if cfg.scale_embedding:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)

        ffn_in = self._jit("ffn_in", self._ffn_input)
        hi_exp = self._jit("hi", self._hi_expert)
        lo_exp = self._jit("lo", self._lo_expert)

        table = active_dev = None
        if ecfg.paged_kv:
            table, active_dev = self._paged_step_prologue(rows)
        row_trace = {r: [] for r in rows}
        for mi, li in enumerate(self.moe_layers):
            p = self.layer_params[li]
            x = self._attn_layer(li, x, table=table, active_dev=active_dev)
            h = ffn_in(p, x)                                   # (B,1,D)
            h_host = np.asarray(h[:, 0], np.float32)           # (B,D)

            # ---- gate (the paper's Expert Scorer input), per slot ----
            tops: Dict[int, np.ndarray] = {}
            gates: Dict[int, np.ndarray] = {}
            for r in rows:
                logits = h_host[r] @ self.routers[mi]
                probs = np.exp(logits - logits.max())
                probs /= probs.sum()
                tops[r] = np.argsort(-probs)[: mc.top_k]
                gates[r] = probs[tops[r]]
                for e, g in zip(tops[r], gates[r]):
                    self.fleet.observe((mi, int(e)), float(g))

            self._score_pending_preds(mi, tops)
            pred_entry = {}
            if ecfg.prefetch:
                pred_entry = self._prefetch_predictions(mi, rows, h_host,
                                                        use_async=False)

            # ---- on-demand scoring + loading (union over slots) ----
            self.loader.new_layer()
            for r in rows:
                self.loader.score_and_enqueue(mi, tops[r].tolist(), gates[r],
                                              clear_pins=False)
            self.loader.drain(mi)

            # ---- expert compute from cache slots, per slot ----
            y_rows = []
            for r in range(self.batch):
                if r not in row_trace:
                    y_rows.append(jnp.zeros_like(h[r : r + 1]))
                    continue
                hr = h[r : r + 1]
                dec = precision_decisions(gates[r], self.loader.th)
                y = jnp.zeros_like(hr)
                wsum = 0.0
                for e, d_, w in zip(tops[r], dec, gates[r]):
                    if d_ == PREC_SKIP:
                        continue
                    is_hi = d_ == PREC_HI
                    slot = self.cache.lookup((mi, e), is_hi)
                    if slot is None:
                        # union-overflow reload (see grouped path)
                        if is_hi:
                            self.cache.stats.misses_hi += 1
                        else:
                            self.cache.stats.misses_lo += 1
                        slot, _ = self.cache.admit((mi, int(e)), is_hi, mi)
                        self._fetch(mi, int(e), int(d_), slot)
                        self.loader.loaded_bytes += self.expert_bytes[int(d_)]
                        self.loader.n_loads[int(d_)] += 1
                        self._union_reloads += 1
                    if self.ecfg.compute_mode == "host":
                        out = self._host_expert(mi, int(e), d_,
                                                np.asarray(hr, np.float32))
                        out = jnp.asarray(out, hr.dtype)
                    elif is_hi:
                        out = hi_exp(self.pool_hi["wi"][slot],
                                     self.pool_hi["wo"][slot], hr)
                    else:
                        out = lo_exp(self.pool_lo["wi_data"][slot],
                                     self.pool_lo["wi_scale"][slot],
                                     self.pool_lo["wo_data"][slot],
                                     self.pool_lo["wo_scale"][slot], hr)
                    y = y + float(w) * out.astype(jnp.float32)
                    wsum += float(w)
                if wsum > 0:
                    y = y / wsum                                # renormalize (skips)
                y_rows.append(y)
                row_trace[r].append(self._trace_entry(mi, r, tops, gates,
                                                      pred_entry))
            x = x + jnp.concatenate(y_rows, axis=0).astype(x.dtype)

        self.positions = self.positions + jnp.asarray(
            self.active.astype(np.int32))
        for r in rows:
            self.trace.append(row_trace[r])
        lg = self.model.logits(self.params, x)[:, 0]
        return np.asarray(lg, np.float32)

    def close(self):
        """Release the staging engine's worker threads (also released
        automatically when the engine is garbage-collected).  Idempotent:
        a second close is a no-op; stepping a closed engine raises
        RuntimeError cleanly instead of failing deep inside the executor."""
        if self._closed:
            return
        self._closed = True
        self.scheduler.flush()
        self.scheduler.shutdown()

    def _check_open(self):
        """Raise cleanly when serving entry points run after close()."""
        if self._closed:
            raise RuntimeError("OffloadEngine is closed; create a new engine "
                               "(close() released its staging threads)")

    def decode_token(self, token: int) -> np.ndarray:
        """One HOBBIT decode step (batch=1 legacy API).  Returns logits (V,)."""
        assert self.batch == 1, "decode_token is batch=1; use decode_step_batch"
        return self.decode_step_batch(np.asarray([int(token)], np.int32))[0]

    def _host_expert(self, mi, e, d_, h):
        """CPU-GPU cooperative mode (§4): run the expert on host weights."""
        cfg = self.cfg
        if d_ == PREC_HI:
            wi = self.storage_hi[mi]["wi"][e]
            wo = self.storage_hi[mi]["wo"][e]
        else:
            wi = np.asarray(dequantize(jax.tree_util.tree_map(
                lambda a: a[e], self.storage_lo[mi]["wi"])))
            wo = np.asarray(dequantize(jax.tree_util.tree_map(
                lambda a: a[e], self.storage_lo[mi]["wo"])))
        z = h @ wi
        if cfg.ffn_activation == "swiglu":
            g, u = np.split(z, 2, axis=-1)
            z = (g / (1 + np.exp(-g))) * u
        else:
            z = 0.5 * z * (1 + np.tanh(np.sqrt(2 / np.pi) * (z + 0.044715 * z**3)))
        return z @ wo

    # ------------------------------------------------------------------
    # evaluation helpers
    # ------------------------------------------------------------------
    def generate(self, prompt: List[int], new_tokens: int,
                 max_len: Optional[int] = None) -> List[int]:
        max_len = max_len or (len(prompt) + new_tokens + 1)
        self.start_sequence(max_len)
        lg = None
        for t in prompt:
            lg = self.decode_token(int(t))
        out = []
        for _ in range(new_tokens):
            nxt = int(np.argmax(lg))
            out.append(nxt)
            lg = self.decode_token(nxt)
        return out

    def score_nll(self, tokens: List[int], max_len: Optional[int] = None) -> float:
        """Teacher-forced mean NLL through the offload path (accuracy evals)."""
        max_len = max_len or (len(tokens) + 1)
        self.start_sequence(max_len)
        nll, n = 0.0, 0
        lg = self.decode_token(int(tokens[0]))
        for t in tokens[1:]:
            p = lg - lg.max()
            p = p - np.log(np.exp(p).sum())
            nll -= p[int(t)]
            n += 1
            lg = self.decode_token(int(t))
        return nll / max(n, 1)

    def stats(self) -> Dict:
        """Fully JSON-serializable engine counters: cache hit/miss/eviction
        breakdown (with hit_ratio), loader traffic, predictor accuracy, and
        the async scheduler's wall-clock stall/overlap accounting."""
        s = {
            "cache": self.cache.stats.to_dict(),
            "loads_hi": self.loader.n_loads[PREC_HI],
            "loads_lo": self.loader.n_loads[PREC_LO],
            "skips": self.loader.n_skips,
            "loaded_bytes": self.loader.loaded_bytes,
            "pred_accuracy": {int(d): float(a)
                              for d, a in self.predictor.accuracy().items()},
            "gating_s": self._gating_s,
            "expert_dispatches": self._expert_dispatches,
            "union_reloads": self._union_reloads,
            # which kernel implementation each hot-path op dispatched/traced
            # ("<op>.<xla|pallas|pallas_interpret>" -> count): a TPU run
            # showing only .xla counts is silently benchmarking the einsum
            # oracle path
            "kernel_dispatch": kops.dispatch_counts(),
            # KV page-pool pressure (zeros under the dense KV layout)
            "kv_pages_used": 0, "kv_pages_total": 0, "kv_page_fraction": 0.0,
        }
        if self.ecfg.paged_kv and self.kv_pool is not None:
            s.update(self.kv_pool.stats())
        s.update(self.scheduler.stats())
        return s
