"""Layer-level Adaptive Expert Predictor (HOBBIT §3.3).

Uses the *current* layer's gating input (the pre-FFN hidden state) as a proxy
for the gating inputs of the next `p` layers — valid because the residual
stream changes slowly across layers (Fig. 7a) — and evaluates all `p` gate
matmuls at once with the Stacking Computer (our Pallas stacked_gating kernel).

The adaptive walk: predict layer l+1; if all predicted experts are cached,
continue to l+2, ... stop at the first layer with a miss (that's the one
worth prefetching for) or after `p` layers.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cache import MultidimensionalCache
from repro.core.scoring import Thresholds, precision_decisions, PREC_HI, PREC_SKIP
from repro.kernels import ops as kops


@dataclasses.dataclass
class Prediction:
    layer: int                 # the layer these experts belong to
    experts: List[int]         # predicted top-k
    gate_vals: np.ndarray      # predicted gate magnitudes (for precision choice)


class AdaptiveExpertPredictor:
    """Holds stacked router weights (L, D, E); predicts future layers' experts."""

    def __init__(self, routers: Sequence[np.ndarray], top_k: int,
                 p: int = 2, mode: str = "auto", *, fleet=None,
                 fleet_weight: float = 0.0):
        """fleet: optional ``core.fleet_heat.FleetHeat``.  With
        fleet_weight > 0, each predicted layer's gate distribution is
        blended with the fleet's per-layer expert prior
        (``(1-w)*probs + w*layer_prior``) before the top-k cut, so a fresh
        request's first prefetches lean on cross-request popularity.  The
        default weight 0.0 leaves the prediction numerics untouched."""
        self.gates = jnp.asarray(np.stack([np.asarray(r) for r in routers]))
        self.num_layers, self.d_model, self.num_experts = self.gates.shape
        self.top_k = top_k
        self.p = p
        self.mode = mode
        self.fleet = fleet
        self.fleet_weight = float(fleet_weight)
        # accuracy bookkeeping: self.eval[d] = (correct_top1, total) for dist d
        self._acc: dict[int, List[int]] = {}

    # ---------------- raw prediction ----------------
    def predict_layers(self, hidden: np.ndarray, layer: int,
                       p: Optional[int] = None) -> List[Prediction]:
        """hidden: (D,) gating input at `layer`.  Predict layers l+1..l+p via
        one stacked gating call."""
        p = p if p is not None else self.p
        lo, hi = layer + 1, min(layer + p, self.num_layers - 1)
        if lo > hi:
            return []
        x = jnp.asarray(hidden, self.gates.dtype)[None, :]        # (1, D)
        stack = self.gates[lo : hi + 1]                            # (P, D, E)
        logits = kops.stacked_gating(x, stack, mode=self.mode)     # (P, 1, E)
        probs = np.asarray(jnp.squeeze(
            jnp.exp(logits - jnp.max(logits, -1, keepdims=True))
            / jnp.sum(jnp.exp(logits - jnp.max(logits, -1, keepdims=True)),
                      -1, keepdims=True), axis=1))
        preds = []
        w = self.fleet_weight if self.fleet is not None else 0.0
        for i, l in enumerate(range(lo, hi + 1)):
            pl = probs[i]
            if w > 0.0:
                pl = (1.0 - w) * pl + w * self.fleet.layer_prior(
                    l, self.num_experts)
            idx = np.argsort(-pl)[: self.top_k]
            preds.append(Prediction(l, idx.tolist(), pl[idx]))
        return preds

    # ---------------- adaptive walk ----------------
    def adaptive_walk(self, hidden: np.ndarray, layer: int,
                      cache: MultidimensionalCache,
                      th: Thresholds) -> List[Tuple[Prediction, np.ndarray]]:
        """Walk forward; return [(prediction, precision_decisions)] for the
        first future layer whose predicted experts are not fully cached
        (the paper preloads exactly those), or [] if everything is resident."""
        preds = self.predict_layers(hidden, layer)
        for pr in preds:
            dec = precision_decisions(pr.gate_vals, th)
            missing = []
            for e, d in zip(pr.experts, dec):
                if d == PREC_SKIP:
                    continue
                if cache.lookup((pr.layer, e), d == PREC_HI) is None:
                    missing.append(True)
                else:
                    missing.append(False)
            # pin resident predicted experts either way (§3.3 "mask")
            for e, d in zip(pr.experts, dec):
                if d != PREC_SKIP:
                    cache.pin((pr.layer, e), d == PREC_HI)
            if any(missing):
                return [(pr, dec)]
        return []

    # ---------------- accuracy bookkeeping ----------------
    def record_accuracy(self, predicted: Prediction, actual_top: Sequence[int],
                        distance: int):
        c, t = self._acc.get(distance, [0, 0])
        c += int(predicted.experts[0] in list(actual_top[: 1]))
        t += 1
        self._acc[distance] = [c, t]

    def accuracy(self) -> dict[int, float]:
        return {d: c / t for d, (c, t) in sorted(self._acc.items()) if t}


def gating_input_similarity(hiddens: np.ndarray, max_dist: int = 3) -> dict[int, float]:
    """Mean cosine similarity of gating inputs between layer l and l+d
    (Fig. 7a reproduction).  hiddens: (L, D) per-layer gating inputs for one
    token (or (L, T, D) averaged over tokens)."""
    h = np.asarray(hiddens, np.float64)
    if h.ndim == 2:
        h = h[:, None, :]
    l = h.shape[0]
    out = {}
    for d in range(1, max_dist + 1):
        sims = []
        for i in range(l - d):
            a, b = h[i], h[i + d]
            num = (a * b).sum(-1)
            den = np.linalg.norm(a, axis=-1) * np.linalg.norm(b, axis=-1) + 1e-12
            sims.append(num / den)
        out[d] = float(np.mean(sims))
    return out
