"""Token-level expert importance scoring (HOBBIT §3.2).

The unimportance degree of the i-th selected expert (experts sorted by
descending normalized gate magnitude ||G(x)||) is the cumulative mass of the
experts ranked above it:

    s_{e_0} = 0;   s_{e_i} = sum_{j<i} ||G(x)_{e_j}||        (Eq. 2)

Precision policy: s <= T1 -> high precision; T1 < s <= T2 -> low precision;
s > T2 -> skip.  e_0 always loads high precision.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

PREC_HI, PREC_LO, PREC_SKIP = 0, 1, 2
PREC_NAMES = {PREC_HI: "hi", PREC_LO: "lo", PREC_SKIP: "skip"}


@dataclasses.dataclass(frozen=True)
class Thresholds:
    t1: float = 0.6
    t2: float = 0.9

    def __post_init__(self):
        assert 0.0 <= self.t1 <= self.t2 <= 1.0 + 1e-9, (self.t1, self.t2)


def normalize_gates(gate_vals: np.ndarray) -> np.ndarray:
    """Normalize selected-expert gate magnitudes to sum to 1 (the paper
    normalizes ||G(x)|| before accumulating)."""
    g = np.abs(np.asarray(gate_vals, np.float64))
    s = g.sum(axis=-1, keepdims=True)
    return g / np.maximum(s, 1e-12)


def unimportance_scores(gate_vals: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """gate_vals: (k,) or (B,k) selected-expert gate magnitudes (any order).

    Returns (order, scores): `order` indexes experts by descending gate value;
    `scores[i]` is Eq. 2's s for the expert at rank i."""
    g = normalize_gates(gate_vals)
    order = np.argsort(-g, axis=-1, kind="stable")
    g_sorted = np.take_along_axis(g, order, axis=-1)
    cum = np.cumsum(g_sorted, axis=-1)
    scores = np.concatenate([np.zeros_like(cum[..., :1]), cum[..., :-1]], axis=-1)
    return order, scores


def precision_decisions(gate_vals: np.ndarray, th: Thresholds) -> np.ndarray:
    """Per selected expert (original order), decide PREC_HI / LO / SKIP."""
    order, scores = unimportance_scores(gate_vals)
    dec_sorted = np.where(scores <= th.t1, PREC_HI,
                          np.where(scores <= th.t2, PREC_LO, PREC_SKIP))
    dec_sorted[..., 0] = PREC_HI  # rank-0 expert always high precision
    dec = np.empty_like(dec_sorted)
    np.put_along_axis(dec, order, dec_sorted, axis=-1)
    return dec


def calibrate_thresholds(score_samples: np.ndarray, *, frac_hi: float = 0.67,
                         frac_lo: float = 0.30) -> Thresholds:
    """Pick T1/T2 so that ~frac_hi of selections are high precision and
    ~frac_lo low precision (the paper's 67/30/3 split, Fig. 5b).

    score_samples: flat array of Eq. 2 scores collected on a calibration set."""
    s = np.sort(np.asarray(score_samples, np.float64).ravel())
    if len(s) == 0:
        return Thresholds()
    t1 = float(s[min(int(frac_hi * len(s)), len(s) - 1)])
    t2 = float(s[min(int((frac_hi + frac_lo) * len(s)), len(s) - 1)])
    t1 = min(max(t1, 0.0), 1.0)
    t2 = min(max(t2, t1), 1.0)
    return Thresholds(t1, t2)


def gate_output_correlation(gate_norms: np.ndarray,
                            output_norms: np.ndarray) -> float:
    """Pearson correlation between ||G(x)|| and ||G(x) E(x)|| (Fig. 5a's
    0.99 claim).  Both inputs are flat sample vectors."""
    a = np.asarray(gate_norms, np.float64).ravel()
    b = np.asarray(output_norms, np.float64).ravel()
    a = (a - a.mean()) / (a.std() + 1e-12)
    b = (b - b.mean()) / (b.std() + 1e-12)
    return float(np.mean(a * b))
