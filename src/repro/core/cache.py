"""Multidimensional Cache Manager (HOBBIT §3.4): two-pool (high/low precision)
slot-based expert cache with Eq. 3 eviction, prediction pinning, and
per-sequence record resets.

The manager tracks *metadata only* (slot table, usage records); the engine
owns the device buffers and writes weights into the slot the manager assigns.

In-flight reservation state machine (one (key, precision) entry)::

            admit()                  begin_inflight(key, slot)
    absent ────────▶ resident ─────────────────────▶ resident+IN-FLIGHT
       ▲                │  ▲                               │
       │   _select_victim  └── end_inflight(key) ◀─────────┘
       └── (eviction)      (bytes landed; entry is an ordinary resident)

  * RESIDENT — owns a slot; evictable by Eq. 3 priority unless pinned.
  * RESIDENT+IN-FLIGHT — owns a slot but its weight bytes are still being
    staged by the async scheduler: `_select_victim` NEVER picks it (a
    staged write must not land on a reassigned slot) and compute must
    `wait()` before reading the slot.
  * Soft pins (predicted experts) yield under slot pressure; hard pins
    (the experts of the layer currently executing) never do.  If every
    resident is in flight, admission raises `CacheStarvation` and the
    caller drains the scheduler (clearing reservations) and retries.
  * Reservations are keyed by (key, precision): the hi and lo copies of one
    expert reserve independently, so the StagingEngine can cancel a queued
    hi reservation (`cancel_inflight`, returning its slot to the free list)
    and admit a lo replacement — or later upgrade a landed lo copy in place
    by admitting the hi copy alongside it.

Lifecycle hooks: `new_sequence()` resets records and pins at batch
boundaries; `advance_token()` clears pins each decode step.  See
docs/ARCHITECTURE.md for where this sits in the decode loop and
core/loader.py for the scheduler half of the handshake.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Set, Tuple

from repro.core.policies import ExpertKey, PolicyRecords, PolicyWeights, MULTIDIM


class CacheStarvation(RuntimeError):
    """Raised when admission finds no evictable slot: every resident entry is
    either hard-pinned by the executing layer or has an async load in flight.
    Callers resolve it by draining in-flight loads (which clears reservations)
    and retrying."""


@dataclasses.dataclass
class CacheStats:
    hits_hi: int = 0
    hits_lo: int = 0
    misses_hi: int = 0
    misses_lo: int = 0
    evictions: int = 0
    # hits on experts this sequence had never touched but the fleet heat map
    # already knew were hot — the cross-request prior paying off
    fleet_heat_hits: int = 0

    @property
    def hits(self):
        return self.hits_hi + self.hits_lo

    @property
    def misses(self):
        return self.misses_hi + self.misses_lo

    def hit_ratio(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def miss_penalty(self, lo_cost_ratio: float = 0.25) -> float:
        """Paper's mixed-precision penalty: hi miss costs 1, lo miss B_l/B_h."""
        return self.misses_hi + lo_cost_ratio * self.misses_lo

    def to_dict(self) -> Dict[str, float]:
        """JSON-serializable view (engine.stats() contract)."""
        return {
            "hits_hi": self.hits_hi, "hits_lo": self.hits_lo,
            "misses_hi": self.misses_hi, "misses_lo": self.misses_lo,
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "hit_ratio": self.hit_ratio(),
            "fleet_heat_hits": self.fleet_heat_hits,
        }


class PrecisionPool:
    """One fixed-capacity slot pool (hi or lo precision)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.slot_of: Dict[ExpertKey, int] = {}
        self.key_of: Dict[int, ExpertKey] = {}
        self.free = list(range(capacity))

    def lookup(self, key: ExpertKey) -> Optional[int]:
        return self.slot_of.get(key)

    def contains(self, key: ExpertKey) -> bool:
        return key in self.slot_of

    def insert(self, key: ExpertKey, slot: int):
        self.slot_of[key] = slot
        self.key_of[slot] = key

    def remove(self, key: ExpertKey) -> int:
        slot = self.slot_of.pop(key)
        del self.key_of[slot]
        return slot


class MultidimensionalCache:
    """Two pools + shared policy records + prediction pin set."""

    def __init__(self, num_layers: int, hi_slots: int, lo_slots: int,
                 weights: PolicyWeights = MULTIDIM, *, fleet=None,
                 fleet_weight: float = 0.25):
        """fleet: optional ``core.fleet_heat.FleetHeat`` — a cross-request
        expert heat prior blended into every Eq. 3 priority with weight
        `fleet_weight` (see ``priority``).  None reproduces the pure
        per-sequence policy bit-for-bit."""
        self.records = PolicyRecords(num_layers)
        self.hi = PrecisionPool(hi_slots)
        self.lo = PrecisionPool(lo_slots)
        self.weights = weights
        self.fleet = fleet
        self.fleet_weight = float(fleet_weight)
        self.pinned: Set[Tuple[ExpertKey, bool]] = set()  # (key, is_hi)
        self.hard_pinned: Set[Tuple[ExpertKey, bool]] = set()
        # async-load reservations: (key, is_hi) -> slot.  The entry already
        # owns its slot in the pool table, but the weight bytes are still in
        # flight; it must never be evicted (the staged write would land on a
        # reassigned slot) and compute must wait() before reading the slot.
        self.inflight: Dict[Tuple[ExpertKey, bool], int] = {}
        self.stats = CacheStats()          # owner: main-thread

    # ------------- sequence / token lifecycle -------------
    # owner: main-thread
    def new_sequence(self):
        self.records.reset()
        self.pinned.clear()
        self.hard_pinned.clear()

    # owner: main-thread
    def advance_token(self):
        self.records.advance_token()
        self.pinned.clear()
        self.hard_pinned.clear()

    # ------------- pinning (predicted experts; §3.3 "mask") -------------
    # owner: main-thread
    def pin(self, key: ExpertKey, high_precision: bool, hard: bool = False):
        """Soft pins (predicted experts) yield under slot pressure; hard pins
        (the experts of the layer currently executing) never do."""
        self.pinned.add((key, high_precision))
        if hard:
            self.hard_pinned.add((key, high_precision))

    # ------------- async-load reservations -------------
    # owner: main-thread
    def begin_inflight(self, key: ExpertKey, high_precision: bool, slot: int):
        self.inflight[(key, high_precision)] = slot

    # owner: main-thread
    def end_inflight(self, key: ExpertKey, high_precision: bool):
        self.inflight.pop((key, high_precision), None)

    # owner: main-thread
    def cancel_inflight(self, key: ExpertKey,
                        high_precision: bool) -> Optional[int]:
        """Abort an in-flight reservation whose copy has NOT been issued yet
        (StagingEngine precision downgrade): drop the reservation, remove the
        entry from its pool and return the freed slot (or None when no such
        reservation exists).  Reservations are keyed by (key, precision), so
        cancelling the hi entry leaves a resident or in-flight lo copy of the
        same expert untouched — a lo landing can later be upgraded in place
        by simply admitting the hi copy alongside it."""
        slot = self.inflight.pop((key, high_precision), None)
        if slot is None:
            return None
        pool = self.hi if high_precision else self.lo
        if pool.lookup(key) == slot:
            pool.remove(key)
            pool.free.append(slot)
        # a cancelled entry no longer exists — stale pins for it must not
        # keep constraining _select_victim until the next advance_token
        self.pinned.discard((key, high_precision))
        self.hard_pinned.discard((key, high_precision))
        return slot

    def is_inflight(self, key: ExpertKey, high_precision: bool) -> bool:
        return (key, high_precision) in self.inflight

    def can_admit(self, high_precision: bool) -> bool:
        """True iff admit() can find a slot without touching an in-flight
        reservation or a hard-pinned resident — used by the async scheduler
        to drop (rather than deadlock on) prefetches under slot pressure."""
        pool = self.hi if high_precision else self.lo
        if pool.free:
            return True
        return any((k, high_precision) not in self.inflight
                   and (k, high_precision) not in self.hard_pinned
                   for k in pool.slot_of)

    def peek_victim_priority(self, high_precision: bool,
                             current_layer: int) -> Optional[float]:
        """Eq. 3 priority of the resident the next admit() on a FULL pool
        would evict, or None when admission is free (free slots) or nothing
        is evictable.  Uses `_select_victim` itself (pure selection, no side
        effects), so callers vetoing an admission that would evict something
        hotter than what they admit — the StagingEngine upgrade pass — are
        always comparing against the real eviction policy."""
        pool = self.hi if high_precision else self.lo
        if pool.free:
            return None
        try:
            victim = self._select_victim(pool, high_precision, current_layer)
        except CacheStarvation:
            return None
        return self.priority(victim, current_layer)

    # ------------- priority (Eq. 3 + fleet prior) -------------
    def priority(self, key: ExpertKey, current_layer: int) -> float:
        """THE cache priority: the per-sequence Eq. 3 score, blended with
        the fleet-wide heat prior when one is attached::

            p = (1 - w) * eq3(key) + w * fleet.score(key)

        Every consumer — ``_select_victim``, ``peek_victim_priority`` and
        the upgrade passes in core/loader.py and core/simulator.py — ranks
        experts through this method, so a fleet-hot expert is harder to
        evict and upgraded sooner even before the current sequence touches
        it.  Without a fleet (fleet=None) this is exactly
        ``records.priority``."""
        p = self.records.priority(key, self.weights, current_layer)
        if self.fleet is None:
            return p
        w = self.fleet_weight
        return (1.0 - w) * p + w * self.fleet.score(key)

    # ------------- queries -------------
    def lookup(self, key: ExpertKey, high_precision: bool) -> Optional[int]:
        pool = self.hi if high_precision else self.lo
        return pool.lookup(key)

    # owner: main-thread
    def probe(self, key: ExpertKey, high_precision: bool, *,
              count_stats: bool = True) -> Optional[int]:
        """lookup + stats + usage record update on hit."""
        slot = self.lookup(key, high_precision)
        if count_stats:
            if slot is not None:
                if high_precision:
                    self.stats.hits_hi += 1
                else:
                    self.stats.hits_lo += 1
            else:
                if high_precision:
                    self.stats.misses_hi += 1
                else:
                    self.stats.misses_lo += 1
        if slot is not None:
            if (count_stats and self.fleet is not None
                    and self.records.freq.get(key, 0) == 0
                    and self.fleet.is_warm(key)):
                # first touch this sequence, but the fleet kept it resident
                self.stats.fleet_heat_hits += 1
            self.records.on_use(key, high_precision)
        return slot

    # ------------- admission / eviction -------------
    # owner: main-thread
    def admit(self, key: ExpertKey, high_precision: bool,
              current_layer: int) -> Tuple[int, Optional[ExpertKey]]:
        """Assign a slot for `key` (evicting the lowest-priority unpinned
        resident if full).  Returns (slot, evicted_key_or_None).  The caller
        must then write the weights into the returned slot."""
        pool = self.hi if high_precision else self.lo
        existing = pool.lookup(key)
        if existing is not None:
            self.records.on_use(key, high_precision)
            return existing, None
        evicted = None
        if pool.free:
            slot = pool.free.pop()
        else:
            victim = self._select_victim(pool, high_precision, current_layer)
            slot = pool.remove(victim)
            evicted = victim
            self.stats.evictions += 1
        pool.insert(key, slot)
        self.records.on_use(key, high_precision)
        return slot, evicted

    def _select_victim(self, pool: PrecisionPool, is_hi: bool,
                       current_layer: int) -> ExpertKey:
        best_key, best_p = None, float("inf")
        for key in pool.slot_of:
            if (key, is_hi) in self.pinned or (key, is_hi) in self.inflight:
                continue
            p = self.priority(key, current_layer)
            if p < best_p:
                best_key, best_p = key, p
        if best_key is None:
            # everything soft-pinned: sacrifice a predicted expert, but never
            # one the currently-executing layer needs (hard pin) or one whose
            # weight bytes are still landing (in flight)
            cands = [k for k in pool.slot_of
                     if (k, is_hi) not in self.hard_pinned
                     and (k, is_hi) not in self.inflight]
            if not cands:
                # pathological: cache < top_k.  Hard-pinned entries of the
                # executing layer may be sacrificed (they already computed or
                # will be reloaded on demand) but in-flight ones never can.
                cands = [k for k in pool.slot_of
                         if (k, is_hi) not in self.inflight]
            if not cands:
                raise CacheStarvation(
                    f"{'hi' if is_hi else 'lo'} pool: every resident expert "
                    "has an async load in flight; drain the scheduler first")
            best_key = min(cands, key=lambda k: self.priority(
                k, current_layer))
        return best_key

    # ------------- views -------------
    def resident(self, high_precision: bool) -> Set[ExpertKey]:
        return set((self.hi if high_precision else self.lo).slot_of)
