"""Mesh-aware internal sharding constraints.

`constrain(x, ...dims)` applies jax.lax.with_sharding_constraint when traced
under a mesh (the `with mesh:` context) that defines the named axes, and is
a no-op otherwise (so model code runs unchanged in single-device tests).
Dim tokens:

    "batch"  -> all data-parallel axes present (("pod","data") or ("data",))
    "model"  -> the tensor-parallel axis
    None     -> replicated
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _active_mesh():
    """The mesh in scope during tracing, or None."""
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return am
    except Exception:
        pass
    return None


def axis_size(name: str) -> int:
    """Size of a mesh axis in the active mesh, or 1 if absent."""
    mesh = _active_mesh()
    if mesh is None or name not in (mesh.axis_names or ()):
        return 1
    return mesh.shape[name]


def dp_size() -> int:
    """Combined size of the data-parallel axes (pod x data)."""
    return axis_size("pod") * axis_size("data")


def constrain(x, *dims):
    mesh = _active_mesh()
    if mesh is None:
        return x
    names = tuple(mesh.axis_names or ())
    if not names:
        return x
    spec = []
    for d in dims:
        if d == "batch":
            ax = tuple(a for a in ("pod", "data") if a in names)
            spec.append(ax if len(ax) > 1 else (ax[0] if ax else None))
        elif d == "all":  # every mesh axis (context-parallel long sequences)
            ax = tuple(a for a in ("pod", "data", "model") if a in names)
            spec.append(ax if len(ax) > 1 else (ax[0] if ax else None))
        elif d is not None and d in names:
            spec.append(d)
        else:
            spec.append(None)
    # drop axes that don't divide the dim (mirror sharding.fit_spec)
    fixed = []
    for dim_size, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        fixed.append(ax if dim_size % size == 0 else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*fixed))
    except Exception:
        return x
