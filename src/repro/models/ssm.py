"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Train/prefill use the chunked matmul form: quadratic attention-like term
inside each chunk plus a sequential inter-chunk state recurrence (lax.scan),
so cost is O(S * L) with chunk length L and the MXU does all the work.
Decode is the O(1) recurrent update on the carried state.

Shapes: d_inner = expand * d_model, H = d_inner / head_dim heads,
state N = d_state, head dim P = head_dim, n_groups G (B/C shared per group).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.models import layers


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nheads, conv_dim


def ssm_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nheads, conv_dim = ssm_dims(cfg)
    ks = layers.split_keys(key, 4)
    in_cols = 2 * d_in + 2 * s.n_groups * s.d_state + nheads
    return {
        "in_proj": layers.dense_init(ks[0], (d, in_cols), layers._dt(cfg)),
        "conv_w": layers.dense_init(ks[1], (s.d_conv, conv_dim), layers._dt(cfg), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": layers.dense_init(ks[3], (d_in, d), layers._dt(cfg)),
    }


def _split_proj(proj, cfg: ModelConfig):
    s = cfg.ssm
    d_in, nheads, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt  # (..., d_in), (..., d_in+2gn), (..., nheads)


def _causal_conv(xbc, conv_w, conv_b, cfg: ModelConfig):
    """Depthwise causal conv over the sequence dim. xbc: (B,S,C)."""
    w = conv_w.astype(jnp.float32)                      # (K, C)
    k = w.shape[0]
    xf = xbc.astype(jnp.float32)
    pad = jnp.pad(xf, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xf.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + conv_b).astype(xbc.dtype)


def _gated_norm(y, z, scale, eps):
    """RMSNorm(y * silu(z)) — mamba2's gated output norm. (..., d_in)."""
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(ms + eps) * (1.0 + scale)).astype(y.dtype)


def ssd_chunked(x, dt, a, b, c, d_skip, chunk: int):
    """SSD scan in matmul form.
    x: (B,S,H,P)  dt: (B,S,H)  a: (H,) negative  b,c: (B,S,G,N)  d_skip: (H,)
    Returns y: (B,S,H,P) and final state (B,H,P,N)."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xf = x.astype(jnp.float32).reshape(bs, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bs, nc, chunk, h)
    bf = b.astype(jnp.float32).reshape(bs, nc, chunk, g, n)
    cf = c.astype(jnp.float32).reshape(bs, nc, chunk, g, n)
    bf = jnp.repeat(bf, rep, axis=3)                    # (B,nc,L,H,N)
    cf = jnp.repeat(cf, rep, axis=3)

    da = dtf * a[None, None, None, :]                   # (B,nc,L,H) <= 0
    da_cs = jnp.cumsum(da, axis=2)                      # within-chunk cumsum
    da_total = da_cs[:, :, -1, :]                       # (B,nc,H)

    # intra-chunk (the "attention-like" term):
    # Lmat[i,j] = exp(da_cs[i]-da_cs[j]) for i>=j else 0
    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # (B,nc,L,L,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    lmat = jnp.where(causal, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bzihn,bzjhn->bzijh", cf, bf)              # (B,nc,L,L,H)
    y_diag = jnp.einsum("bzijh,bzjh,bzjhp->bzihp", cb * lmat, dtf, xf)

    # per-chunk input->state contribution
    decay_to_end = jnp.exp(da_total[:, :, None, :] - da_cs)     # (B,nc,L,H)
    s_chunk = jnp.einsum("bzlh,bzlh,bzlhn,bzlhp->bzhpn",
                         decay_to_end, dtf, bf, xf)             # (B,nc,H,P,N)

    # inter-chunk recurrence
    def step(h_prev, xs):
        s_c, da_tot = xs                                        # (B,H,P,N),(B,H)
        h_new = h_prev * jnp.exp(da_tot)[:, :, None, None] + s_c
        return h_new, h_prev

    h0 = jnp.zeros((bs, h, p, n), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        step, h0, (s_chunk.transpose(1, 0, 2, 3, 4), da_total.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N)

    # inter-chunk output: y_off[i] = C_i . (exp(da_cs[i]) * h_prev)
    y_off = jnp.einsum("bzlhn,bzlh,bzhpn->bzlhp",
                       cf, jnp.exp(da_cs), h_prevs)
    y = (y_diag + y_off).reshape(bs, s, h, p)
    y = y + xf.reshape(bs, s, h, p) * d_skip[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssm_forward(p, x, cfg: ModelConfig):
    """Full-sequence mamba2 block. x: (B,S,D).
    Returns (out, state) where state = dict(h, conv) continues into decode."""
    s = cfg.ssm
    d_in, nheads, conv_dim = ssm_dims(cfg)
    proj = x @ p["in_proj"]
    z, xbc, dt = _split_proj(proj, cfg)
    # conv tail (pre-activation inputs) for decode handoff
    kw = p["conv_w"].shape[0]
    pad_raw = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (kw - 1, 0), (0, 0)))
    conv_tail = pad_raw[:, -(kw - 1):, :] if kw > 1 else pad_raw[:, :0, :]
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], cfg)
    gn = s.n_groups * s.d_state
    xs, b, c = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    bsz, seq, _ = x.shape
    xs = xs.reshape(bsz, seq, nheads, s.head_dim)
    b = b.reshape(bsz, seq, s.n_groups, s.d_state)
    c = c.reshape(bsz, seq, s.n_groups, s.d_state)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    chunk = min(s.chunk_size, seq)
    if seq % chunk:  # pad sequence to a chunk multiple (masked by dt=0)
        pad = chunk - seq % chunk
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, h_final = ssd_chunked(xs, dtf, a, b, c, p["D"], chunk)
        y = y[:, :seq]
    else:
        y, h_final = ssd_chunked(xs, dtf, a, b, c, p["D"], chunk)
    y = _gated_norm(y.reshape(bsz, seq, d_in), z, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"h": h_final, "conv": conv_tail}


def ssm_decode(p, x, state, cfg: ModelConfig):
    """Single-token recurrent update.
    x: (B,1,D); state: dict(h=(B,H,P,N) fp32, conv=(B,K-1,convdim)).
    Returns (out (B,1,D), new state)."""
    s = cfg.ssm
    d_in, nheads, conv_dim = ssm_dims(cfg)
    bsz = x.shape[0]
    proj = x[:, 0, :] @ p["in_proj"]                     # (B, cols)
    z, xbc, dt = _split_proj(proj, cfg)
    # rolling causal conv
    window = jnp.concatenate([state["conv"], xbc[:, None, :].astype(jnp.float32)], axis=1)
    wf = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window, wf) + p["conv_b"]
    xbc_act = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:, :]

    gn = s.n_groups * s.d_state
    xs, b, c = jnp.split(xbc_act, [d_in, d_in + gn], axis=-1)
    xs = xs.reshape(bsz, nheads, s.head_dim)
    b = jnp.repeat(b.reshape(bsz, s.n_groups, s.d_state), nheads // s.n_groups, axis=1)
    c = jnp.repeat(c.reshape(bsz, s.n_groups, s.d_state), nheads // s.n_groups, axis=1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dtf * a)                                 # (B,H)
    h = state["h"] * da[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dtf, b.astype(jnp.float32), xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", c.astype(jnp.float32), h)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(bsz, d_in).astype(x.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None, :], {"h": h, "conv": new_conv}


def init_ssm_state(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in, nheads, conv_dim = ssm_dims(cfg)
    return {
        "h": jnp.zeros((batch, nheads, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), jnp.float32),
    }
