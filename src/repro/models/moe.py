"""Sparse MoE layer: top-k router, capacity-based scatter dispatch,
per-expert batched GEMMs, scatter-add combine, aux losses, shared experts.

Dispatch strategy (SPMD-friendly, static shapes):
  1. router logits (T, E) in fp32; softmax -> probs; top-k per token.
  2. capacity C = ceil(T * k * capacity_factor / E); slot = expert * C + pos
     where pos is the token's arrival index within its expert (one-hot cumsum).
     Tokens beyond capacity are *dropped* (their combine weight contributes 0),
     matching capacity-factor MoE training practice.
  3. gather tokens into an (E, C, D) buffer (+1 trash row for drops), run all
     experts as one batched einsum, scatter-add back weighted by gate probs.

Expert weights may be bf16 dense or groupwise-quantized (QTensor) — the
mixed-precision resident-expert option used by the §Perf hillclimb and by the
HOBBIT offload engine's device-side compute.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers, shard_utils
from repro.quant.quantize import QTensor, dequantize


class RouterOutput(NamedTuple):
    probs: jax.Array        # (T, E) fp32 full softmax
    top_w: jax.Array        # (T, k) normalized combine weights
    top_idx: jax.Array      # (T, k) int32 expert ids
    aux_loss: jax.Array     # scalar: load-balance + z loss


def moe_init(key, cfg: ModelConfig):
    mc = cfg.moe
    d, f, e = cfg.d_model, mc.d_ff_expert, mc.num_experts
    ks = layers.split_keys(key, 4)
    wi_cols = 2 * f if cfg.ffn_activation == "swiglu" else f
    p = {
        "router": layers.dense_init(ks[0], (d, e), jnp.float32),
        "experts": {
            "wi": layers.dense_init(ks[1], (e, d, wi_cols), layers._dt(cfg)),
            "wo": layers.dense_init(ks[2], (e, f, d), layers._dt(cfg)),
        },
    }
    if mc.num_shared_experts:
        fs = (mc.d_ff_shared or f) * mc.num_shared_experts
        p["shared"] = layers.ffn_init(ks[3], cfg, d_ff=fs)
    return p


def route(router_w, x_flat, mc: MoEConfig) -> RouterOutput:
    """x_flat: (T, D) -> routing decision + aux losses."""
    logits = x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, mc.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # load-balance loss (Switch): E * sum_e f_e * p_e
    e = probs.shape[-1]
    hard = jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32)
    f_e = jnp.mean(hard, axis=0)
    p_e = jnp.mean(probs, axis=0)
    lb = e * jnp.sum(f_e * p_e) * mc.router_aux_weight
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))) * mc.router_z_weight
    return RouterOutput(probs, top_w, top_idx.astype(jnp.int32), lb + z)


def _capacity(t: int, mc: MoEConfig) -> int:
    c = int(np.ceil(t * mc.top_k * mc.capacity_factor / mc.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling friendliness


def dispatch_indices(top_idx, mc: MoEConfig, capacity: int, token_mask=None):
    """(T,k) expert ids -> (T,k) buffer slots in [0, E*C] (E*C = dropped).

    token_mask: optional (T,) live-token mask.  Dead tokens (e.g. inactive
    batch slots riding through a decode step) occupy no expert capacity and
    combine with weight 0, so they can never crowd live tokens out."""
    t, k = top_idx.shape
    e = mc.num_experts
    flat = top_idx.reshape(t * k)
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)          # (T*k, E)
    if token_mask is not None:
        live = jnp.repeat(token_mask.astype(jnp.int32), k)
        onehot = onehot * live[:, None]
    pos = jnp.cumsum(onehot, axis=0) - 1                        # arrival index
    pos = jnp.sum(pos * onehot, axis=-1)                        # (T*k,)
    keep = pos < capacity
    if token_mask is not None:
        keep = keep & (live > 0)
    slot = jnp.where(keep, flat * capacity + pos, e * capacity)
    return slot.reshape(t, k), keep.reshape(t, k)


def expert_ffn(experts, xb, cfg: ModelConfig, tok_ax=None, groups: int = 1):
    """xb: (E, C, D) or (G, E, C, D) -> same shape through each expert's FFN.

    Sharding: experts over `model` when E divides it; otherwise the hidden
    d_ff dim takes the model axis (megatron-style within each expert).
    With G > 1 groups, the group dim carries the data axis."""
    wi, wo = experts["wi"], experts["wo"]
    if isinstance(wi, QTensor):
        wi = dequantize(wi, dtype=xb.dtype)
    if isinstance(wo, QTensor):
        wo = dequantize(wo, dtype=xb.dtype)
    grouped = xb.ndim == 4
    e = xb.shape[1] if grouped else xb.shape[0]
    e_ok = e % max(shard_utils.axis_size("model"), 1) == 0
    e_ax = "model" if e_ok else None
    f_ax = None if e_ok else "model"

    def act(h):
        if cfg.ffn_activation == "swiglu":
            a, u = jnp.split(h, 2, axis=-1)
            return jax.nn.silu(a.astype(jnp.float32)).astype(xb.dtype) * u
        if cfg.ffn_activation == "sq_relu":
            return jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(xb.dtype)
        return jax.nn.gelu(h.astype(jnp.float32)).astype(xb.dtype)

    if grouped:
        h = jnp.einsum("gecd,edf->gecf", xb, wi)
        h = shard_utils.constrain(h, "batch" if xb.shape[0] > 1 else None,
                                  e_ax, None, f_ax)
        return jnp.einsum("gecf,efd->gecd", act(h), wo)
    h = jnp.einsum("ecd,edf->ecf", xb, wi)
    # decode path (tiny token counts, e_ok): with_sharding_constraint(None)
    # FORCES replication, which would make XLA all-gather the column-sharded
    # decode-mode expert weights — let propagation follow the weights instead.
    if tok_ax is not None or not e_ok:
        h = shard_utils.constrain(h, e_ax, tok_ax, f_ax)
    return jnp.einsum("ecf,efd->ecd", act(h), wo)


def moe_forward(p, x, cfg: ModelConfig, router_out: Optional[RouterOutput] = None,
                groups: Optional[int] = None, token_mask=None):
    """x: (B, S, D).  Returns (y, aux_loss, router_out).

    GShard-style *grouped* dispatch: tokens are split into G groups (G = the
    data-parallel axis size, so each group lives on one data shard) and the
    capacity gather/scatter happens per group.  A single global dispatch
    would gather every token to every chip (XLA lowers a cross-shard take to
    an all-gather of the operand — ~17 GB/chip at 1M tokens)."""
    mc = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    r = router_out if router_out is not None else route(p["router"], xf, mc)

    e = mc.num_experts
    g = groups if groups is not None else shard_utils.dp_size()
    # grouped dispatch pays off for big token counts (train/prefill); decode
    # steps keep a single group so the weight-stationary decode sharding
    # (megatron col/row experts) is not disturbed
    if groups is None and (t % g or t // g < 512):
        g = 1
    if t % g:
        g = 1
    tl = t // g
    cap = _capacity(tl, mc)
    tok_ax = "batch" if cap >= 512 or g > 1 else None
    e_ax = "model" if e % max(shard_utils.axis_size("model"), 1) == 0 else None

    top_idx_g = r.top_idx.reshape(g, tl, mc.top_k)
    if token_mask is not None:
        mask_g = jnp.asarray(token_mask).reshape(g, tl)
        slot, keep = jax.vmap(
            lambda ti, mk: dispatch_indices(ti, mc, cap, mk))(top_idx_g, mask_g)
    else:
        slot, keep = jax.vmap(
            lambda ti: dispatch_indices(ti, mc, cap))(top_idx_g)  # (G, tl, k)

    # inverse slot map per group: slot -> local token row (tl = pad row);
    # scattering 1-D indices then row-gathering avoids the giant 2-D scatter
    # index tensors XLA would otherwise materialize.
    tok_idx = jnp.broadcast_to(jnp.arange(tl, dtype=jnp.int32)[None, :, None],
                               slot.shape).reshape(g, -1)
    gather_rows = jnp.full((g, e * cap + 1), tl, jnp.int32)
    gather_rows = jax.vmap(lambda gr, sl, ti: gr.at[sl].set(ti, mode="drop"))(
        gather_rows, slot.reshape(g, -1), tok_idx)
    xg = shard_utils.constrain(xf.reshape(g, tl, d), "batch" if g > 1 else None,
                               None if g > 1 else tok_ax, None)
    xg_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xf.dtype)], axis=1)
    xb = jax.vmap(lambda xp, gr: jnp.take(xp, gr[: e * cap], axis=0))(
        xg_pad, gather_rows)                                     # (G, E*cap, D)
    xb = xb.reshape(g, e, cap, d)
    if g > 1:
        xb = shard_utils.constrain(xb, "batch", e_ax, None, None)
        yb = expert_ffn(p["experts"], xb, cfg, groups=g)
    else:
        xb0 = shard_utils.constrain(xb[0], e_ax, tok_ax, None)
        yb = expert_ffn(p["experts"], xb0, cfg, tok_ax=tok_ax)[None]
    yb = yb.reshape(g, e * cap, d)

    yb_pad = jnp.concatenate([yb, jnp.zeros((g, 1, d), yb.dtype)], axis=1)
    y_choice = jax.vmap(lambda yp, sl: jnp.take(yp, sl, axis=0))(
        yb_pad, slot.reshape(g, -1))                             # (G, tl*k, D)
    y_choice = y_choice.reshape(t, mc.top_k, d)
    w = (r.top_w * keep.reshape(t, mc.top_k).astype(r.top_w.dtype)).astype(x.dtype)
    y = jnp.einsum("tk,tkd->td", w, y_choice)
    y = shard_utils.constrain(y, "batch", None)

    if mc.num_shared_experts and "shared" in p:
        y = y + layers.ffn_forward(p["shared"], xf, cfg)
    return y.reshape(b, s, d), r.aux_loss, r


def moe_forward_dense_eval(p, x, cfg: ModelConfig):
    """Oracle: compute every expert densely and combine by full top-k weights.
    O(E) FLOPs — used only in tests to validate the dispatch path."""
    mc = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    r = route(p["router"], xf, mc)
    wi, wo = p["experts"]["wi"], p["experts"]["wo"]
    if isinstance(wi, QTensor):
        wi = dequantize(wi, dtype=x.dtype)
    if isinstance(wo, QTensor):
        wo = dequantize(wo, dtype=x.dtype)
    h = jnp.einsum("td,edf->etf", xf, wi)
    if cfg.ffn_activation == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    ye = jnp.einsum("etf,efd->etd", h, wo)                      # (E,T,D)
    mask = jnp.zeros((b * s, mc.num_experts), r.top_w.dtype)
    mask = mask.at[jnp.arange(b * s)[:, None], r.top_idx].set(r.top_w)
    y = jnp.einsum("te,etd->td", mask, ye.astype(r.top_w.dtype)).astype(x.dtype)
    if mc.num_shared_experts and "shared" in p:
        y = y + layers.ffn_forward(p["shared"], xf, cfg)
    return y.reshape(b, s, d), r
