"""Model assembly: config -> init / forward / loss / prefill / decode_step.

The layer stack is organized as (unrolled prefix) + (lax.scan over stacked
repeating blocks) + (unrolled tail); see configs.base.  All functions are pure
and jit/pjit-friendly; the HOBBIT offload engine uses `unstack_layers` to get
a flat per-layer view for its host-driven decode loop.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import kv_pages as kvp
from repro.models import layers, moe as moe_lib, shard_utils, ssm as ssm_lib


class Batch(NamedTuple):
    tokens: jax.Array                     # (B, S) int32
    loss_mask: jax.Array                  # (B, S) f32 (1 = predict this target)
    prefix_embeds: Optional[jax.Array] = None   # (B, P, D) vlm patch embeds
    audio_frames: Optional[jax.Array] = None    # (B, F, D_enc) whisper frames


# --------------------------------------------------------------------------
# per-layer init / apply
# --------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str, is_moe: bool, cross: bool):
    ks = layers.split_keys(key, 4)
    p: Dict[str, Any] = {"pre_norm": layers.norm_init(cfg)}
    if kind.startswith("attn"):
        if cfg.mla is not None:
            p["attn"] = layers.mla_init(ks[0], cfg)
        else:
            p["attn"] = layers.attn_init(ks[0], cfg)
    elif kind == "ssm":
        p["mixer"] = ssm_lib.ssm_init(ks[0], cfg)
    if cross:
        p["cross_norm"] = layers.norm_init(cfg)
        p["cross"] = layers.attn_init(ks[3], cfg, cross=True)
    # mixer-only layers (mamba2 arch has no FFN)
    if cfg.d_ff > 0 or is_moe:
        p["ffn_norm"] = layers.norm_init(cfg)
        p["ffn"] = moe_lib.moe_init(ks[1], cfg) if is_moe else layers.ffn_init(ks[1], cfg)
    if cfg.sandwich_norm:
        p["post_norm"] = layers.norm_init(cfg)
        if "ffn" in p:
            p["post_ffn_norm"] = layers.norm_init(cfg)
    return p


def _use_rope(cfg: ModelConfig, kind: str) -> bool:
    if cfg.family == "hybrid":
        return False      # jamba attention layers use no positional encoding
    return cfg.rope_theta > 0


def _layer_forward(p, x, positions, cfg: ModelConfig, kind: str, is_moe: bool,
                   enc_kv=None):
    """Full-sequence layer. Returns (x, aux_loss, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(p["pre_norm"], x, cfg)
    if kind.startswith("attn"):
        if cfg.mla is not None:
            out, kv = layers.mla_forward(p["attn"], h, positions, cfg)
            cache = {"c_kv": kv[0], "k_rope": kv[1]}
        else:
            out, kv = layers.attn_forward(p["attn"], h, positions, cfg, kind,
                                          use_rope=_use_rope(cfg, kind))
            cache = {"k": kv[0], "v": kv[1]}
    else:
        out, state = ssm_lib.ssm_forward(p["mixer"], h, cfg)
        cache = state
    if cfg.sandwich_norm:
        out = layers.apply_norm(p["post_norm"], out, cfg)
    x = x + out

    if enc_kv is not None and "cross" in p:
        h = layers.apply_norm(p["cross_norm"], x, cfg)
        x = x + layers.cross_attn_forward(p["cross"], h, enc_kv, cfg)

    if "ffn" in p:
        h = layers.apply_norm(p["ffn_norm"], x, cfg)
        if is_moe:
            y, aux, _ = moe_lib.moe_forward(p["ffn"], h, cfg)
        else:
            y = layers.ffn_forward(p["ffn"], h, cfg)
        if cfg.sandwich_norm:
            y = layers.apply_norm(p["post_ffn_norm"], y, cfg)
        x = x + y
    return x, aux, cache


def _layer_decode(p, x, cache, positions, cfg: ModelConfig, kind: str,
                  is_moe: bool, enc_kv=None, token_mask=None):
    """One-token layer step. Returns (x, new_cache).  token_mask: optional
    (B,) live-slot mask — dead slots take no MoE dispatch capacity."""
    h = layers.apply_norm(p["pre_norm"], x, cfg)
    if kind.startswith("attn"):
        if cfg.mla is not None:
            out, new_cache = layers.mla_decode(p["attn"], h, cache, positions, cfg)
        else:
            out, new_cache = layers.attn_decode(p["attn"], h, cache, positions, cfg,
                                                kind, use_rope=_use_rope(cfg, kind))
    else:
        out, new_cache = ssm_lib.ssm_decode(p["mixer"], h, cache, cfg)
    if cfg.sandwich_norm:
        out = layers.apply_norm(p["post_norm"], out, cfg)
    x = x + out

    if enc_kv is not None and "cross" in p:
        h = layers.apply_norm(p["cross_norm"], x, cfg)
        x = x + layers.cross_attn_forward(p["cross"], h, enc_kv, cfg)

    if "ffn" in p:
        h = layers.apply_norm(p["ffn_norm"], x, cfg)
        if is_moe:
            y, _, _ = moe_lib.moe_forward(p["ffn"], h, cfg,
                                          token_mask=token_mask)
        else:
            y = layers.ffn_forward(p["ffn"], h, cfg)
        if cfg.sandwich_norm:
            y = layers.apply_norm(p["post_ffn_norm"], y, cfg)
        x = x + y
    return x, new_cache


def supports_paged_kv(cfg: ModelConfig) -> bool:
    """True iff the paged KV layout serves this architecture: every layer a
    full-window "attn" layer (no ring caches), no MLA latent cache, no
    cross-attention encoder — the HOBBIT engine's model class (Mixtral /
    Phi-MoE shapes).  Other families keep the dense per-batch cache."""
    return (cfg.mla is None and cfg.encoder is None
            and all(k == "attn" for k in cfg.layer_kinds()))


def _layer_decode_paged(p, x, kp, vp, table, positions, active, cfg, is_moe):
    """One-token layer step against paged KV.  Mirrors `_layer_decode` for
    the paged model class (attn + optional MoE/FFN); `active` doubles as the
    MoE token mask so released slots take no dispatch capacity."""
    h = layers.apply_norm(p["pre_norm"], x, cfg)
    out, kp, vp = layers.paged_attn_decode(p["attn"], h, kp, vp, table,
                                           positions, active, cfg)
    if cfg.sandwich_norm:
        out = layers.apply_norm(p["post_norm"], out, cfg)
    x = x + out
    if "ffn" in p:
        h = layers.apply_norm(p["ffn_norm"], x, cfg)
        if is_moe:
            y, _, _ = moe_lib.moe_forward(p["ffn"], h, cfg, token_mask=active)
        else:
            y = layers.ffn_forward(p["ffn"], h, cfg)
        if cfg.sandwich_norm:
            y = layers.apply_norm(p["post_ffn_norm"], y, cfg)
        x = x + y
    return x, kp, vp


def _layer_chunk_paged(p, x, kp, vp, table, start, n, wstart, valid_flat,
                       cfg, is_moe):
    """One prefill-chunk layer step against paged KV (mirror of
    `_layer_forward` for the paged model class).  valid_flat: (B*C,) live-
    token mask — pad tokens of the final chunk occupy no MoE capacity.
    wstart: (B,) per-row write floor — positions below it attend but drop
    their K/V writes (prefix-sharing re-feed over aliased pages)."""
    h = layers.apply_norm(p["pre_norm"], x, cfg)
    out, kp, vp = layers.paged_attn_prefill_chunk(p["attn"], h, kp, vp,
                                                  table, start, n, cfg,
                                                  wstart=wstart)
    if cfg.sandwich_norm:
        out = layers.apply_norm(p["post_norm"], out, cfg)
    x = x + out
    if "ffn" in p:
        h = layers.apply_norm(p["ffn_norm"], x, cfg)
        if is_moe:
            y, _, _ = moe_lib.moe_forward(p["ffn"], h, cfg,
                                          token_mask=valid_flat)
        else:
            y = layers.ffn_forward(p["ffn"], h, cfg)
        if cfg.sandwich_norm:
            y = layers.apply_norm(p["post_ffn_norm"], y, cfg)
        x = x + y
    return x, kp, vp


# --------------------------------------------------------------------------
# whisper encoder
# --------------------------------------------------------------------------

def _encoder_init(key, cfg: ModelConfig):
    e = cfg.encoder
    ks = layers.split_keys(key, e.num_layers + 1)
    lyrs = []
    for i in range(e.num_layers):
        k1, k2 = jax.random.split(ks[i])
        lyrs.append({
            "norm1": {"scale": jnp.zeros((e.d_model,), jnp.float32),
                      "bias": jnp.zeros((e.d_model,), jnp.float32)},
            "attn": {
                "wq": layers.dense_init(k1, (e.d_model, e.d_model), layers._dt(cfg)),
                "wk": layers.dense_init(jax.random.fold_in(k1, 1), (e.d_model, e.d_model), layers._dt(cfg)),
                "wv": layers.dense_init(jax.random.fold_in(k1, 2), (e.d_model, e.d_model), layers._dt(cfg)),
                "wo": layers.dense_init(jax.random.fold_in(k1, 3), (e.d_model, e.d_model), layers._dt(cfg)),
            },
            "norm2": {"scale": jnp.zeros((e.d_model,), jnp.float32),
                      "bias": jnp.zeros((e.d_model,), jnp.float32)},
            "ffn": {"wi": layers.dense_init(k2, (e.d_model, e.d_ff), layers._dt(cfg)),
                    "wo": layers.dense_init(jax.random.fold_in(k2, 1), (e.d_ff, e.d_model), layers._dt(cfg))},
        })
    return {"layers": lyrs,
            "final_norm": {"scale": jnp.zeros((e.d_model,), jnp.float32),
                           "bias": jnp.zeros((e.d_model,), jnp.float32)}}


def _ln(p, x, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)) * (1.0 + p["scale"]) + p["bias"]).astype(x.dtype)


def _encoder_forward(p, frames, cfg: ModelConfig):
    """frames: (B, F, d_enc) post-conv (stub) -> encoder states (B, F, d_enc)."""
    e = cfg.encoder
    x = frames.astype(layers._dt(cfg))
    x = x + layers.sinusoidal_positions(x.shape[1], e.d_model)[None].astype(x.dtype)
    hd = e.d_model // e.num_heads
    for lp in p["layers"]:
        h = _ln(lp["norm1"], x, cfg.norm_eps)
        b, f, _ = h.shape
        q = (h @ lp["attn"]["wq"]).reshape(b, f, e.num_heads, hd)
        k = (h @ lp["attn"]["wk"]).reshape(b, f, e.num_heads, hd)
        v = (h @ lp["attn"]["wv"]).reshape(b, f, e.num_heads, hd)
        mask = jnp.ones((b, f, f), bool)  # bidirectional
        o = layers.mha(q, k, v, mask, 0.0, 1.0 / np.sqrt(hd))
        x = x + o.reshape(b, f, e.d_model) @ lp["attn"]["wo"]
        h = _ln(lp["norm2"], x, cfg.norm_eps)
        h = jax.nn.gelu((h @ lp["ffn"]["wi"]).astype(jnp.float32)).astype(x.dtype)
        x = x + h @ lp["ffn"]["wo"]
    return _ln(p["final_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()
        self.has_cross = cfg.encoder is not None
        # Megatron-style padded vocab: keeps the vocab dim divisible by the
        # model axis so logits stay vocab-sharded (pad columns are masked).
        self.v_pad = -(-cfg.vocab_size // 256) * 256

    # -------------------- init --------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = layers.split_keys(key, 8)
        params: Dict[str, Any] = {
            "embed": layers.dense_init(keys[0], (self.v_pad, cfg.d_model),
                                       layers._dt(cfg), scale=0.02),
            "final_norm": layers.norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(keys[1], (cfg.d_model, self.v_pad),
                                                  layers._dt(cfg))
        kinds, moes = cfg.layer_kinds(), cfg.layer_is_moe()
        np_, nb, per = len(cfg.prefix_pattern), cfg.num_blocks, cfg.period

        params["prefix"] = [
            _layer_init(jax.random.fold_in(keys[2], i), cfg, kinds[i], moes[i], self.has_cross)
            for i in range(np_)]

        def one_block(k):
            return [_layer_init(jax.random.fold_in(k, j), cfg,
                                cfg.block_pattern[j], cfg.moe_pattern[j], self.has_cross)
                    for j in range(per)]

        blocks = [one_block(jax.random.fold_in(keys[3], i)) for i in range(nb)]
        params["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)

        params["tail"] = [
            _layer_init(jax.random.fold_in(keys[4], i), cfg,
                        cfg.tail_pattern[i], cfg.tail_moe[i], self.has_cross)
            for i in range(len(cfg.tail_pattern))]

        if cfg.encoder is not None:
            params["encoder"] = _encoder_init(keys[5], cfg)
            # project encoder states into decoder K/V space is handled by the
            # per-layer cross wk/wv (sized d_enc -> kv heads) in _layer_init.
        return params

    # -------------------- embedding / logits --------------------
    def _embed(self, params, batch: Batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch.tokens, axis=0)
        if cfg.scale_embedding:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        offset = 0
        if cfg.frontend == "vision_patches" and batch.prefix_embeds is not None:
            x = jnp.concatenate([batch.prefix_embeds.astype(x.dtype), x], axis=1)
            offset = batch.prefix_embeds.shape[1]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.rope_theta <= 0:  # learned/sinusoidal absolute positions (whisper)
            x = x + layers.sinusoidal_positions(s, cfg.d_model)[None].astype(x.dtype)
        x = shard_utils.constrain(x, "batch", None, None)
        return x, positions, offset

    def logits(self, params, x, *, keep_pad: bool = False):
        cfg = self.cfg
        h = layers.apply_norm(params["final_norm"], x, cfg)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        lg = h.astype(jnp.float32) @ w.astype(jnp.float32)
        lg = layers._softcap(lg, cfg.final_logit_softcap)
        if self.v_pad != cfg.vocab_size:
            mask = jnp.arange(self.v_pad) < cfg.vocab_size
            lg = jnp.where(mask, lg, layers.NEG_INF)
            if not keep_pad:
                lg = lg[..., : cfg.vocab_size]
        return lg

    # -------------------- full-sequence forward --------------------
    def forward(self, params, batch: Batch, *, remat: bool = False,
                return_cache: bool = False):
        """Returns (hidden (B,Stot,D), aux_loss, cache_or_None).

        When return_cache=False the per-layer KV caches are not emitted from
        the scan at all (they would otherwise be stacked into (num_blocks,...)
        buffers that survive DCE through the remat boundary)."""
        cfg = self.cfg
        x, positions, offset = self._embed(params, batch)
        enc_kv = None
        if self.has_cross:
            enc_states = _encoder_forward(params["encoder"], batch.audio_frames, cfg)
            enc_kv = enc_states  # per-layer projection below

        aux_total = jnp.zeros((), jnp.float32)
        caches = {"prefix": [], "tail": []}

        def run_layer(p, x, kind, is_moe):
            ekv = None
            if enc_kv is not None and "cross" in p:
                b, f, _ = enc_kv.shape
                hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
                k = (enc_kv @ p["cross"]["wk"]).reshape(b, f, hkv, hd)
                v = (enc_kv @ p["cross"]["wv"]).reshape(b, f, hkv, hd)
                ekv = (k, v)
            return _layer_forward(p, x, positions, cfg, kind, is_moe, enc_kv=ekv)

        kinds, moes = cfg.layer_kinds(), cfg.layer_is_moe()
        li = 0
        for p in params["prefix"]:
            x, aux, c = run_layer(p, x, kinds[li], moes[li])
            aux_total += aux
            caches["prefix"].append(c)
            li += 1

        per = cfg.period

        def block_fn(carry, bp):
            x, aux_total = carry
            cs = []
            for j in range(per):
                x, aux, c = run_layer(bp[j], x, cfg.block_pattern[j], cfg.moe_pattern[j])
                aux_total += aux
                cs.append(c)
            # NOTE: sequence-parallel sharding of the carry was tried here
            # and reverted: XLA re-gathers the saved residual stack in the
            # backward scan (9 TB of all-gather for DeepSeek), negating the
            # memory win.  See EXPERIMENTS.md §Perf.
            return (x, aux_total), (cs if return_cache else None)

        if remat:
            block_fn = jax.checkpoint(block_fn, prevent_cse=False)
        (x, aux_total), block_caches = jax.lax.scan(block_fn, (x, aux_total),
                                                    params["blocks"])
        caches["blocks"] = block_caches
        li += cfg.num_blocks * per

        for p in params["tail"]:
            x, aux, c = run_layer(p, x, kinds[li], moes[li])
            aux_total += aux
            caches["tail"].append(c)
            li += 1

        if return_cache:
            return x, aux_total, (caches, enc_kv, offset)
        return x, aux_total, None

    # -------------------- loss --------------------
    def loss(self, params, batch: Batch, *, remat: bool = True,
             xent_chunk: int = 65536):
        """Next-token xent (chunked over tokens to bound logits memory)."""
        cfg = self.cfg
        x, aux, _ = self.forward(params, batch, remat=remat)
        if cfg.frontend == "vision_patches" and batch.prefix_embeds is not None:
            x = x[:, batch.prefix_embeds.shape[1]:, :]
        b, s, d = x.shape
        # predict token t+1 from position t
        h = x[:, :-1, :].reshape(-1, d)
        y = batch.tokens[:, 1:].reshape(-1)
        m = batch.loss_mask[:, 1:].reshape(-1).astype(jnp.float32)
        t = h.shape[0]
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

        chunk = min(xent_chunk, t)
        while t % chunk:
            chunk -= 1

        vpad_mask = (jnp.arange(self.v_pad) < cfg.vocab_size
                     ) if self.v_pad != cfg.vocab_size else None

        def xent_block(args):
            hc, yc, mc = args
            lg = hc.astype(jnp.float32) @ w.astype(jnp.float32)
            lg = shard_utils.constrain(lg, "batch", "model")  # (T, Vpad) sharded
            lg = layers._softcap(lg, cfg.final_logit_softcap)
            if vpad_mask is not None:
                lg = jnp.where(vpad_mask, lg, layers.NEG_INF)
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, yc[:, None], axis=-1)[:, 0]
            return jnp.sum((lse - gold) * mc), jnp.sum(mc)

        xent_block = jax.checkpoint(xent_block)  # recompute logits in bwd

        if chunk == t:
            tot, cnt = xent_block((h, y, m))
        else:
            nc = t // chunk

            def body(carry, args):
                tot, cnt = carry
                dt_, dc = xent_block(args)
                return (tot + dt_, cnt + dc), None

            (tot, cnt), _ = jax.lax.scan(
                body, (jnp.zeros(()), jnp.zeros(())),
                (h.reshape(nc, chunk, d), y.reshape(nc, chunk), m.reshape(nc, chunk)))
        nll = tot / jnp.maximum(cnt, 1.0)
        return nll + aux, {"nll": nll, "aux": aux, "tokens": cnt}

    # -------------------- decode --------------------
    def init_cache(self, batch: int, max_len: int, *, paged: bool = False,
                   page_size: int = 64, num_pages: Optional[int] = None,
                   prefix_sharing: bool = True):
        """Decode cache for every layer.

        paged=False (default): zeroed dense per-slot buffers — every slot
        pays for `max_len` up front (+enc_kv slot for whisper).

        paged=True: a started `kv_pages.PagedKVPool` instead — slots draw
        `page_size`-token pages from a shared pool of `num_pages` (default:
        the dense equivalent, batch * ceil(max_len / page_size)) as they
        grow; drive it with `decode_step_paged` / `prefill_chunk_paged`.
        prefix_sharing toggles the pool's radix prefix index (cross-slot
        page aliasing with copy-on-write; ignored for dense caches).
        Only the all-"attn" model class supports it (`supports_paged_kv`)."""
        cfg = self.cfg
        if paged:
            if not supports_paged_kv(cfg):
                raise ValueError(
                    f"paged KV unsupported for arch {cfg.name}: needs "
                    "all-'attn' layers, no MLA, no encoder")
            maxp = kvp.pages_for(max_len, page_size)
            return_pool = kvp.PagedKVPool(
                num_layers=cfg.num_layers, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, dtype=layers._dt(cfg),
                num_pages=num_pages or batch * maxp, page_size=page_size,
                max_pages_per_slot=maxp, prefix_sharing=prefix_sharing)
            return_pool.start(batch)
            return return_pool
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        dt = layers._dt(cfg)

        def one(kind):
            if kind == "ssm":
                return ssm_lib.init_ssm_state(cfg, batch)
            if cfg.mla is not None:
                m = cfg.mla
                return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
                        "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dt)}
            # sliding-window / chunked layers only ever attend within
            # `window`, so their cache is a ring of that many slots
            sm = max_len if kind == "attn" else min(max_len, cfg.window_size)
            return {"k": jnp.zeros((batch, sm, hkv, hd), dt),
                    "v": jnp.zeros((batch, sm, hkv, hd), dt)}

        kinds = cfg.layer_kinds()
        np_, nb, per = len(cfg.prefix_pattern), cfg.num_blocks, cfg.period
        cache = {
            "prefix": [one(kinds[i]) for i in range(np_)],
            "blocks": [jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[one(cfg.block_pattern[j]) for _ in range(nb)]) for j in range(per)]
            if nb else [],
            "tail": [one(k) for k in cfg.tail_pattern],
        }
        if self.has_cross:
            e = cfg.encoder
            n_layers = cfg.num_layers
            cache["enc_kv"] = jnp.zeros((n_layers, 2, batch, e.seq_len,
                                         cfg.num_kv_heads, cfg.resolved_head_dim), dt)
        return cache

    def _cross_kv_from_cache(self, cache, layer_idx):
        if "enc_kv" not in cache:
            return None
        ekv = cache["enc_kv"][layer_idx]
        return (ekv[0], ekv[1])

    def decode_step(self, params, cache, tokens, positions, active=None):
        """tokens: (B,1) int32; positions: (B,) write index; active: optional
        (B,) live-slot mask (continuous batching: rows of released slots stay
        in the batch for shape stability but must not consume MoE dispatch
        capacity).  Returns (logits (B, V), new_cache)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.scale_embedding:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        if cfg.rope_theta <= 0:
            # absolute positions: add the embedding for the current position
            pos_table = layers.sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
            x = x + pos_table[positions][:, None, :].astype(x.dtype)

        kinds, moes = cfg.layer_kinds(), cfg.layer_is_moe()
        per = cfg.period
        li = 0
        new_cache = {"prefix": [], "tail": []}
        for p, c in zip(params["prefix"], cache["prefix"]):
            x, nc = _layer_decode(p, x, c, positions, cfg, kinds[li], moes[li],
                                  enc_kv=self._cross_kv_from_cache(cache, li),
                                  token_mask=active)
            new_cache["prefix"].append(nc)
            li += 1

        if cfg.num_blocks:
            block_li0 = li

            def block_fn(carry, xs):
                # the stacked cache rides in the CARRY and is updated with
                # per-block dynamic slices — passing it as scan xs/ys would
                # read+write the entire multi-GB cache every decode step
                x, cache_st = carry
                bp, bi = xs
                cache_st = list(cache_st)
                for j in range(per):
                    ekv = None
                    if "enc_kv" in cache:
                        ekv_all = jax.lax.dynamic_index_in_dim(
                            cache["enc_kv"], block_li0 + bi * per + j, axis=0,
                            keepdims=False)
                        ekv = (ekv_all[0], ekv_all[1])
                    bc_j = jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, bi, 0, keepdims=False), cache_st[j])
                    x, ncj = _layer_decode(bp[j], x, bc_j, positions, cfg,
                                           cfg.block_pattern[j], cfg.moe_pattern[j],
                                           enc_kv=ekv, token_mask=active)
                    cache_st[j] = jax.tree_util.tree_map(
                        lambda a, u: jax.lax.dynamic_update_index_in_dim(
                            a, u.astype(a.dtype), bi, 0), cache_st[j], ncj)
                return (x, tuple(cache_st)), None

            bi = jnp.arange(cfg.num_blocks, dtype=jnp.int32)
            (x, new_blocks), _ = jax.lax.scan(
                block_fn, (x, tuple(cache["blocks"])), (params["blocks"], bi))
            new_cache["blocks"] = list(new_blocks)
            li += cfg.num_blocks * per
        else:
            new_cache["blocks"] = []

        for p, c, kind, is_moe in zip(params["tail"], cache["tail"],
                                      cfg.tail_pattern, cfg.tail_moe):
            x, nc = _layer_decode(p, x, c, positions, cfg, kind, is_moe,
                                  enc_kv=self._cross_kv_from_cache(cache, li),
                                  token_mask=active)
            new_cache["tail"].append(nc)
            li += 1

        if "enc_kv" in cache:
            new_cache["enc_kv"] = cache["enc_kv"]
        lg = self.logits(params, x)[:, 0, :]
        return lg, new_cache

    # -------------------- paged decode / chunked prefill --------------------
    # Donated argnums for jits of the two paged entry points below (the page
    # buffers, rebound to the returned updated buffers by every caller).
    # Single source of truth shared by serving/api.py (DenseBackend),
    # models/kv_pages.py (ChunkedPrefill) and the trace-time auditor's
    # registry (tools/analysis/entrypoints.py), so the declaration the
    # donation-honored rule audits is the one production registers.
    PAGED_DECODE_DONATE = (1, 2)
    PAGED_PREFILL_DONATE = (1, 2)

    def decode_step_paged(self, params, k_pages, v_pages, table, tokens,
                          positions, active):
        """One decode step against a paged KV pool (`supports_paged_kv`
        model class; flat per-layer loop — the paged layout replaces the
        scanned-block cache carry with shared page buffers).

        k_pages/v_pages: per-layer lists of (P, psz, Hkv, hd) pool buffers;
        table: (B, maxp) page table; tokens: (B, 1); positions: (B,) write
        index; active: (B,) bool (inactive slots write nothing and take no
        MoE capacity).  Returns (logits (B, V), new_k_pages, new_v_pages)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.scale_embedding:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        if cfg.rope_theta <= 0:
            pos_table = layers.sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
            x = x + pos_table[positions][:, None, :].astype(x.dtype)
        moes = self.cfg.layer_is_moe()
        k_pages, v_pages = list(k_pages), list(v_pages)
        for li, p in enumerate(unstack_layers(cfg, params)):
            x, k_pages[li], v_pages[li] = _layer_decode_paged(
                p, x, k_pages[li], v_pages[li], table, positions, active,
                cfg, moes[li])
        lg = self.logits(params, x)[:, 0, :]
        return lg, k_pages, v_pages

    def prefill_chunk_paged(self, params, k_pages, v_pages, table, tokens,
                            start, n, wstart=None):
        """One chunk of chunked prefill against a paged KV pool: run `tokens`
        (B, C) — row b valid for its first n[b] tokens, starting at absolute
        position start[b] — through every layer, writing K/V into the rows'
        pages and attending over everything written so far.

        wstart: optional (B,) per-row write floor for prefix sharing —
        positions below wstart[b] are re-fed tokens whose K/V already sits
        in aliased pages: they attend normally but their writes are dropped,
        so shared pages are never re-written (the values would be identical;
        dropping keeps copy-on-write confined to genuinely divergent
        writes).  None means write everything (no sharing).

        Returns (last-valid-token logits (B, V), new_k_pages, new_v_pages).
        Rows may belong to different requests: admission batches up to k
        joining prompts through one call (serving.batching).  Numerics match
        one-shot prefill exactly for attention; MoE capacity is computed per
        chunk, so token *drops* can differ at tight capacity_factor (ample
        capacity — the serving configs here — makes them identical)."""
        cfg = self.cfg
        b, c = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.scale_embedding:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        if cfg.rope_theta <= 0:
            pos_table = layers.sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
            x = x + pos_table[positions].astype(x.dtype)
        valid_flat = (jnp.arange(c, dtype=jnp.int32)[None, :]
                      < n[:, None]).reshape(-1)
        if wstart is None:
            wstart = jnp.zeros_like(start)
        moes = self.cfg.layer_is_moe()
        k_pages, v_pages = list(k_pages), list(v_pages)
        for li, p in enumerate(unstack_layers(cfg, params)):
            x, k_pages[li], v_pages[li] = _layer_chunk_paged(
                p, x, k_pages[li], v_pages[li], table, start, n, wstart,
                valid_flat, cfg, moes[li])
        last = jnp.clip(n - 1, 0, c - 1)
        xl = jnp.take_along_axis(x, last[:, None, None], axis=1)    # (B,1,D)
        lg = self.logits(params, xl)[:, 0, :]
        return lg, k_pages, v_pages

    # -------------------- prefill --------------------
    def prefill(self, params, batch: Batch, max_len: int):
        """Run the full prompt, build a decode cache padded to max_len.
        Returns (last_logits (B,V), cache, next_positions (B,))."""
        cfg = self.cfg
        x, aux, (caches, enc_states, offset) = self.forward(
            params, batch, remat=False, return_cache=True)
        b, s, _ = x.shape
        cache = self.init_cache(b, max_len)

        def fill_attn(dst, kv):
            if cfg.mla is not None:
                c_kv, k_rope = kv["c_kv"], kv["k_rope"]
                dst = dict(dst)
                dst["c_kv"] = jax.lax.dynamic_update_slice(
                    dst["c_kv"], c_kv.astype(dst["c_kv"].dtype), (0, 0, 0))
                dst["k_rope"] = jax.lax.dynamic_update_slice(
                    dst["k_rope"], k_rope.astype(dst["k_rope"].dtype), (0, 0, 0))
                return dst
            sm = dst["k"].shape[1]
            src_k, src_v = kv["k"], kv["v"]
            if src_k.shape[1] > sm:
                # ring cache: keep the last `sm` keys at slots p % sm
                p0 = src_k.shape[1] - sm
                slots = (p0 + jnp.arange(sm)) % sm
                return {
                    "k": dst["k"].at[:, slots].set(
                        src_k[:, -sm:].astype(dst["k"].dtype)),
                    "v": dst["v"].at[:, slots].set(
                        src_v[:, -sm:].astype(dst["v"].dtype)),
                }
            return {
                "k": jax.lax.dynamic_update_slice(dst["k"], src_k.astype(dst["k"].dtype),
                                                  (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(dst["v"], src_v.astype(dst["v"].dtype),
                                                  (0, 0, 0, 0)),
            }

        kinds = cfg.layer_kinds()
        for i, c in enumerate(caches["prefix"]):
            cache["prefix"][i] = (c if kinds[i] == "ssm" else fill_attn(cache["prefix"][i], c))
        per = cfg.period
        for j in range(per):
            kind = cfg.block_pattern[j]
            src = caches["blocks"][j]  # stacked (nb, ...) from scan
            if kind == "ssm":
                cache["blocks"][j] = src
            else:
                dst = cache["blocks"][j]
                cache["blocks"][j] = jax.vmap(fill_attn)(dst, src)
        for i, c in enumerate(caches["tail"]):
            kind = cfg.tail_pattern[i]
            cache["tail"][i] = c if kind == "ssm" else fill_attn(cache["tail"][i], c)

        if self.has_cross and enc_states is not None:
            hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            all_kv = []
            flat_layers = unstack_layers(self.cfg, params)
            for p in flat_layers[: cfg.num_layers]:
                bsz, f, _ = enc_states.shape
                k = (enc_states @ p["cross"]["wk"]).reshape(bsz, f, hkv, hd)
                v = (enc_states @ p["cross"]["wv"]).reshape(bsz, f, hkv, hd)
                all_kv.append(jnp.stack([k, v]))
            cache["enc_kv"] = jnp.stack(all_kv).astype(cache["enc_kv"].dtype)

        last = self.logits(params, x[:, -1:, :])[:, 0, :]
        positions = jnp.full((b,), s, jnp.int32)
        return last, cache, positions


# --------------------------------------------------------------------------
# flat per-layer access (HOBBIT engine, tests)
# --------------------------------------------------------------------------

def unstack_layers(cfg: ModelConfig, params):
    """Flatten (prefix, scanned blocks, tail) into a per-layer param list."""
    out = list(params["prefix"])
    for i in range(cfg.num_blocks):
        blk = jax.tree_util.tree_map(lambda a, i=i: a[i], params["blocks"])
        out.extend(blk)
    out.extend(params["tail"])
    return out


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
