from repro.models.model import Batch, Model, build_model, unstack_layers

__all__ = ["Batch", "Model", "build_model", "unstack_layers"]
