"""Paged KV cache: a fixed device-resident page pool shared by all serving
slots, so a slot's KV memory grows with its *actual* length instead of every
slot paying for the batch's ``max_len``.

Mirrors how the HOBBIT engine treats expert memory (a pooled resource whose
slots are dynamically assigned) and applies the same idea to the other big
serving allocation, the KV cache:

  * ``PagedKVPool`` owns, per transformer layer, K and V buffers of shape
    ``(num_pages, page_size, num_kv_heads, head_dim)`` plus host-side
    metadata: a per-slot page table (logical page index -> physical page id),
    a free list, per-page refcounts, and per-slot admission *reservations* so
    a request admitted into a slot can always grow to its declared total
    length even while other requests are being admitted concurrently.
  * **Prefix sharing** (``prefix_sharing=True``): completed prompts are
    registered page-by-page in a radix trie keyed by token content.  A new
    admission matches its prompt against the trie and *aliases* the longest
    covered prefix — full pages, plus a trailing partial page whose written
    tokens agree with the prompt — into its own page table (refcount++),
    skipping prefill for the matched tokens and reserving pages only for the
    unshared suffix.  The first write into a shared page (the divergent
    suffix landing in a partial prefix page, or decode appending past the
    prompt) triggers **copy-on-write**: the writer's table entry is switched
    to a fresh page and the page contents are copied on device before the
    scatter (``make_writable``); readers keep the original.  Sharing is
    live-slot only — a released slot drops out of the trie, so
    ``refcount[p]`` always equals the number of slot tables referencing
    ``p``.
  * The jit-facing view is purely functional: ``table_device()`` exports the
    page table as an int32 ``(batch, max_pages_per_slot)`` array, and the
    paged attention kernels (``layers.paged_attn_decode`` /
    ``layers.paged_attn_prefill_chunk``) gather/scatter through it, returning
    updated page buffers that the host writes back.  Aliased tables need no
    kernel changes: the kernels index physical pages through the table, so
    two slots whose tables point at the same page attend over the same KV.
  * ``release(slot)`` decrements the refcount of every page in the slot's
    table and returns the exclusively-owned ones to the free list, so the
    next queued request can be admitted mid-flight without reallocating
    anything.  A second ``release`` of the same slot is a clean no-op.

``ChunkedPrefill`` is the shared admission driver: it feeds prompts through
``model.prefill_chunk_paged`` in fixed-size chunks (one *batched* jitted call
per chunk covering every request currently being admitted) so long prompts
never stall in-flight decodes.  With prefix sharing it resumes feeding at
the matched length (re-feeding at least the final prompt token so last-token
logits exist) and passes a per-row ``wstart`` to the kernel so re-fed
positions *attend* but never *re-write* aliased pages.  Both
``DenseBackend`` and the ``OffloadEngine`` use it.

See ``docs/ARCHITECTURE.md`` for how this fits the request lifecycle.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagePoolExhausted(RuntimeError):
    """Raised when a page allocation or reservation cannot be satisfied.

    Admission-time callers (the batching scheduler) treat this as "the
    request must wait for pages"; hitting it *mid-decode* indicates the
    caller admitted a request without reserving its full length."""


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages needed to hold `tokens` KV entries."""
    # `tokens` is always a host int (static at trace time when this runs
    # under jit via init_cache), so int() here never blocks on a device value
    return -(-int(tokens) // page_size) if tokens > 0 else 0  # analysis: ignore[host-sync-in-jit]


@partial(jax.jit, donate_argnums=(0,))
def _copy_page(pages, src, dst):
    """Copy physical page `src` onto `dst` inside one page buffer (the COW
    device copy).  src/dst are traced int32 scalars, so every (shape, dtype)
    compiles exactly once regardless of which pages are copied."""
    return pages.at[dst].set(pages[src])


class _PrefixNode:
    """One page of a registered prompt chain in the radix prefix index.

    ``tokens`` is the page's written token content (a full ``page_size``
    tuple for interior pages, shorter for a trailing partial page — partial
    nodes are always leaves); ``page`` is the physical page id whose KV holds
    those tokens; ``refs`` is the set of live slots whose tables alias the
    page *through this node* (registrant + sharers — a slot that copy-on-
    writes away is removed).  Nodes are pruned when ``refs`` empties, so the
    trie never retains pages beyond the slots that own them."""

    __slots__ = ("tokens", "page", "parent", "children", "partials", "refs")

    def __init__(self, tokens: Tuple[int, ...], page: int,
                 parent: Optional["_PrefixNode"]):
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _PrefixNode] = {}
        self.partials: List[_PrefixNode] = []
        self.refs: set = set()


class PagedKVPool:
    """Fixed device-resident KV page pool with per-slot page tables, a radix
    prefix index for cross-slot page aliasing, and copy-on-write.

    The pool is sized once (``num_pages`` pages of ``page_size`` tokens per
    layer); serving slots draw pages on demand and return them on release.
    All metadata lives on the host (plain python/numpy — allocation is a
    per-token-batch, not per-element, operation); only the page buffers and
    the exported page table touch the device.
    """

    def __init__(self, *, num_layers: int, num_kv_heads: int, head_dim: int,
                 dtype, num_pages: int, page_size: int = 64,
                 max_pages_per_slot: int = 0, prefix_sharing: bool = True):
        """max_pages_per_slot bounds one slot's logical length (defaults to
        the whole pool); it is the width of the exported page table.
        prefix_sharing=False disables the radix index entirely (admissions
        always prefill their full prompt and share no pages)."""
        self.num_layers = num_layers
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.max_pages_per_slot = int(max_pages_per_slot or num_pages)
        self.prefix_sharing = bool(prefix_sharing)
        self.k: List[jax.Array] = [
            jnp.zeros((num_pages, page_size, num_kv_heads, head_dim), dtype)
            for _ in range(num_layers)]
        self.v: List[jax.Array] = [
            jnp.zeros((num_pages, page_size, num_kv_heads, head_dim), dtype)
            for _ in range(num_layers)]
        self.batch = 0
        self.free: List[int] = list(range(self.num_pages))
        self.table = np.zeros((0, self.max_pages_per_slot), np.int32)
        self.owned: List[List[int]] = []
        self.lens = np.zeros((0,), np.int64)
        self.reserved = np.zeros((0,), np.int64)   # pages promised, not drawn
        self.refcount = np.zeros((num_pages,), np.int32)  # owner: main-thread
        self._table_dev = None
        self._root = _PrefixNode((), -1, None)            # owner: main-thread
        self._page_node: Dict[int, _PrefixNode] = {}      # owner: main-thread
        self._slot_nodes: List[List[_PrefixNode]] = []    # owner: main-thread
        # pages that may yet be consumed by copy-on-write: one per extra
        # sharer of each *partial* (writable) shared page.  Subtracted from
        # reservable_pages() so a donor-side COW can never steal a page
        # promised to another slot's reservation.
        self.cow_debt = 0                                 # owner: main-thread
        self.prefix_hit_tokens = 0
        self.cow_copies = 0

    # ------------- batch lifecycle -------------
    def start(self, batch: int):
        """Reset metadata for a new batch of `batch` slots (buffers are
        reused; stale page contents are dead because reads are masked by
        each slot's position)."""
        self.batch = batch
        self.free = list(range(self.num_pages))
        self.table = np.zeros((batch, self.max_pages_per_slot), np.int32)
        self.owned = [[] for _ in range(batch)]
        self.lens = np.zeros((batch,), np.int64)
        self.reserved = np.zeros((batch,), np.int64)
        self.refcount = np.zeros((self.num_pages,), np.int32)
        self._root = _PrefixNode((), -1, None)
        self._page_node = {}
        self._slot_nodes = [[] for _ in range(batch)]
        self.cow_debt = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0
        self._table_dev = None

    # ------------- radix prefix index -------------
    def _is_partial(self, node: _PrefixNode) -> bool:
        return len(node.tokens) < self.page_size

    # owner: main-thread
    def _refs_add(self, node: _PrefixNode, slot: int):
        if self._is_partial(node) and len(node.refs) >= 1:
            self.cow_debt += 1        # one more potential COW on this page
        node.refs.add(slot)

    # owner: main-thread
    def _refs_discard(self, node: _PrefixNode, slot: int):
        if slot not in node.refs:
            return
        if self._is_partial(node) and len(node.refs) >= 2:
            self.cow_debt -= 1
        node.refs.discard(slot)
        if not node.refs:
            self._prune(node)

    # owner: main-thread
    def _prune(self, node: _PrefixNode):
        """Unlink a no-longer-referenced node from the trie.  Children prune
        themselves: a slot referencing a node references all its ancestors,
        so a node's refs can only empty after its descendants'."""
        parent = node.parent
        if parent is not None:
            if self._is_partial(node):
                if node in parent.partials:
                    parent.partials.remove(node)
            elif parent.children.get(node.tokens) is node:
                del parent.children[node.tokens]
        if self._page_node.get(node.page) is node:
            del self._page_node[node.page]

    def _match_prefix(self, prompt: np.ndarray
                      ) -> Tuple[List[_PrefixNode], Optional[_PrefixNode],
                                 int, int]:
        """Longest trie match for `prompt`: (full-page node chain, best
        partial leaf or None, tokens covered by the chain, tokens covered by
        the partial).  A partial page matches when every written token the
        prompt reaches agrees (tokens written past the prompt's end are
        invisible — reads are masked by position)."""
        psz, L = self.page_size, len(prompt)
        node, nodes, off = self._root, [], 0
        while L - off >= psz:
            child = node.children.get(tuple(prompt[off:off + psz].tolist()))
            if child is None:
                break
            nodes.append(child)
            off += psz
            node = child
        best, bestk = None, 0
        for pc in node.partials:
            k = min(len(pc.tokens), L - off)
            if k > bestk and pc.tokens[:k] == tuple(
                    prompt[off:off + k].tolist()):
                best, bestk = pc, k
        return nodes, best, off, bestk

    def _share_plan(self, tokens: int, prompt
                    ) -> Optional[Tuple[List[_PrefixNode], int, int]]:
        """Best admissible aliasing plan for a fresh slot needing `tokens`
        total KV entries: (node chain to alias, matched token count, suffix
        pages to reserve).  Tries partial-page sharing first (costs one
        cow_debt unit of headroom), falls back to full pages only, then to
        None (no sharing).  Non-mutating."""
        if not (self.prefix_sharing and prompt is not None):
            return None
        prompt = np.asarray(prompt).reshape(-1)
        if len(prompt) == 0:
            return None
        need = pages_for(tokens, self.page_size)
        nodes, best, off, bestk = self._match_prefix(prompt)
        plans = []
        if best is not None and bestk > 0:
            plans.append((nodes + [best], off + bestk, 1))
        if nodes:
            plans.append((list(nodes), off, 0))
        for chain, matched, debt in plans:
            if len(chain) > need:     # reserve_tokens shorter than the match
                continue
            extra = need - len(chain)
            if extra + debt <= self.reservable_pages():
                return chain, matched, extra
        return None

    def _alias(self, slot: int, chain: List[_PrefixNode]):
        """Point the first len(chain) logical pages of `slot` at the chain's
        physical pages (refcount++ each; no prefill, no free-list draw)."""
        own = self.owned[slot]
        for node in chain:
            self.table[slot, len(own)] = node.page
            own.append(node.page)
            self.refcount[node.page] += 1
            self._refs_add(node, slot)
            self._slot_nodes[slot].append(node)
        self._table_dev = None

    # owner: main-thread
    def register_prefix(self, slot: int, prompt):
        """Insert `slot`'s completed prompt into the radix index, page by
        page, so later admissions can alias it.  Pages already shared (the
        slot aliased them at admission) are skipped; content another live
        slot registered first wins (we stop rather than fork the trie on
        identical content under a different physical page)."""
        if not self.prefix_sharing:
            return
        prompt = np.asarray(prompt).reshape(-1)
        psz, L = self.page_size, len(prompt)
        node, own = self._root, self.owned[slot]
        for i in range(L // psz):
            content = tuple(prompt[i * psz:(i + 1) * psz].tolist())
            mine = int(own[i])
            child = node.children.get(content)
            if child is not None:
                if child.page != mine:
                    return            # duplicate content registered first
                node = child
                continue
            if mine in self._page_node:
                return  # page already indexed under other content (aliased)
            child = _PrefixNode(content, mine, node)
            child.refs.add(slot)
            node.children[content] = child
            self._page_node[mine] = child
            self._slot_nodes[slot].append(child)
            node = child
        rem = L % psz
        if rem == 0:
            return
        mine = int(own[L // psz])
        if mine in self._page_node:
            return                    # trailing page is itself an alias
        content = tuple(prompt[L - rem:].tolist())
        if any(pc.tokens == content for pc in node.partials):
            return                    # identical partial already registered
        leaf = _PrefixNode(content, mine, node)
        leaf.refs.add(slot)
        node.partials.append(leaf)
        self._page_node[mine] = leaf
        self._slot_nodes[slot].append(leaf)

    # ------------- copy-on-write -------------
    # owner: main-thread
    def make_writable(self, slot: int, start: int, end: int):
        """Host-side COW guard: call before any jitted call that writes
        token positions [start, end) of `slot`.  Shared target pages
        (refcount > 1) are copied to fresh pages — the writer's table entry
        moves, readers keep the original.  A page only this slot references
        stays registered when the write lands strictly PAST the node's
        recorded tokens (a decode append extends the page; matchers only
        ever read the recorded prefix, and their position mask hides the
        rest) and is unregistered when the write overlaps them (the
        recorded content is about to diverge)."""
        if not self.prefix_sharing or start >= end:
            return
        psz = self.page_size
        own = self.owned[slot]
        for li in range(int(start) // psz, (int(end) - 1) // psz + 1):
            if li >= len(own):
                break                 # not drawn yet -> cannot be shared
            pid = int(own[li])
            node = self._page_node.get(pid)
            if node is None or slot not in node.refs:
                continue              # exclusive page
            if self.refcount[pid] > 1:
                # other slots read this page (up to their own matched
                # lengths): any write, even past the recorded tokens, could
                # land where another sharer appends — copy first
                self._cow(slot, li, node)
            elif max(int(start), li * psz) - li * psz < len(node.tokens):
                self._refs_discard(node, slot)  # sole owner: just unregister
                self._slot_nodes[slot].remove(node)
            # else: sole-owner append past the recorded tokens — the record
            # stays accurate, so the page stays matchable for later sharers

    def _cow(self, slot: int, li: int, node: _PrefixNode):
        """Copy-on-write logical page `li` of `slot` off the shared physical
        page: draw a fresh page (funded by the cow_debt headroom), copy the
        KV content on device, and repoint this slot's table entry.  The
        other sharers (and the trie) keep the original page."""
        pid = node.page
        promised = int(self.reserved.sum())
        if not self.free or len(self.free) - promised - (
                self.cow_debt - 1) <= 0:
            raise PagePoolExhausted(
                f"slot {slot}: pool exhausted on copy-on-write of page {pid} "
                f"({len(self.free)} free, {promised} reserved, "
                f"{self.cow_debt} COW debt)")
        new = self.free.pop()
        self.refcount[new] = 1
        self.refcount[pid] -= 1
        self.table[slot, li] = new
        self.owned[slot][li] = new
        self._refs_discard(node, slot)          # releases one cow_debt unit
        self._slot_nodes[slot].remove(node)
        s, d = jnp.asarray(pid, jnp.int32), jnp.asarray(new, jnp.int32)
        self.k = [_copy_page(kp, s, d) for kp in self.k]
        self.v = [_copy_page(vp, s, d) for vp in self.v]
        self.cow_copies += 1
        self._table_dev = None

    # ------------- admission reservations -------------
    def reservable_pages(self) -> int:
        """Pages available to NEW admissions: free pages minus pages already
        promised to in-flight slots' future growth minus pages that pending
        copy-on-writes of shared partial pages may consume."""
        return len(self.free) - int(self.reserved.sum()) - self.cow_debt

    def fits(self, tokens: int) -> bool:
        """True iff a request of `tokens` total KV entries can EVER be
        served by this pool (page-table width and pool size); False means
        waiting will not help — reject, don't queue."""
        need = pages_for(tokens, self.page_size)
        return need <= min(self.max_pages_per_slot, self.num_pages)

    def can_reserve(self, tokens: int, prompt=None) -> bool:
        """True iff a request needing `tokens` total KV entries can be
        admitted now without ever starving an already-admitted slot (False
        for requests that exceed the per-slot table width or the pool —
        those can never be admitted; see `fits`).  With `prompt`, admission
        cost is evaluated against the best prefix-sharing plan: only the
        unshared suffix needs reservable pages.  Non-mutating."""
        if not self.fits(tokens):
            return False
        if pages_for(tokens, self.page_size) <= self.reservable_pages():
            return True
        return self._share_plan(tokens, prompt) is not None

    # owner: main-thread
    def reserve(self, slot: int, tokens: int, prompt=None) -> int:
        """Promise `tokens` total KV entries to `slot` (its prompt plus its
        decode budget).  With `prompt` and prefix sharing on, first alias
        the longest trie-matched prefix into the slot's table and charge the
        reservation only for the unshared suffix.  Returns the matched token
        count (0 without sharing).  Raises PagePoolExhausted if the promise
        cannot be kept, and ValueError if it exceeds the slot's page-table
        width."""
        need = pages_for(tokens, self.page_size)
        if need > self.max_pages_per_slot:
            raise ValueError(
                f"request needs {need} pages > max_pages_per_slot="
                f"{self.max_pages_per_slot} (max_len bound)")
        if need > self.num_pages:
            raise PagePoolExhausted(
                f"request needs {need} pages > pool size {self.num_pages}")
        if self.prefix_sharing and prompt is not None and not self.owned[slot]:
            plan = self._share_plan(tokens, prompt)
            if plan is not None:
                chain, matched, extra = plan
                self._alias(slot, chain)
                self.reserved[slot] = max(int(self.reserved[slot]), extra)
                self.lens[slot] = max(int(self.lens[slot]), matched)
                self.prefix_hit_tokens += matched
                return matched
        extra = need - len(self.owned[slot])
        if extra > self.reservable_pages() + int(self.reserved[slot]):
            raise PagePoolExhausted(
                f"slot {slot}: {extra} pages wanted, "
                f"{self.reservable_pages()} reservable")
        self.reserved[slot] = max(int(self.reserved[slot]), extra)
        return 0

    # ------------- allocation -------------
    def ensure(self, slot: int, length: int):
        """Grow `slot` to cover `length` tokens, drawing pages from the free
        list (the slot's own reservation first).  No-op if already covered
        (aliased prefix pages count as covered — they are never re-drawn).

        The draw is guarded against OTHER slots' reservations and pending
        COW debt: a slot growing without (or past) its own reservation may
        only take pages the pool has not promised elsewhere, so the offender
        raises PagePoolExhausted here — a properly-reserved slot can never
        lose a promised page and hit exhaustion mid-decode."""
        target = pages_for(length, self.page_size)
        if target > self.max_pages_per_slot:
            raise ValueError(
                f"slot {slot}: length {length} exceeds max_pages_per_slot")
        own = self.owned[slot]
        while len(own) < target:
            promised_to_others = int(self.reserved.sum()) - int(
                self.reserved[slot])
            if not self.free or len(self.free) - promised_to_others - (
                    self.cow_debt) <= 0:
                raise PagePoolExhausted(
                    f"slot {slot}: pool exhausted growing to {length} tokens "
                    f"({len(self.free)} free, {promised_to_others} promised "
                    "to other slots' reservations; admit with reserve() to "
                    "prevent this)")
            pid = self.free.pop()
            self.refcount[pid] = 1
            self.table[slot, len(own)] = pid
            own.append(pid)
            if self.reserved[slot] > 0:
                self.reserved[slot] -= 1
            self._table_dev = None
        self.lens[slot] = max(int(self.lens[slot]), int(length))

    # owner: main-thread
    def release(self, slot: int):
        """Drop the slot's claim on its pages — exclusively-owned pages
        (refcount hitting 0) return to the free list immediately, shared
        pages stay with their remaining sharers — and drop its reservation,
        so the next queued request can draw them at once.  Releasing an
        already-released (or never-admitted) slot is a clean no-op: the
        slot owns nothing, so no refcount is decremented twice and the free
        list cannot be corrupted."""
        for node in reversed(self._slot_nodes[slot]):
            self._refs_discard(node, slot)
        self._slot_nodes[slot] = []
        for pid in self.owned[slot]:
            self.refcount[pid] -= 1
            if self.refcount[pid] <= 0:
                self.refcount[pid] = 0
                self.free.append(pid)
        self.owned[slot] = []
        self.lens[slot] = 0
        self.reserved[slot] = 0
        self._table_dev = None

    # ------------- preemption snapshots -------------
    # owner: main-thread
    def snapshot_slot(self, slot: int) -> Dict[str, object]:
        """Host-side snapshot of `slot`'s KV state for preemption: page
        contents (gathered through the slot's table to host, per layer),
        written length, and the undrawn reservation balance.  Taken BEFORE
        ``release(slot)`` — the snapshot copies aliased prefix pages too, so
        releasing afterwards only drops this slot's refcounts and the
        remaining sharers keep the originals untouched."""
        pages = [int(p) for p in self.owned[slot]]
        if pages:
            idx = jnp.asarray(np.asarray(pages, np.int32))
            k = [np.asarray(kp[idx]) for kp in self.k]
            v = [np.asarray(vp[idx]) for vp in self.v]
        else:
            k, v = [], []
        return {"length": int(self.lens[slot]),
                "reserved": int(self.reserved[slot]),
                "num_pages": len(pages), "k": k, "v": v}

    # owner: main-thread
    def restore_slot(self, slot: int, snap: Dict[str, object]) -> None:
        """Re-admit a paused slot from its ``snapshot_slot`` dict: draw fresh
        pages for the snapshotted contents (plus the original undrawn
        reservation), scatter the page contents back on device, and restore
        the written length.  Restored pages are private (never re-registered
        in the prefix trie) — conservative, but sharing re-forms naturally on
        the next admission that matches.  Raises PagePoolExhausted when the
        pool cannot cover pages + reservation right now (the scheduler keeps
        the snapshot and retries later)."""
        assert not self.owned[slot], f"slot {slot} not empty on restore"
        npages = int(snap["num_pages"])
        reserved = int(snap["reserved"])
        if npages + reserved > self.reservable_pages():
            raise PagePoolExhausted(
                f"slot {slot}: resume needs {npages}+{reserved} pages, "
                f"{self.reservable_pages()} reservable")
        own = self.owned[slot]
        for li in range(npages):
            pid = self.free.pop()
            self.refcount[pid] = 1
            self.table[slot, li] = pid
            own.append(pid)
        if npages:
            idx = jnp.asarray(np.asarray(own, np.int32))
            self.k = [kp.at[idx].set(jnp.asarray(sk))
                      for kp, sk in zip(self.k, snap["k"])]
            self.v = [vp.at[idx].set(jnp.asarray(sv))
                      for vp, sv in zip(self.v, snap["v"])]
        self.lens[slot] = int(snap["length"])
        self.reserved[slot] = reserved
        self._table_dev = None

    # ------------- jit-facing views -------------
    def table_device(self) -> jax.Array:
        """Page table as a device int32 (batch, max_pages_per_slot) array
        (cached until the table changes)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.table)
        return self._table_dev

    # ------------- observability -------------
    @property
    def pages_used(self) -> int:
        """Physical pages currently owned by some slot."""
        return self.num_pages - len(self.free)

    @property
    def page_fraction(self) -> float:
        """pages_used / num_pages — the pool-pressure gauge."""
        return self.pages_used / self.num_pages if self.num_pages else 0.0

    @property
    def aliased_pages(self) -> int:
        """Physical pages currently referenced by more than one slot."""
        return int(np.sum(self.refcount >= 2))

    def stats(self) -> Dict[str, float]:
        """JSON-serializable pool counters (backend stats() contract keys)."""
        return {
            "kv_pages_used": self.pages_used,
            "kv_pages_total": self.num_pages,
            "kv_page_fraction": self.page_fraction,
            "kv_page_size": self.page_size,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "cow_copies": self.cow_copies,
            "aliased_page_fraction": (
                self.aliased_pages / self.pages_used if self.pages_used
                else 0.0),
        }


class ChunkedPrefill:
    """Incremental chunked-prefill admission driver over a ``PagedKVPool``.

    One instance per backend batch.  ``begin(slot, prompt, reserve_tokens)``
    registers a joining request (reserving its full KV budget so decode can
    never hit pool exhaustion); each ``step()`` advances EVERY pending
    admission by one fixed-size chunk through a single shared jitted call to
    ``model.prefill_chunk_paged`` and returns the last-token logits of the
    requests whose prompt completed.  The batching scheduler interleaves
    ``step()`` with decode steps so long prompts never stall in-flight
    decodes; ``run(slot, prompt, ...)`` is the blocking convenience loop used
    by the protocol-level ``join``.

    Prefix sharing: ``begin`` aliases the trie-matched prefix via
    ``pool.reserve(..., prompt=prompt)`` and resumes feeding at the matched
    length (always re-feeding the final prompt token so the finished
    admission has last-token logits); the matched length rides along as the
    row's ``wstart`` so re-fed positions attend over the aliased pages but
    drop their K/V writes.  Completed prompts are registered back into the
    trie for the next admission to match.
    """

    def __init__(self, model, params, pool: PagedKVPool, *, chunk: int = 64,
                 jit: bool = True):
        """chunk: tokens fed per step per request (the jit compiles once per
        (pending_rows, chunk) shape)."""
        self.model = model
        self.params = params
        self.pool = pool
        self.chunk = int(chunk)
        # donate the page buffers: the pool is rebound to the outputs right
        # after the call, so XLA may update pages in place instead of
        # holding input+output pools alive (2x KV footprint)
        self._fn = (jax.jit(model.prefill_chunk_paged,
                            donate_argnums=type(model).PAGED_PREFILL_DONATE)
                    if jit else model.prefill_chunk_paged)
        # slot -> (prompt, fed, wstart): next feed offset + write floor
        self._pending: Dict[int, Tuple[np.ndarray, int, int]] = {}
        self._unclaimed: Dict[int, np.ndarray] = {}  # finished during run()

    def begin(self, slot: int, prompt, reserve_tokens: Optional[int] = None):
        """Register `prompt` for admission into `slot`, reserving
        `reserve_tokens` total KV entries (default: the prompt alone).  A
        trie-matched prefix is aliased instead of re-prefilled."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert len(prompt) > 0, "empty prompt"
        assert slot not in self._pending, f"slot {slot} already admitting"
        matched = self.pool.reserve(slot, int(reserve_tokens or len(prompt)),
                                    prompt=prompt)
        fed = min(int(matched), len(prompt) - 1)
        self._pending[slot] = (prompt, fed, int(matched))

    @property
    def pending_slots(self) -> List[int]:
        """Slots with an admission in progress (sorted)."""
        return sorted(self._pending)

    def step(self) -> Dict[int, np.ndarray]:
        """Feed one chunk for every pending admission in ONE jitted call.
        Returns {slot: last-token logits (V,)} for prompts that completed
        (callers then flip the slot active and set its position)."""
        finished: Dict[int, np.ndarray] = dict(self._unclaimed)
        self._unclaimed = {}
        if not self._pending:
            return finished
        slots = self.pending_slots
        c = self.chunk
        toks = np.zeros((len(slots), c), np.int32)
        starts = np.zeros((len(slots),), np.int32)
        ns = np.zeros((len(slots),), np.int32)
        wstarts = np.zeros((len(slots),), np.int32)
        for i, s in enumerate(slots):
            prompt, fed, ws = self._pending[s]
            n = min(c, len(prompt) - fed)
            toks[i, :n] = prompt[fed : fed + n]
            starts[i], ns[i], wstarts[i] = fed, n, ws
            self.pool.ensure(s, fed + n)
            self.pool.make_writable(s, max(fed, ws), fed + n)
        table_rows = jnp.asarray(self.pool.table[slots])
        lg, ks, vs = self._fn(self.params, self.pool.k, self.pool.v,
                              table_rows, jnp.asarray(toks),
                              jnp.asarray(starts), jnp.asarray(ns),
                              jnp.asarray(wstarts))
        self.pool.k, self.pool.v = list(ks), list(vs)
        lg = np.asarray(lg, np.float32)
        for i, s in enumerate(slots):
            prompt, fed, ws = self._pending[s]
            fed += int(ns[i])
            if fed >= len(prompt):
                del self._pending[s]
                self.pool.register_prefix(s, prompt)
                finished[s] = lg[i]
            else:
                self._pending[s] = (prompt, fed, ws)
        return finished

    def run(self, slot: int, prompt,
            reserve_tokens: Optional[int] = None) -> np.ndarray:
        """Blocking admission: begin + step until `slot` finishes.  Other
        pending admissions advance alongside (shared chunks)."""
        self.begin(slot, prompt, reserve_tokens)
        while True:
            done = self.step()
            if slot in done:
                # logits of OTHER admissions that completed during this loop
                # stay claimable by the next step() call
                self._unclaimed.update(
                    {s: l for s, l in done.items() if s != slot})
                return done[slot]
            self._unclaimed.update(done)
