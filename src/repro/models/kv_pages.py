"""Paged KV cache: a fixed device-resident page pool shared by all serving
slots, so a slot's KV memory grows with its *actual* length instead of every
slot paying for the batch's ``max_len``.

Mirrors how the HOBBIT engine treats expert memory (a pooled resource whose
slots are dynamically assigned) and applies the same idea to the other big
serving allocation, the KV cache:

  * ``PagedKVPool`` owns, per transformer layer, K and V buffers of shape
    ``(num_pages, page_size, num_kv_heads, head_dim)`` plus host-side
    metadata: a per-slot page table (logical page index -> physical page id),
    a free list, and per-slot admission *reservations* so a request admitted
    into a slot can always grow to its declared total length even while other
    requests are being admitted concurrently.
  * The jit-facing view is purely functional: ``table_device()`` exports the
    page table as an int32 ``(batch, max_pages_per_slot)`` array, and the
    paged attention kernels (``layers.paged_attn_decode`` /
    ``layers.paged_attn_prefill_chunk``) gather/scatter through it, returning
    updated page buffers that the host writes back.
  * ``release(slot)`` returns the slot's pages to the free list, so the next
    queued request can be admitted mid-flight without reallocating anything —
    the continuous-batching analogue of the engine's expert-slot eviction.

``ChunkedPrefill`` is the shared admission driver: it feeds prompts through
``model.prefill_chunk_paged`` in fixed-size chunks (one *batched* jitted call
per chunk covering every request currently being admitted) so long prompts
never stall in-flight decodes.  Both ``DenseBackend`` and the
``OffloadEngine`` use it.

See ``docs/ARCHITECTURE.md`` for how this fits the request lifecycle.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagePoolExhausted(RuntimeError):
    """Raised when a page allocation or reservation cannot be satisfied.

    Admission-time callers (the batching scheduler) treat this as "the
    request must wait for pages"; hitting it *mid-decode* indicates the
    caller admitted a request without reserving its full length."""


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages needed to hold `tokens` KV entries."""
    # `tokens` is always a host int (static at trace time when this runs
    # under jit via init_cache), so int() here never blocks on a device value
    return -(-int(tokens) // page_size) if tokens > 0 else 0  # analysis: ignore[host-sync-in-jit]


class PagedKVPool:
    """Fixed device-resident KV page pool with per-slot page tables.

    The pool is sized once (``num_pages`` pages of ``page_size`` tokens per
    layer); serving slots draw pages on demand and return them on release.
    All metadata lives on the host (plain python/numpy — allocation is a
    per-token-batch, not per-element, operation); only the page buffers and
    the exported page table touch the device.
    """

    def __init__(self, *, num_layers: int, num_kv_heads: int, head_dim: int,
                 dtype, num_pages: int, page_size: int = 64,
                 max_pages_per_slot: int = 0):
        """max_pages_per_slot bounds one slot's logical length (defaults to
        the whole pool); it is the width of the exported page table."""
        self.num_layers = num_layers
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.max_pages_per_slot = int(max_pages_per_slot or num_pages)
        self.k: List[jax.Array] = [
            jnp.zeros((num_pages, page_size, num_kv_heads, head_dim), dtype)
            for _ in range(num_layers)]
        self.v: List[jax.Array] = [
            jnp.zeros((num_pages, page_size, num_kv_heads, head_dim), dtype)
            for _ in range(num_layers)]
        self.batch = 0
        self.free: List[int] = list(range(self.num_pages))
        self.table = np.zeros((0, self.max_pages_per_slot), np.int32)
        self.owned: List[List[int]] = []
        self.lens = np.zeros((0,), np.int64)
        self.reserved = np.zeros((0,), np.int64)   # pages promised, not drawn
        self._table_dev = None

    # ------------- batch lifecycle -------------
    def start(self, batch: int):
        """Reset metadata for a new batch of `batch` slots (buffers are
        reused; stale page contents are dead because reads are masked by
        each slot's position)."""
        self.batch = batch
        self.free = list(range(self.num_pages))
        self.table = np.zeros((batch, self.max_pages_per_slot), np.int32)
        self.owned = [[] for _ in range(batch)]
        self.lens = np.zeros((batch,), np.int64)
        self.reserved = np.zeros((batch,), np.int64)
        self._table_dev = None

    # ------------- admission reservations -------------
    def reservable_pages(self) -> int:
        """Pages available to NEW admissions: free pages minus pages already
        promised to in-flight slots' future growth."""
        return len(self.free) - int(self.reserved.sum())

    def fits(self, tokens: int) -> bool:
        """True iff a request of `tokens` total KV entries can EVER be
        served by this pool (page-table width and pool size); False means
        waiting will not help — reject, don't queue."""
        need = pages_for(tokens, self.page_size)
        return need <= min(self.max_pages_per_slot, self.num_pages)

    def can_reserve(self, tokens: int) -> bool:
        """True iff a request needing `tokens` total KV entries can be
        admitted now without ever starving an already-admitted slot (False
        for requests that exceed the per-slot table width or the pool —
        those can never be admitted; see `fits`)."""
        if not self.fits(tokens):
            return False
        return pages_for(tokens, self.page_size) <= self.reservable_pages()

    def reserve(self, slot: int, tokens: int):
        """Promise `tokens` total KV entries to `slot` (its prompt plus its
        decode budget).  Raises PagePoolExhausted if the promise cannot be
        kept, and ValueError if it exceeds the slot's page-table width."""
        need = pages_for(tokens, self.page_size)
        if need > self.max_pages_per_slot:
            raise ValueError(
                f"request needs {need} pages > max_pages_per_slot="
                f"{self.max_pages_per_slot} (max_len bound)")
        if need > self.num_pages:
            raise PagePoolExhausted(
                f"request needs {need} pages > pool size {self.num_pages}")
        extra = need - len(self.owned[slot])
        if extra > self.reservable_pages() + int(self.reserved[slot]):
            raise PagePoolExhausted(
                f"slot {slot}: {extra} pages wanted, "
                f"{self.reservable_pages()} reservable")
        self.reserved[slot] = max(int(self.reserved[slot]), extra)

    # ------------- allocation -------------
    def ensure(self, slot: int, length: int):
        """Grow `slot` to cover `length` tokens, drawing pages from the free
        list (the slot's own reservation first).  No-op if already covered.

        The draw is guarded against OTHER slots' reservations: a slot
        growing without (or past) its own reservation may only take pages
        the pool has not promised elsewhere, so the offender raises
        PagePoolExhausted here — a properly-reserved slot can never lose a
        promised page and hit exhaustion mid-decode."""
        target = pages_for(length, self.page_size)
        if target > self.max_pages_per_slot:
            raise ValueError(
                f"slot {slot}: length {length} exceeds max_pages_per_slot")
        own = self.owned[slot]
        while len(own) < target:
            promised_to_others = int(self.reserved.sum()) - int(
                self.reserved[slot])
            if not self.free or len(self.free) - promised_to_others <= 0:
                raise PagePoolExhausted(
                    f"slot {slot}: pool exhausted growing to {length} tokens "
                    f"({len(self.free)} free, {promised_to_others} promised "
                    "to other slots' reservations; admit with reserve() to "
                    "prevent this)")
            pid = self.free.pop()
            self.table[slot, len(own)] = pid
            own.append(pid)
            if self.reserved[slot] > 0:
                self.reserved[slot] -= 1
            self._table_dev = None
        self.lens[slot] = max(int(self.lens[slot]), int(length))

    def release(self, slot: int):
        """Return the slot's pages to the pool and drop its reservation —
        the next queued request can draw them immediately."""
        self.free.extend(self.owned[slot])
        self.owned[slot] = []
        self.lens[slot] = 0
        self.reserved[slot] = 0
        self._table_dev = None

    # ------------- jit-facing views -------------
    def table_device(self) -> jax.Array:
        """Page table as a device int32 (batch, max_pages_per_slot) array
        (cached until the table changes)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.table)
        return self._table_dev

    # ------------- observability -------------
    @property
    def pages_used(self) -> int:
        """Physical pages currently owned by some slot."""
        return self.num_pages - len(self.free)

    @property
    def page_fraction(self) -> float:
        """pages_used / num_pages — the pool-pressure gauge."""
        return self.pages_used / self.num_pages if self.num_pages else 0.0

    def stats(self) -> Dict[str, float]:
        """JSON-serializable pool counters (backend stats() contract keys)."""
        return {
            "kv_pages_used": self.pages_used,
            "kv_pages_total": self.num_pages,
            "kv_page_fraction": self.page_fraction,
            "kv_page_size": self.page_size,
        }


class ChunkedPrefill:
    """Incremental chunked-prefill admission driver over a ``PagedKVPool``.

    One instance per backend batch.  ``begin(slot, prompt, reserve_tokens)``
    registers a joining request (reserving its full KV budget so decode can
    never hit pool exhaustion); each ``step()`` advances EVERY pending
    admission by one fixed-size chunk through a single shared jitted call to
    ``model.prefill_chunk_paged`` and returns the last-token logits of the
    requests whose prompt completed.  The batching scheduler interleaves
    ``step()`` with decode steps so long prompts never stall in-flight
    decodes; ``run(slot, prompt, ...)`` is the blocking convenience loop used
    by the protocol-level ``join``.
    """

    def __init__(self, model, params, pool: PagedKVPool, *, chunk: int = 64,
                 jit: bool = True):
        """chunk: tokens fed per step per request (the jit compiles once per
        (pending_rows, chunk) shape)."""
        self.model = model
        self.params = params
        self.pool = pool
        self.chunk = int(chunk)
        # donate the page buffers: the pool is rebound to the outputs right
        # after the call, so XLA may update pages in place instead of
        # holding input+output pools alive (2x KV footprint)
        self._fn = (jax.jit(model.prefill_chunk_paged, donate_argnums=(1, 2))
                    if jit else model.prefill_chunk_paged)
        self._pending: Dict[int, Tuple[np.ndarray, int]] = {}  # slot->(p,fed)
        self._unclaimed: Dict[int, np.ndarray] = {}  # finished during run()

    def begin(self, slot: int, prompt, reserve_tokens: Optional[int] = None):
        """Register `prompt` for admission into `slot`, reserving
        `reserve_tokens` total KV entries (default: the prompt alone)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert len(prompt) > 0, "empty prompt"
        assert slot not in self._pending, f"slot {slot} already admitting"
        self.pool.reserve(slot, int(reserve_tokens or len(prompt)))
        self._pending[slot] = (prompt, 0)

    @property
    def pending_slots(self) -> List[int]:
        """Slots with an admission in progress (sorted)."""
        return sorted(self._pending)

    def step(self) -> Dict[int, np.ndarray]:
        """Feed one chunk for every pending admission in ONE jitted call.
        Returns {slot: last-token logits (V,)} for prompts that completed
        (callers then flip the slot active and set its position)."""
        finished: Dict[int, np.ndarray] = dict(self._unclaimed)
        self._unclaimed = {}
        if not self._pending:
            return finished
        slots = self.pending_slots
        c = self.chunk
        toks = np.zeros((len(slots), c), np.int32)
        starts = np.zeros((len(slots),), np.int32)
        ns = np.zeros((len(slots),), np.int32)
        for i, s in enumerate(slots):
            prompt, fed = self._pending[s]
            n = min(c, len(prompt) - fed)
            toks[i, :n] = prompt[fed : fed + n]
            starts[i], ns[i] = fed, n
            self.pool.ensure(s, fed + n)
        table_rows = jnp.asarray(self.pool.table[slots])
        lg, ks, vs = self._fn(self.params, self.pool.k, self.pool.v,
                              table_rows, jnp.asarray(toks),
                              jnp.asarray(starts), jnp.asarray(ns))
        self.pool.k, self.pool.v = list(ks), list(vs)
        lg = np.asarray(lg, np.float32)
        for i, s in enumerate(slots):
            prompt, fed = self._pending[s]
            fed += int(ns[i])
            if fed >= len(prompt):
                del self._pending[s]
                finished[s] = lg[i]
            else:
                self._pending[s] = (prompt, fed)
        return finished

    def run(self, slot: int, prompt,
            reserve_tokens: Optional[int] = None) -> np.ndarray:
        """Blocking admission: begin + step until `slot` finishes.  Other
        pending admissions advance alongside (shared chunks)."""
        self.begin(slot, prompt, reserve_tokens)
        while True:
            done = self.step()
            if slot in done:
                # logits of OTHER admissions that completed during this loop
                # stay claimable by the next step() call
                self._unclaimed.update(
                    {s: l for s, l in done.items() if s != slot})
                return done[slot]
            self._unclaimed.update(done)
