"""Layer library: norms, RoPE, attention variants (GQA / sliding-window /
chunked / MLA / softcap), FFN variants.  Pure functions over param dicts.

Conventions:
  activations  (B, S, D), compute dtype = cfg dtype (bf16), fp32 reductions
  attention    q/k/v as (B, S, H, hd); GQA without materializing repeats
  decode       S=1 query against a (B, Smax, ...) cache + a (B,) position vec
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import shard_utils
from repro.quant.quantize import QTensor

NEG_INF = -1e30


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.zeros((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * (1.0 + p["scale"]) + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * (1.0 + p["scale"])
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps):
    """qk-norm over the head dim (gemma3)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(ms + eps)) * (1.0 + scale)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, d: int):
    pos = np.arange(max_len)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# --------------------------------------------------------------------------
# masks
# --------------------------------------------------------------------------

def make_mask(q_pos, k_pos, kind: str, window: int):
    """Boolean (..., Sq, Sk) mask: True = attendable.  q_pos/k_pos: int32 arrays
    broadcastable to (..., Sq) / (..., Sk)."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    causal = dk <= dq
    if kind == "attn":
        return causal
    if kind == "attn_local":
        return causal & (dq - dk < window)
    if kind == "attn_chunked":
        return causal & (dq // window == dk // window)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# core attention math
# --------------------------------------------------------------------------

def _softcap(x, cap: float):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def _score_spec(b: int, hkv: int, sq: int, sk: int):
    """Sharding cascade for the (B, Hkv, g, Sq, Sk) score tensor: prefer
    kv-head sharding; fall back to query-dim, then key-dim (context
    parallel — XLA psums the softmax statistics) when heads don't divide
    the model axis (e.g. 40 q-heads / 6 whisper heads on a 16-way axis).
    When the batch itself can't shard (long_500k's B=1), the key dim takes
    every mesh axis to match the context-parallel KV cache layout."""
    mdl = shard_utils.axis_size("model")
    dp = shard_utils.dp_size()
    if mdl * dp <= 1:
        return (None,) * 5
    if b % max(dp, 1) != 0:
        if sk % (dp * mdl) == 0:
            return (None, None, None, None, "all")
        if sk % mdl == 0:
            return (None, None, None, None, "model")
        return (None,) * 5
    if hkv % mdl == 0:
        return ("batch", "model", None, None, None)
    if sq % mdl == 0:
        return ("batch", None, None, "model", None)
    if sk % mdl == 0:
        return ("batch", None, None, None, "model")
    return ("batch", None, None, None, None)


def mha(q, k, v, mask, softcap: float, scale: float):
    """q: (B,Sq,Hq,hd) k/v: (B,Sk,Hkv,hd); GQA grouped einsum, fp32 softmax."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = shard_utils.constrain(logits, *_score_spec(b, hkv, sq, k.shape[1]))
    logits = _softcap(logits, softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def chunked_mha(q, k, v, q_pos, k_pos, kind, window, softcap, scale,
                q_chunk: int = 1024):
    """Query-chunked attention: bounds the live score tensor to (qc, Sk).
    Used for long prefill/train sequences; numerically identical to mha."""
    b, sq, hq, hd = q.shape
    if sq <= q_chunk:
        mask = make_mask(q_pos, k_pos, kind, window)
        return mha(q, k, v, mask, softcap, scale)
    nc, rem = divmod(sq, q_chunk)
    main = nc * q_chunk

    def body(carry, xs):
        qc, qpc = xs  # (b, qc, hq, hd), (b, qc)
        mask = make_mask(qpc, k_pos, kind, window)
        return carry, mha(qc, k, v, mask, softcap, scale)

    qs = q[:, :main].reshape(b, nc, q_chunk, hq, hd).transpose(1, 0, 2, 3, 4)
    qps = q_pos[:, :main].reshape(b, nc, q_chunk).transpose(1, 0, 2)
    _, outs = jax.lax.scan(body, None, (qs, qps))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, main, hq, hd)
    if rem:  # tail queries (e.g. a vision-prefix remainder)
        mask = make_mask(q_pos[:, main:], k_pos, kind, window)
        tail = mha(q[:, main:], k, v, mask, softcap, scale)
        out = jnp.concatenate([out, tail], axis=1)
    return out


# --------------------------------------------------------------------------
# GQA attention layer (full-seq + decode)
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = split_keys(key, 4)
    kv_d = cfg.encoder.d_model if (cross and cfg.encoder) else d
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), _dt(cfg)),
        "wk": dense_init(ks[1], (kv_d, hkv * hd), _dt(cfg)),
        "wv": dense_init(ks[2], (kv_d, hkv * hd), _dt(cfg)),
        "wo": dense_init(ks[3], (hq * hd, d), _dt(cfg)),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def attn_forward(p, x, positions, cfg: ModelConfig, kind: str,
                 use_rope: bool = True):
    """Full-sequence self attention (train / prefill). Returns (out, (k, v))."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # cache stores unexpanded GQA heads, sharded to match cache_shardings
    # (heads over model when divisible, else sequence over model)
    mdl = shard_utils.axis_size("model")
    kv_head_ax = "model" if hkv % max(mdl, 1) == 0 else None
    kv_seq_ax = None if kv_head_ax else "model"
    kv_out = (shard_utils.constrain(k, "batch", kv_seq_ax, kv_head_ax, None),
              shard_utils.constrain(v, "batch", kv_seq_ax, kv_head_ax, None))
    # Tensor-parallel layout: if the kv heads don't divide the model axis but
    # the q heads do, expand kv to q heads so attention shards cleanly
    # (standard TP practice; kv tensors are small relative to scores).
    if mdl > 1 and hkv % mdl != 0 and hq % mdl == 0:
        g = hq // hkv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    hkv_eff = k.shape[2]
    h_ok = hkv_eff % max(mdl, 1) == 0
    q = shard_utils.constrain(q, "batch", None, "model" if hq % max(mdl, 1) == 0 else None, None)
    k = shard_utils.constrain(k, "batch", None if h_ok else "model",
                              "model" if h_ok else None, None)
    v = shard_utils.constrain(v, "batch", None if h_ok else "model",
                              "model" if h_ok else None, None)
    scale = 1.0 / np.sqrt(hd)
    out = chunked_mha(q, k, v, positions, positions, kind, cfg.window_size,
                      cfg.attn_logit_softcap, scale)
    out = shard_utils.constrain(out, "batch", None,
                                "model" if hq % max(mdl, 1) == 0 else None, None)
    return out.reshape(b, s, hq * hd) @ p["wo"], kv_out


def attn_decode(p, x, kv_cache, positions, cfg: ModelConfig, kind: str,
                use_rope: bool = True):
    """One-token decode.  x: (B,1,D); kv_cache: dict(k=(B,Smax,Hkv,hd), v=...).
    positions: (B,) current write index.  Returns (out, new_cache).

    Sliding-window / chunked layers use a RING cache of `window` slots
    (production KV sizing: a 1024-window gemma3 layer never needs a 32k
    cache); slot = pos % Smax, and the absolute position of slot j is
    recovered as pos - ((pos - j) mod Smax)."""
    b, s1, d = x.shape
    assert s1 == 1
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, 1, hq, hd)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k = apply_rope(k, positions[:, None], cfg.rope_theta)
    smax = kv_cache["k"].shape[1]
    ring = kind != "attn" and smax <= cfg.window_size
    slots = positions % smax if ring else positions
    ck = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0)))(
        kv_cache["k"], k, slots)
    cv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0)))(
        kv_cache["v"], v, slots)
    idx = jnp.arange(smax, dtype=jnp.int32)[None, :]
    if ring:
        k_pos = positions[:, None] - ((positions[:, None] - idx) % smax)
        valid = (k_pos >= 0)[:, None, :]          # (B, 1, Smax)
    else:
        k_pos = idx
        valid = jnp.ones((1, 1, smax), bool)
    mask = make_mask(positions[:, None], k_pos, kind, cfg.window_size) & valid
    scale = 1.0 / np.sqrt(hd)
    out = mha(q, ck, cv, mask, cfg.attn_logit_softcap, scale)
    return out.reshape(b, 1, hq * hd) @ p["wo"], {"k": ck, "v": cv}


def _paged_qkv(p, x, cfg: ModelConfig, positions):
    """Shared q/k/v projection + qk-norm + RoPE for the paged attention
    paths.  x: (B, S, D); positions: (B, S) absolute token positions."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _paged_gather(pages, table):
    """Gather a slot-contiguous logical KV view from the shared page pool.
    pages: (P, psz, Hkv, hd); table: (B, maxp) physical page ids.  Returns
    (B, maxp*psz, Hkv, hd) — logical token t of slot b lives at row
    table[b, t // psz], offset t % psz, so the reshape restores token
    order.  Junk rows (stale/unallocated pages) are masked by position in
    the caller's attention mask, never read."""
    b, maxp = table.shape
    _, psz, hkv, hd = pages.shape
    return pages[table].reshape(b, maxp * psz, hkv, hd)


def paged_attn_decode(p, x, k_pages, v_pages, table, positions, active,
                      cfg: ModelConfig):
    """One-token decode against a paged KV pool (full "attn" layers only).

    x: (B, 1, D); k_pages/v_pages: (P, psz, Hkv, hd) SHARED across slots;
    table: (B, maxp) page table; positions: (B,) write index; active: (B,)
    bool — inactive slots' writes are DROPPED (their table rows may point at
    pages now owned by another slot, so a junk write would corrupt a
    neighbour).  Returns (out (B,1,Hq*hd @ wo), new_k_pages, new_v_pages).

    Attention runs through `kops.paged_flash_decode`: the page table drives
    the kernel's K/V index maps, so no (B, maxp*psz) dense gathered cache
    view is ever materialized (`_paged_gather` remains the prefill/oracle
    path only).  Every slot attends over tokens [0, position] — inactive
    slots attend over junk exactly as the gathered path did; their outputs
    are garbage the caller masks out."""
    b = x.shape[0]
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    q, k, v = _paged_qkv(p, x, cfg, positions[:, None])
    psz = k_pages.shape[1]
    page = jnp.take_along_axis(table, (positions // psz)[:, None], axis=1)[:, 0]
    page = jnp.where(active, page, k_pages.shape[0])      # OOB -> dropped
    off = positions % psz
    k_pages = k_pages.at[page, off].set(k[:, 0].astype(k_pages.dtype),
                                        mode="drop")
    v_pages = v_pages.at[page, off].set(v[:, 0].astype(v_pages.dtype),
                                        mode="drop")
    out = kops.paged_flash_decode(q[:, 0], k_pages, v_pages, table,
                                  positions + 1, scale=1.0 / np.sqrt(hd),
                                  softcap=cfg.attn_logit_softcap)
    out = out.astype(q.dtype)
    return out.reshape(b, 1, hq * hd) @ p["wo"], k_pages, v_pages


def paged_attn_prefill_chunk(p, x, k_pages, v_pages, table, start, n,
                             cfg: ModelConfig, wstart=None):
    """One prefill chunk against a paged KV pool: write the chunk's K/V into
    the slot's pages, then attend causally over everything written so far
    (earlier chunks + this one).

    x: (B, C, D) chunk activations (rows may belong to different requests
    being admitted together); start: (B,) absolute position of each row's
    first token; n: (B,) valid tokens in the row (n < C pads the final
    chunk — pad positions write nothing and their outputs are garbage the
    caller masks out); wstart: optional (B,) write floor — positions below
    it attend over the (aliased, already-written) pages but drop their own
    K/V writes, so prefix-sharing re-feeds never touch shared pages.
    Returns (out (B,C,D'), new_k_pages, new_v_pages)."""
    b, c, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    q, k, v = _paged_qkv(p, x, cfg, positions)
    psz = k_pages.shape[1]
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < n[:, None]   # (B, C)
    write_ok = valid if wstart is None else (
        valid & (positions >= wstart[:, None]))
    page = jnp.take_along_axis(table, positions // psz, axis=1)
    page = jnp.where(write_ok, page, k_pages.shape[0])    # pads/refeeds drop
    off = positions % psz
    k_pages = k_pages.at[page.reshape(-1), off.reshape(-1)].set(
        k.reshape(b * c, hkv, hd).astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[page.reshape(-1), off.reshape(-1)].set(
        v.reshape(b * c, hkv, hd).astype(v_pages.dtype), mode="drop")
    kg = _paged_gather(k_pages, table)
    vg = _paged_gather(v_pages, table)
    idx = jnp.arange(kg.shape[1], dtype=jnp.int32)[None, None, :]
    # causal: for valid q rows every key <= q_pos was written (earlier
    # chunks or this one); pad rows attend to junk but are masked downstream
    mask = idx <= positions[:, :, None]                   # (B, C, Smax)
    out = mha(q, kg, vg, mask, cfg.attn_logit_softcap, 1.0 / np.sqrt(hd))
    return out.reshape(b, c, hq * hd) @ p["wo"], k_pages, v_pages


def cross_attn_forward(p, x, enc_kv, cfg: ModelConfig):
    """Cross attention into precomputed encoder K/V (whisper decoder)."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k, v = enc_kv  # (B, Senc, Hkv, hd)
    mask = jnp.ones((b, s, k.shape[1]), bool)
    out = mha(q, k, v, mask, 0.0, 1.0 / np.sqrt(hd))
    return out.reshape(b, s, hq * hd) @ p["wo"]


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# --------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = split_keys(key, 5)
    return {
        "wq": dense_init(ks[0], (d, h * (m.nope_head_dim + m.rope_head_dim)), _dt(cfg)),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank + m.rope_head_dim), _dt(cfg)),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, h * m.nope_head_dim), _dt(cfg)),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, h * m.v_head_dim), _dt(cfg)),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d), _dt(cfg)),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
    }


def _mla_split_q(p, x, cfg):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q = (x @ p["wq"]).reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    return q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]


def _mla_compress_kv(p, x, positions, cfg):
    """Returns (c_kv normalized (B,S,R), k_rope (B,S,1,rope_hd))."""
    m = cfg.mla
    dkv = x @ p["w_dkv"]                                   # (B,S,R+rope)
    c_kv, k_rope = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    # rmsnorm on the latent (deepseek applies a norm before up-projection)
    cf = c_kv.astype(jnp.float32)
    cf = cf * jax.lax.rsqrt(jnp.mean(jnp.square(cf), -1, keepdims=True) + cfg.norm_eps)
    c_kv = (cf * (1.0 + p["kv_norm"])).astype(x.dtype)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_forward(p, x, positions, cfg: ModelConfig, q_chunk: int = 1024):
    """Full-seq MLA (train/prefill): up-project latent to K/V per head,
    query-chunked so the live score tensor is bounded to (qc, S).
    Returns (out, (c_kv, k_rope)) — the compressed cache."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _mla_split_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _mla_compress_kv(p, x, positions, cfg)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, m.nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, m.v_head_dim)
    mdl = shard_utils.axis_size("model")
    head_ax = "model" if h % max(mdl, 1) == 0 else None
    q_nope = shard_utils.constrain(q_nope, "batch", None, head_ax, None)
    k_nope = shard_utils.constrain(k_nope, "batch", None, head_ax, None)
    v = shard_utils.constrain(v, "batch", None, head_ax, None)
    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    k_rope_f = k_rope[:, :, 0].astype(jnp.float32)

    def attend(qn, qr, qpos):
        logits = (jnp.einsum("bqhd,bkhd->bhqk", qn.astype(jnp.float32),
                             k_nope.astype(jnp.float32))
                  + jnp.einsum("bqhd,bkd->bhqk", qr.astype(jnp.float32),
                               k_rope_f)) * scale
        logits = shard_utils.constrain(logits, "batch", head_ax, None, None)
        mask = make_mask(qpos, positions, "attn", 0)
        logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)

    if s <= q_chunk:
        out = attend(q_nope, q_rope, positions)
    else:
        nc, rem = divmod(s, q_chunk)
        main = nc * q_chunk

        def body(_, xs):
            qn, qr, qp = xs
            return None, attend(qn, qr, qp)

        qns = q_nope[:, :main].reshape(b, nc, q_chunk, h, -1).transpose(1, 0, 2, 3, 4)
        qrs = q_rope[:, :main].reshape(b, nc, q_chunk, h, -1).transpose(1, 0, 2, 3, 4)
        qps = positions[:, :main].reshape(b, nc, q_chunk).transpose(1, 0, 2)
        _, outs = jax.lax.scan(body, None, (qns, qrs, qps))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, main, h, m.v_head_dim)
        if rem:
            tail = attend(q_nope[:, main:], q_rope[:, main:], positions[:, main:])
            out = jnp.concatenate([out, tail], axis=1)
    out = out.reshape(b, s, h * m.v_head_dim) @ p["wo"]
    mdl_seq_ax = "model"
    c_kv = shard_utils.constrain(c_kv, "batch", mdl_seq_ax, None)
    k_rope_out = shard_utils.constrain(k_rope[:, :, 0, :], "batch", mdl_seq_ax, None)
    return out, (c_kv, k_rope_out)


def mla_decode(p, x, cache, positions, cfg: ModelConfig):
    """Absorbed-matrix MLA decode: attend directly in the R-dim latent space.
    cache: dict(c_kv=(B,Smax,R), k_rope=(B,Smax,rope_hd))."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    q_nope, q_rope = _mla_split_q(p, x, cfg)               # (B,1,H,*)
    q_rope = apply_rope(q_rope, positions[:, None], cfg.rope_theta)
    c_new, k_rope_new = _mla_compress_kv(p, x, positions[:, None], cfg)
    c_kv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0)))(
        cache["c_kv"], c_new, positions)
    k_rope = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0)))(
        cache["k_rope"], k_rope_new[:, :, 0, :], positions)
    # absorb W_uk into q: q_lat (B,1,H,R)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    logits = (jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    smax = c_kv.shape[1]
    mask = (jnp.arange(smax, dtype=jnp.int32)[None, :] <= positions[:, None])
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", w, c_kv.astype(jnp.float32))  # (B,1,H,R)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(b, 1, h * m.v_head_dim) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}


# --------------------------------------------------------------------------
# FFN variants (dense path; MoE lives in moe.py)
# --------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    if cfg.ffn_activation == "swiglu":
        wi = dense_init(k1, (d, 2 * f), _dt(cfg))
    else:
        wi = dense_init(k1, (d, f), _dt(cfg))
    return {"wi": wi, "wo": dense_init(k2, (f, d), _dt(cfg))}


def _matmul(x, w, mode="auto"):
    if isinstance(w, QTensor):
        return kops.dequant_matmul(x, w, mode=mode).astype(x.dtype)
    return x @ w


def ffn_forward(p, x, cfg: ModelConfig):
    h = _matmul(x, p["wi"])
    h = shard_utils.constrain(h, *(("batch",) + (None,) * (h.ndim - 2) + ("model",)))
    if cfg.ffn_activation == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif cfg.ffn_activation == "sq_relu":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    else:  # gelu
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return _matmul(h, p["wo"])
