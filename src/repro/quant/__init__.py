from repro.quant.quantize import (
    DEFAULT_GROUP,
    PACK_FACTOR,
    QMAX,
    QTensor,
    dequantize,
    expert_nbytes,
    pack_codes,
    quantization_error,
    quantize,
    quantize_tree,
    unpack_codes,
)

__all__ = [
    "DEFAULT_GROUP", "PACK_FACTOR", "QMAX", "QTensor", "dequantize",
    "expert_nbytes", "pack_codes", "quantization_error", "quantize",
    "quantize_tree", "unpack_codes",
]
