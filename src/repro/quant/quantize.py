"""Groupwise symmetric weight quantization: int8, packed int4 and int2.

This is the numerical substrate of HOBBIT's mixed-precision experts.  Weights are
quantized *per group along the contraction (input) dimension* with a symmetric
scale, matching llama.cpp-style k-quant block layouts in spirit:

    w[g*G + i, n]  ~=  q[g*G + i, n] * scale[g, n]

where ``G`` is the group size, ``q`` is a signed integer code and ``scale`` is
fp32 (stored bf16-able).  int4 and int2 codes are *packed* two (resp. four) per
int8 byte along the contraction dim so the in-memory footprint is the real one —
the Pallas fused dequant-matmul kernel consumes the packed layout directly.

Everything here is pure jnp and jit-friendly; QTensor is a pytree.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Number of codes packed per int8 storage byte.
PACK_FACTOR = {8: 1, 4: 2, 2: 4}
# Max magnitude representable per bit-width (symmetric, zero-point-free).
QMAX = {8: 127, 4: 7, 2: 1}

DEFAULT_GROUP = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """A groupwise-quantized 2-D (or stacked N-D) tensor.

    data:   int8 storage, shape (..., K // pack, N) — packed codes.
    scale:  fp32, shape (..., K // group, N) — one scale per group per column.
    bits / group_size / orig_k are static (aux) fields.
    """

    data: jax.Array
    scale: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    group_size: int = dataclasses.field(metadata=dict(static=True))
    orig_k: int = dataclasses.field(metadata=dict(static=True))

    @property
    def shape(self) -> Tuple[int, ...]:
        return (*self.data.shape[:-2], self.orig_k, self.data.shape[-1])

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize + self.scale.size * 2

    def astuple(self):
        return self.data, self.scale


def _check_dims(k: int, bits: int, group_size: int) -> None:
    if bits not in PACK_FACTOR:
        raise ValueError(f"unsupported bit-width {bits}; want one of {list(PACK_FACTOR)}")
    if k % group_size != 0:
        raise ValueError(f"contraction dim {k} not divisible by group size {group_size}")
    if group_size % PACK_FACTOR[bits] != 0:
        raise ValueError(f"group size {group_size} not divisible by pack factor")


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack signed integer codes (..., K, N) int8 -> (..., K//pack, N) int8.

    Codes are stored in unsigned nibble/crumb form (code + qmax offsetting is NOT
    used — we keep two's-complement in the low bits, masked on unpack)."""
    pack = PACK_FACTOR[bits]
    if pack == 1:
        return codes.astype(jnp.int8)
    *lead, k, n = codes.shape
    u = codes.astype(jnp.uint8) & ((1 << bits) - 1)
    u = u.reshape(*lead, k // pack, pack, n)
    out = jnp.zeros((*lead, k // pack, n), dtype=jnp.uint8)
    for i in range(pack):
        out = out | (u[..., i, :] << (bits * i))
    return out.astype(jnp.int8)


def unpack_codes(packed: jax.Array, bits: int) -> jax.Array:
    """Unpack (..., K//pack, N) int8 -> signed codes (..., K, N) int8."""
    pack = PACK_FACTOR[bits]
    if pack == 1:
        return packed
    *lead, kp, n = packed.shape
    u = packed.astype(jnp.uint8)
    parts = []
    mask = (1 << bits) - 1
    for i in range(pack):
        nib = (u >> (bits * i)) & mask
        # sign-extend: values >= 2^(bits-1) are negative.
        signed = jnp.where(nib >= (1 << (bits - 1)), nib.astype(jnp.int16) - (1 << bits), nib.astype(jnp.int16))
        parts.append(signed.astype(jnp.int8))
    out = jnp.stack(parts, axis=-2)  # (..., kp, pack, n)
    return out.reshape(*lead, kp * pack, n)


@partial(jax.jit, static_argnames=("bits", "group_size"))
def quantize(w: jax.Array, bits: int = 8, group_size: int = DEFAULT_GROUP) -> QTensor:
    """Groupwise symmetric quantization along dim -2 (the contraction dim)."""
    *lead, k, n = w.shape
    _check_dims(k, bits, group_size)
    g = k // group_size
    wg = w.astype(jnp.float32).reshape(*lead, g, group_size, n)
    if bits == 2:
        # Ternary (TWN-style): threshold at 0.7*mean|w|, scale = mean |w| above it.
        # Far lower MSE than amax/1 scaling for Gaussian-ish weights.
        absw = jnp.abs(wg)
        delta = 0.7 * jnp.mean(absw, axis=-2, keepdims=True)
        mask = absw > delta
        scale = jnp.sum(absw * mask, axis=-2) / jnp.maximum(jnp.sum(mask, axis=-2), 1)
    else:
        amax = jnp.max(jnp.abs(wg), axis=-2)  # (..., g, n)
        scale = amax / QMAX[bits]
    scale = jnp.where(scale == 0.0, 1.0, scale)
    codes = jnp.clip(jnp.round(wg / scale[..., :, None, :]), -QMAX[bits], QMAX[bits]).astype(jnp.int8)
    codes = codes.reshape(*lead, k, n)
    return QTensor(data=pack_codes(codes, bits), scale=scale, bits=bits, group_size=group_size, orig_k=k)


@partial(jax.jit, static_argnames=("dtype",))
def dequantize(q: QTensor, dtype=jnp.float32) -> jax.Array:
    """Reconstruct the (approximate) dense weight."""
    codes = unpack_codes(q.data, q.bits).astype(jnp.float32)
    *lead, k, n = codes.shape
    g = k // q.group_size
    codes = codes.reshape(*lead, g, q.group_size, n)
    w = codes * q.scale[..., :, None, :]
    return w.reshape(*lead, k, n).astype(dtype)


def quantize_tree(tree, bits: int = 8, group_size: int = DEFAULT_GROUP, predicate=None):
    """Quantize every >=2-D float leaf of a pytree (optionally filtered by path)."""

    def _q(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)) or leaf.ndim < 2:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if predicate is not None and not predicate(path, leaf):
            return leaf
        k = leaf.shape[-2]
        if k % group_size != 0:
            return leaf
        return quantize(jnp.asarray(leaf), bits=bits, group_size=group_size)

    return jax.tree_util.tree_map_with_path(_q, tree)


def quantization_error(w: jax.Array, bits: int, group_size: int = DEFAULT_GROUP) -> float:
    """Relative Frobenius reconstruction error (for tests / calibration)."""
    q = quantize(w, bits=bits, group_size=group_size)
    wr = dequantize(q)
    num = jnp.linalg.norm(w.astype(jnp.float32) - wr)
    den = jnp.linalg.norm(w.astype(jnp.float32)) + 1e-12
    return float(num / den)


def expert_nbytes(d_model: int, d_ff: int, bits: int, n_matrices: int = 3,
                  group_size: int = DEFAULT_GROUP) -> int:
    """Bytes to store one (SwiGLU) expert at a given precision — the quantity that
    drives HOBBIT's loading-cost model.  bits=16 means bf16 dense."""
    params = n_matrices * d_model * d_ff
    if bits == 16:
        return params * 2
    scales = params // group_size
    return params * bits // 8 + scales * 2
