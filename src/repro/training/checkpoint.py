"""Checkpointing: pytree <-> directory of .npz shards + JSON manifest.

No orbax dependency; handles arbitrary nested dict/list/tuple/NamedTuple
pytrees of jax/numpy arrays, preserves dtypes (incl. bfloat16 via a uint16
view), and is resumable (save step, restore into the same treedef).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "__bf16__"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(ckpt_dir: str, tree: Any, step: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    meta = {"step": step, "leaves": {}}
    for i, (path, leaf) in enumerate(flat):
        key = f"a{i}"
        arr = np.asarray(leaf)
        entry = {"path": _path_str(path), "dtype": str(arr.dtype)}
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            entry["dtype"] = _BF16
        arrays[key] = arr
        meta["leaves"][key] = entry
    np.savez(os.path.join(ckpt_dir, f"step_{step}.npz"), **arrays)
    with open(os.path.join(ckpt_dir, f"step_{step}.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(ckpt_dir, "latest"), "w") as f:
        f.write(str(step))
    return os.path.join(ckpt_dir, f"step_{step}.npz")


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, like: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of `like` (shape/dtype validated)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"step_{step}.npz"))
    with open(os.path.join(ckpt_dir, f"step_{step}.json")) as f:
        meta = json.load(f)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    restored = []
    for i, leaf in enumerate(leaves_like):
        key = f"a{i}"
        arr = data[key]
        if meta["leaves"][key]["dtype"] == _BF16:
            arr = arr.view(jnp.bfloat16)
        want = np.asarray(leaf)
        if arr.shape != want.shape:
            raise ValueError(
                f"leaf {meta['leaves'][key]['path']}: shape {arr.shape} != {want.shape}")
        restored.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored), step
