"""Training step + loop: grad, clip, AdamW update, metrics."""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Batch, Model
from repro.training import optimizer as opt_lib
from repro.training.optimizer import OptimizerConfig, OptState


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_train_step(model: Model, ocfg: OptimizerConfig, *,
                    remat: bool = True, microbatches: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). jit-able /
    pjit-able (this is what the multi-pod dry-run lowers for train_4k).

    microbatches > 1 enables gradient accumulation: the global batch is
    split on the batch axis and scanned, bounding the live remat-residual
    stack (and its fp32 shadow that XLA hoists out of the backward loop) to
    one microbatch's worth.  Numerically equivalent to the monolithic step
    up to fp32 summation order."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=remat)
        return loss, metrics

    def train_step(state: TrainState, batch: Batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            mb = microbatches
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch)

            def body(acc, mbatch):
                gsum, lsum = acc
                (l, met), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mbatch)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), met

            acc_dt = jnp.dtype(ocfg.moment_dtype)
            # derive from params (not jnp.zeros) so the accumulator inherits
            # the params' sharding — a fresh zeros carry gets data-replicated
            # by the partitioner (+28 GB/chip at DeepSeek scale)
            gz = jax.tree_util.tree_map(
                lambda p: (p * 0).astype(acc_dt), state.params)
            (gsum, lsum), mets = jax.lax.scan(body, (gz, jnp.zeros(())), micro)
            grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
            loss = lsum / mb
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m), mets)
        new_params, new_opt, om = opt_lib.apply_updates(
            ocfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch: Batch):
        loss, metrics = model.loss(params, batch, remat=False)
        return metrics["nll"]

    return eval_step


def init_state(model: Model, seed: int = 0) -> TrainState:
    params = model.init(jax.random.PRNGKey(seed))
    return TrainState(params, opt_lib.init_opt_state(params))


def train(model: Model, ocfg: OptimizerConfig, data_iter, steps: int, *,
          log_every: int = 20, eval_fn: Optional[Callable] = None,
          state: Optional[TrainState] = None, jit: bool = True,
          log: Callable = print) -> tuple[TrainState, list[Dict[str, float]]]:
    """Single-host training loop (examples / tests / accuracy benchmarks)."""
    state = state or init_state(model)
    step_fn = make_train_step(model, ocfg)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=0)
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = time.time() - t0
            if eval_fn is not None:
                m["eval_nll"] = float(eval_fn(state.params))
            history.append(m)
            log(f"step {i:5d} loss={m['loss']:.4f} nll={m['nll']:.4f} "
                f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e}")
    return state, history
