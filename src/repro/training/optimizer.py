"""AdamW + cosine schedule + global-norm clipping, hand-rolled (no optax).

Moments are fp32 regardless of param dtype; the update is computed in fp32
and cast back (bf16 params train stably this way at these scales).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # Moment dtype: fp32 default; bf16 halves optimizer HBM for >50B models
    # (standard large-scale practice; update math still runs in fp32).
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_at(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, moment_dtype="float32") -> OptState:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dt), p)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def _is_matrix(x):
    return x.ndim >= 2  # decay only matrices (norms/biases/scalars exempt)


def apply_updates(cfg: OptimizerConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)
    # In bf16-moment mode the whole update runs in bf16: a TPU compile fuses
    # the fp32-upcast chain into one elementwise pass either way, but the
    # CPU-backend buffer assignment (our dry-run memory proof) materializes
    # every cast — 3 full fp32 copies of a 236B tree.  The bf16 update loses
    # ~3 bits of moment precision (stochastic rounding would recover it);
    # fp32 moments remain the default for real (small-scale) training runs.
    cdt = jnp.float32 if mdt == jnp.float32 else jnp.bfloat16

    def upd(p, g, m, v):
        g = g.astype(cdt) * scale.astype(cdt)
        m = (cfg.b1 * m.astype(cdt) + (1 - cfg.b1) * g).astype(mdt)
        v = (cfg.b2 * v.astype(cdt) + (1 - cfg.b2) * jnp.square(g)).astype(mdt)
        mhat = m.astype(cdt) / b1c.astype(cdt)
        vhat = v.astype(cdt) / b2c.astype(cdt)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(cdt)
        newp = (p.astype(cdt) - lr.astype(cdt) * delta).astype(p.dtype)
        return newp, m, v

    flat = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}
