"""End-to-end serving driver (the paper's deployment scenario): one
continuous-batching scheduler serving the *same* mixed-length request
workload through both backends of the unified `InferenceBackend` API —
resident dense weights and the HOBBIT mixed-precision offload engine —
plus a simulated edge-hardware latency report for the offload path.

    PYTHONPATH=src python examples/offload_serving.py
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine
from repro.core.simulator import JETSON_ORIN, RTX4090, HobbitSimConfig, simulate_systems
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.quant.quantize import expert_nbytes
from repro.serving.api import DenseBackend, HobbitBackend
from repro.serving.batching import BatchingServer, Request
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import train


def make_requests(rng):
    """The paper's workload shape: short (16) and long (128) prompts with
    mixed completion lengths, more requests than scheduler slots."""
    reqs = []
    for i in range(8):
        plen = 16 if i < 4 else 128
        reqs.append(Request(rid=i, prompt=rng.integers(0, 512, plen),
                            max_new_tokens=16 + 16 * (i % 2)))
    return reqs


def serve(backend, reqs):
    srv = BatchingServer(backend, max_batch=4, max_len=196)
    for r in reqs:
        srv.submit(r)
    srv.run()
    return srv


def main():
    cfg = smoke_variant(get_config("phi-moe"), layers=4, d_model=128, vocab=512)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    dc = DataConfig(vocab_size=512, seq_len=64, batch_size=16)
    state, _ = train(model, OptimizerConfig(lr=1e-3, warmup_steps=20,
                                            total_steps=120),
                     batches(dc), 120, log_every=60)

    # ---- identical scheduler code path, identical workload, two backends
    # (fresh rng per backend so both serve the same prompts)
    srv = serve(DenseBackend(model, state.params),
                make_requests(np.random.default_rng(0)))
    print("dense backend   :", srv.stats())

    eng = OffloadEngine(model, state.params, EngineConfig(hi_slots=20,
                                                          lo_slots=12))
    srv = serve(HobbitBackend(eng), make_requests(np.random.default_rng(0)))
    print("hobbit backend  :", srv.stats())
    mid_flight = [e for e in srv.events if e[0] == "join" and e[3] > 0]
    print(f"mid-flight admissions: {len(mid_flight)} "
          f"(slots freed and refilled while neighbours kept decoding)")

    # ---- edge-hardware latency simulation from the offload run's trace ----
    full = get_config("phi-moe")
    sim_cfg = HobbitSimConfig(
        hi_slots=20, lo_slots=12,
        hi_bytes=expert_nbytes(full.d_model, full.moe.d_ff_expert, 16),
        lo_bytes=expert_nbytes(full.d_model, full.moe.d_ff_expert, 4))
    for hw in (RTX4090, JETSON_ORIN):
        res = simulate_systems(eng.trace, eng.num_moe_layers, hw, sim_cfg)
        print(f"simulated decode tok/s on {hw.name}: "
              + ", ".join(f"{k}={v['tok_per_s']:.1f}" for k, v in res.items()))


if __name__ == "__main__":
    main()
