"""End-to-end serving driver (the paper's deployment scenario): batched
requests against a small trained MoE served two ways — the resident path
with continuous bucket batching, and the HOBBIT offload engine with a
simulated edge-hardware latency report.

    PYTHONPATH=src python examples/offload_serving.py
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine
from repro.core.simulator import JETSON_ORIN, RTX4090, HobbitSimConfig, simulate_systems
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.quant.quantize import expert_nbytes
from repro.serving.batching import BatchingServer, Request
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import train


def main():
    cfg = smoke_variant(get_config("phi-moe"), layers=4, d_model=128, vocab=512)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    dc = DataConfig(vocab_size=512, seq_len=64, batch_size=16)
    state, _ = train(model, OptimizerConfig(lr=1e-3, warmup_steps=20,
                                            total_steps=120),
                     batches(dc), 120, log_every=60)

    # ---- resident path: batched requests (paper's [16,32]/[128,32] groups)
    srv = BatchingServer(model, state.params, max_batch=4, max_len=196)
    rng = np.random.default_rng(0)
    for i in range(8):
        plen = 16 if i < 4 else 128
        srv.submit(Request(rid=i, prompt=rng.integers(0, 512, plen),
                           max_new_tokens=32))
    srv.run()
    print("resident serving:", srv.stats())

    # ---- HOBBIT offload path + edge-hardware latency simulation
    eng = OffloadEngine(model, state.params, EngineConfig(hi_slots=20,
                                                          lo_slots=12))
    for i in range(2):
        eng.generate(list(rng.integers(0, 512, 16)), 32)
    full = get_config("phi-moe")
    sim_cfg = HobbitSimConfig(
        hi_slots=20, lo_slots=12,
        hi_bytes=expert_nbytes(full.d_model, full.moe.d_ff_expert, 16),
        lo_bytes=expert_nbytes(full.d_model, full.moe.d_ff_expert, 4))
    for hw in (RTX4090, JETSON_ORIN):
        res = simulate_systems(eng.trace, eng.num_moe_layers, hw, sim_cfg)
        print(f"simulated decode tok/s on {hw.name}: "
              + ", ".join(f"{k}={v['tok_per_s']:.1f}" for k, v in res.items()))


if __name__ == "__main__":
    main()
