"""Train a ~100M-parameter MoE for a few hundred steps end-to-end (the
brief's training driver), with eval, checkpointing and resume.

    PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config, smoke_variant
from repro.data.pipeline import DataConfig, batches, eval_batches, unigram_entropy
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import (init_state, make_eval_step,
                                       train)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", type=str, default="results/train_moe_ckpt")
    args = ap.parse_args()

    # ~100M params: 6 layers, d=512, 8 experts of d_ff=1024, vocab 8192
    base = smoke_variant(get_config("mixtral-8x7b"), layers=6, d_model=512,
                         vocab=8192)
    cfg = dataclasses.replace(
        base, name="moe-100m",
        moe=dataclasses.replace(base.moe, num_experts=8, d_ff_expert=1024))
    model = build_model(cfg)
    print(f"params: {cfg.param_count()/1e6:.0f}M "
          f"(active {cfg.active_param_count()/1e6:.0f}M)")

    dc = DataConfig(vocab_size=8192, seq_len=128, batch_size=8)
    ev = eval_batches(dc, 2)
    es = jax.jit(make_eval_step(model))

    state = init_state(model)
    start = 0
    if ckpt.latest_step(args.ckpt) is not None:
        state, start = ckpt.restore(args.ckpt, state)
        print(f"resumed from step {start}")

    def eval_fn(params):
        return sum(float(es(params, b)) for b in ev) / len(ev)

    ocfg = OptimizerConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    state, hist = train(model, ocfg, batches(dc, start_step=start),
                        args.steps - start, log_every=25, eval_fn=eval_fn,
                        state=state)
    ckpt.save(args.ckpt, state, step=args.steps)
    print(f"final eval nll {hist[-1]['eval_nll']:.3f} "
          f"(unigram entropy {unigram_entropy(dc):.3f})")


if __name__ == "__main__":
    main()
