"""Quickstart: build a small MoE, train it briefly, then serve it through
the HOBBIT mixed-precision offload engine and compare against full-precision
decoding.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine, Thresholds
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.serving.api import HobbitBackend, generate, score_nll
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import train


def main():
    # 1. a reduced Mixtral-family config (8 experts, top-2, 4 layers)
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=512)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M  "
          f"experts={cfg.moe.num_experts} top-{cfg.moe.top_k}")

    # 2. train briefly on the synthetic pipeline
    dc = DataConfig(vocab_size=512, seq_len=64, batch_size=16)
    state, hist = train(model, OptimizerConfig(lr=1e-3, warmup_steps=20,
                                               total_steps=150),
                        batches(dc), 150, log_every=50)

    # 3. serve through HOBBIT behind the unified serving API: expert cache
    #    smaller than the expert set, mixed-precision loads on miss, adaptive
    #    prefetch, multidim cache — with a real (dense) prefill for the prompt
    eng = OffloadEngine(model, state.params, EngineConfig(
        hi_slots=10, lo_slots=6, thresholds=Thresholds(0.6, 0.9), prefetch_p=2))
    backend = HobbitBackend(eng)
    prompt = np.asarray([[1, 42, 7, 99, 15, 3]], np.int32)
    res = generate(backend, prompt, 24)
    s = eng.stats()
    print(f"\nHOBBIT generated: {res.tokens[0, prompt.shape[1]:].tolist()}")
    print(f"cache hit ratio: {s['cache']['hit_ratio']:.2f}  "
          f"loads hi/lo/skip: {s['loads_hi']}/{s['loads_lo']}/{s['skips']}")
    print(f"next-layer prediction accuracy: {s['pred_accuracy']}")
    print(f"load stall: {s['load_stall_s']*1e3:.1f} ms  prefetch overlap: "
          f"{s['overlap_fraction']:.0%} of copy time hidden behind compute")

    # 4. accuracy impact of mixed-precision substitution, through the same
    #    serving API (the scorer decodes teacher-forced on the offload path)
    toks = np.random.default_rng(0).integers(0, 512, 32)
    full = HobbitBackend(OffloadEngine(model, state.params, EngineConfig(
        hi_slots=64, lo_slots=1, thresholds=Thresholds(1.0, 1.0),
        prefetch=False)))
    nll_full = score_nll(full, toks)
    nll_mixed = score_nll(HobbitBackend(OffloadEngine(
        model, state.params, EngineConfig(
            hi_slots=64, lo_slots=32, thresholds=Thresholds(0.6, 0.9),
            prefetch=False))), toks)
    print(f"\nNLL full-precision: {nll_full:.4f}   mixed int4: {nll_mixed:.4f} "
          f"(delta {100*(nll_mixed-nll_full)/nll_full:+.2f}% — paper: <=1%)")


if __name__ == "__main__":
    main()
