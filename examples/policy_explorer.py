"""Tune the multidimensional cache policy weights on a calibration trace
(the paper sets the four Eq. 3 weights "by minimizing the mixed precision
expert cache miss penalties on a calibration dataset" — this script does
exactly that, with a coarse simplex sweep) and sweep cache sizes.

    PYTHONPATH=src python examples/policy_explorer.py
"""

import dataclasses
import itertools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import (EngineConfig, OffloadEngine, PolicyWeights, Thresholds,
                        cache_policy_penalty)
from repro.core.policies import LFU, LRU, MULTIDIM
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import train


def main():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=512)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    dc = DataConfig(vocab_size=512, seq_len=48, batch_size=16)
    state, _ = train(model, OptimizerConfig(lr=1e-3, warmup_steps=20,
                                            total_steps=120),
                     batches(dc), 120, log_every=120)

    # calibration trace
    eng = OffloadEngine(model, state.params, EngineConfig(hi_slots=10, lo_slots=6))
    rng = np.random.default_rng(0)
    trace, breaks = [], []
    for _ in range(4):
        breaks.append(len(trace))
        eng.start_sequence(64)
        for t in rng.integers(0, 512, 40):
            eng.decode_token(int(t))
        trace.extend(eng.trace)

    th = Thresholds(0.6, 0.9)
    nl = eng.num_moe_layers

    # coarse simplex sweep over Eq. 3 weights
    grid = [0.0, 0.2, 0.4, 0.6]
    best = (float("inf"), None)
    for lru, lfu, lhu in itertools.product(grid, grid, grid):
        fld = 1.0 - lru - lfu - lhu
        if fld < -1e-9 or fld > 0.6:
            continue
        w = PolicyWeights(lru, lfu, lhu, max(fld, 0.0) if abs(fld) > 1e-9 else 0.0)
        pen = cache_policy_penalty(trace, nl, w, 10, 6, th,
                                   sequence_breaks=breaks)
        if pen < best[0]:
            best = (pen, w)
    for name, w in (("LRU", LRU), ("LFU", LFU), ("MULTIDIM default", MULTIDIM),
                    ("tuned", best[1])):
        pen = cache_policy_penalty(trace, nl, w, 10, 6, th, sequence_breaks=breaks)
        print(f"{name:18s} weights={w}  miss_penalty={pen:.1f}")

    # cache-size sensitivity (paper: the policy advantage persists across sizes)
    print("\ncache-size sweep (penalty, tuned vs LRU):")
    for hi, lo in ((6, 3), (10, 6), (16, 8), (24, 12)):
        p_t = cache_policy_penalty(trace, nl, best[1], hi, lo, th,
                                   sequence_breaks=breaks)
        p_l = cache_policy_penalty(trace, nl, LRU, hi, lo, th,
                                   sequence_breaks=breaks)
        print(f"  hi={hi:2d} lo={lo:2d}: tuned={p_t:7.1f}  lru={p_l:7.1f}  "
              f"gain={100*(1-p_t/max(p_l,1e-9)):+.1f}%")


if __name__ == "__main__":
    main()
