"""HOBBIT core tests: Eq. 2 scoring, thresholds, cache manager invariants
(hypothesis), policies, loader, predictor, simulator."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests collect-and-skip without hypothesis
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.core import (FLD, LFU, LHU, LRU, MULTIDIM, MultidimensionalCache,
                        PREC_HI, PREC_LO, PREC_SKIP, Thresholds,
                        calibrate_thresholds, precision_decisions,
                        unimportance_scores)
from repro.core.policies import PolicyRecords
from repro.core.simulator import (HobbitSimConfig, OffloadSimulator, RTX4090,
                                  TraceLayer, cache_policy_penalty)


# ---------------------------------------------------------------- scoring
def test_eq2_scores_basic():
    order, s = unimportance_scores(np.array([0.7, 0.3]))
    assert list(order) == [0, 1]
    np.testing.assert_allclose(s, [0.0, 0.7])


def test_eq2_scores_unsorted_input():
    order, s = unimportance_scores(np.array([0.2, 0.5, 0.3]))
    assert list(order) == [1, 2, 0]
    np.testing.assert_allclose(s, [0.0, 0.5, 0.8])


def test_precision_rank0_always_hi():
    # even with T1=0 the top-gate expert stays high precision
    dec = precision_decisions(np.array([0.9, 0.1]), Thresholds(0.0, 0.0))
    assert dec[0] == PREC_HI and dec[1] == PREC_SKIP


def test_precision_decisions_order_preserved():
    dec = precision_decisions(np.array([0.1, 0.8, 0.1]), Thresholds(0.6, 0.95))
    # expert 1 has the largest gate -> hi; others share the tail
    assert dec[1] == PREC_HI
    assert set(dec) <= {PREC_HI, PREC_LO, PREC_SKIP}


@settings(max_examples=40, deadline=None)
@given(k=st.integers(2, 6), seed=st.integers(0, 10_000),
       t1=st.floats(0, 1), frac=st.floats(0, 1))
def test_property_scores_monotone_and_bounded(k, seed, t1, frac):
    g = np.random.default_rng(seed).uniform(0.01, 1.0, size=(k,))
    order, s = unimportance_scores(g)
    assert s[0] == 0.0
    assert (np.diff(s) >= -1e-12).all()          # non-decreasing in rank
    assert s[-1] <= 1.0 + 1e-9
    dec = precision_decisions(g, Thresholds(min(t1, 1.0), 1.0))
    assert dec[np.argmax(g)] == PREC_HI          # top expert always hi
    assert not (dec == PREC_SKIP).any()          # T2=1 -> nothing skipped


def test_calibrate_thresholds_hits_target_split():
    rng = np.random.default_rng(0)
    scores = rng.uniform(0, 1, 10_000)
    th = calibrate_thresholds(scores, frac_hi=0.67, frac_lo=0.30)
    assert abs((scores <= th.t1).mean() - 0.67) < 0.02
    assert abs(((scores > th.t1) & (scores <= th.t2)).mean() - 0.30) < 0.02


# ---------------------------------------------------------------- policies
def test_policy_records_and_priorities():
    r = PolicyRecords(num_layers=8)
    r.on_use((0, 1), True)
    r.advance_token()
    r.on_use((3, 2), False)
    # LRU prefers the more recently used expert
    assert r.priority((3, 2), LRU, 0) > r.priority((0, 1), LRU, 0)
    # LHU prefers the high-precision-used expert
    assert r.priority((0, 1), LHU, 0) > r.priority((3, 2), LHU, 0)
    # FLD prefers the next layer downstream of current layer 2
    assert r.priority((3, 2), FLD, 2) > r.priority((0, 1), FLD, 2)


def test_policy_reset_on_new_sequence():
    r = PolicyRecords(4)
    r.on_use((0, 0), True)
    r.reset()
    assert r.priority((0, 0), LFU, 0) == 0.0


# ---------------------------------------------------------------- cache
def test_cache_admit_evicts_lowest_priority():
    c = MultidimensionalCache(num_layers=4, hi_slots=2, lo_slots=1, weights=LRU)
    c.new_sequence()
    c.advance_token()
    assert c.admit((0, 0), True, 0) == (c.lookup((0, 0), True), None)
    c.advance_token()
    c.admit((1, 0), True, 1)
    c.advance_token()
    slot, evicted = c.admit((2, 0), True, 2)
    assert evicted == (0, 0)                      # least recently used
    assert c.lookup((0, 0), True) is None
    assert c.lookup((1, 0), True) is not None


def test_cache_pin_blocks_eviction():
    c = MultidimensionalCache(4, hi_slots=2, lo_slots=0, weights=LRU)
    c.new_sequence()
    c.advance_token()
    c.admit((0, 0), True, 0)
    c.admit((1, 0), True, 0)
    c.pin((0, 0), True)                            # older, but pinned
    _, evicted = c.admit((2, 0), True, 0)
    assert evicted == (1, 0)


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                              st.booleans()), min_size=1, max_size=200),
       hi=st.integers(1, 6), lo=st.integers(1, 4))
def test_property_cache_never_exceeds_capacity(ops, hi, lo):
    c = MultidimensionalCache(4, hi, lo, MULTIDIM)
    c.new_sequence()
    for i, (layer, expert, is_hi) in enumerate(ops):
        if i % 7 == 0:
            c.advance_token()
        if c.probe((layer, expert), is_hi) is None:
            c.admit((layer, expert), is_hi, layer)
        assert len(c.hi.slot_of) <= hi
        assert len(c.lo.slot_of) <= lo
        # slot table is a bijection
        assert len(set(c.hi.slot_of.values())) == len(c.hi.slot_of)
        assert len(set(c.lo.slot_of.values())) == len(c.lo.slot_of)
    s = c.stats
    assert s.hits + s.misses == len(ops)


# ---------------------------------------------------------------- simulator
def _mk_trace(n_tokens=20, n_layers=4, e=8, k=2, seed=0):
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n_tokens):
        tok = []
        for _li in range(n_layers):
            experts = rng.choice(e, size=k, replace=False)
            g = np.sort(rng.uniform(0.1, 1.0, k))[::-1]
            g = g / g.sum()
            tok.append(TraceLayer(experts=experts.tolist(), gate_vals=g,
                                  pred_experts=experts.tolist(),
                                  pred_gate_vals=g))
        trace.append(tok)
    return trace


def test_simulator_hobbit_loads_fewer_bytes_than_on_demand():
    trace = _mk_trace()
    cfg = HobbitSimConfig(hi_slots=8, lo_slots=4, hi_bytes=1_000_000,
                          lo_bytes=250_000)
    on = OffloadSimulator("on_demand", 4, RTX4090, cfg).run(trace)
    hb = OffloadSimulator("hobbit", 4, RTX4090, cfg).run(trace)
    assert hb["total_s"] > 0 and on["total_s"] > 0
    # perfect predictions + mixed precision must not be slower
    assert hb["total_s"] <= on["total_s"] * 1.05


def test_simulator_dense_layerwise_slowest():
    trace = _mk_trace()
    cfg = HobbitSimConfig(hi_slots=8, lo_slots=4, hi_bytes=1_000_000,
                          lo_bytes=250_000)
    dense = OffloadSimulator("dense_layerwise", 4, RTX4090, cfg).run(trace)
    on = OffloadSimulator("on_demand", 4, RTX4090, cfg).run(trace)
    assert dense["total_s"] >= on["total_s"]


def test_cache_policy_penalty_decreases_with_capacity():
    trace = _mk_trace(40)
    th = Thresholds(0.6, 0.9)
    small = cache_policy_penalty(trace, 4, LRU, 4, 2, th)
    big = cache_policy_penalty(trace, 4, LRU, 16, 8, th)
    assert big <= small
