"""Shared test helpers.

`hypothesis` is an optional dev dependency: the property tests in
test_core / test_layers / test_moe / test_quantize use it when available,
but its absence must not error out collection of the whole suite.  Test
modules import the real names when possible and fall back to these stubs,
under which every ``@given`` test is collected as a zero-arg skip.
"""

import pytest


def hypothesis_stubs():
    """Return (given, settings, st) stand-ins: property tests collect but
    skip with a clear reason instead of erroring the module import."""

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    def given(*_a, **_k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():  # zero-arg: no fixture resolution for strategy params
                pass

            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco

    def settings(*_a, **_k):
        return lambda f: f

    return given, settings, _AnyStrategy()
