"""Shared test helpers.

Three roles:

* make the repo root importable so tests can exercise the CI gates
  (``tools.check_bench`` / ``tools.check_docs``) and the static analyzers
  (``tools.analysis``) in-process;
* ``hypothesis`` stubs — the property tests collect-but-skip cleanly when
  hypothesis is not installed;
* the TSan-lite race guard: every engine built anywhere in the suite gets
  an `InstrumentedCache` (autouse fixture below), so the staging/engine
  tests double as a runtime thread-confinement check;
* jit recompilation counters (`jit_cache_sizes` / `assert_no_recompiles`)
  for the steady-state compile-count guards in test_recompile_guard.py.
"""

import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))       # tools.* imports


def hypothesis_stubs():
    """Return (given, settings, st) stand-ins: property tests collect but
    skip with a clear reason instead of erroring the module import."""

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    def given(*_a, **_k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():  # zero-arg: no fixture resolution for strategy params
                pass

            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco

    def settings(*_a, **_k):
        return lambda f: f

    return given, settings, _AnyStrategy()


@pytest.fixture(autouse=True)
def thread_confined_cache(monkeypatch):
    """Run every engine-built cache under the TSan-lite confinement guard.

    `OffloadEngine` constructs its cache via the `MultidimensionalCache`
    name imported into `repro.core.engine`; patching that binding swaps in
    `InstrumentedCache`, which raises `ThreadConfinementError` the moment a
    metadata mutator runs off the constructing thread.  Tests that build a
    cache directly can opt in by instantiating `InstrumentedCache`."""
    from repro.core import engine as engine_mod
    from repro.core.cache_guard import InstrumentedCache

    monkeypatch.setattr(engine_mod, "MultidimensionalCache",
                        InstrumentedCache)
    yield


def jit_cache_sizes(fns: dict) -> dict:
    """{name: compiled-variant count} for a dict of jitted callables (0 for
    plain Python callables, e.g. engines running with jit disabled)."""
    out = {}
    for name, fn in fns.items():
        size = getattr(fn, "_cache_size", None)
        out[name] = int(size()) if callable(size) else 0
    return out


def assert_no_recompiles(before: dict, after: dict):
    """Every jitted function's compile count must be unchanged."""
    grew = {k: (before.get(k, 0), v) for k, v in after.items()
            if v != before.get(k, 0)}
    assert not grew, (
        f"steady-state decode recompiled: {grew} — a shape or donation "
        "changed between steps (fixed-P padding / page-table export "
        "invariant violated)")
