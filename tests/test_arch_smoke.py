"""Per-architecture smoke tests (brief requirement): a REDUCED variant of
each assigned family (2+ layers, d_model<=512, <=4 experts) runs one forward
/ train step on CPU with correct output shapes and no NaNs; decode matches
teacher-forced forward exactly in f32."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_variant
from repro.models import Batch, build_model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import init_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    kw = {}
    if cfg.frontend == "vision_patches":
        kw["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_prefix_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio_frames":
        kw["audio_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder.seq_len, cfg.encoder.d_model)) * 0.02)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return Batch(tokens=toks, loss_mask=jnp.ones((b, s)), **kw)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.d_model <= 512 and cfg.num_layers <= 10
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    x, aux, _ = m.forward(params, batch)
    exp_s = 32 + (cfg.num_prefix_tokens if cfg.frontend == "vision_patches" else 0)
    assert x.shape == (2, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any())
    loss, metrics = m.loss(params, batch, remat=False)
    assert np.isfinite(float(loss))
    lg = m.logits(params, x)
    assert lg.shape[-1] == cfg.vocab_size


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    m = build_model(cfg)
    state = init_state(m, seed=0)
    step = jax.jit(make_train_step(m, OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                      total_steps=10)))
    batch = _batch(cfg)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.opt.step) == 1
    # params changed
    d0 = jax.tree_util.tree_leaves(state.params)[3]
    d1 = jax.tree_util.tree_leaves(state2.params)[3]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_matches_forward(arch):
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    batch = _batch(cfg, b, s, seed=1)
    x, _, _ = m.forward(params, batch)
    full_lg = m.logits(params, x)[:, -1, :]
    pre = Batch(tokens=batch.tokens[:, : s - 1],
                loss_mask=jnp.ones((b, s - 1)),
                prefix_embeds=batch.prefix_embeds,
                audio_frames=batch.audio_frames)
    _, cache, pos = m.prefill(params, pre, max_len=32)
    lg, _ = m.decode_step(params, cache, batch.tokens[:, s - 1 : s], pos)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_lg),
                               rtol=2e-4, atol=2e-4)


def test_ring_cache_local_attention_matches_forward():
    """Decode through a ring KV cache (window smaller than the sequence)
    must match teacher-forced full-sequence logits at every step."""
    cfg = smoke_variant(get_config("gemma2-27b"))
    cfg = dataclasses.replace(cfg, dtype="float32", window_size=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    pre_len = 4
    b0 = Batch(tokens=toks[:, :pre_len], loss_mask=jnp.ones((2, pre_len)))
    _, cache, pos = m.prefill(params, b0, max_len=32)
    # ring caches must actually be window-sized
    local_cache = cache["blocks"][0]
    assert local_cache["k"].shape[2] == 8   # (nb, B, window, hkv, hd)
    for i in range(pre_len, 20):
        lg, cache = m.decode_step(params, cache, toks[:, i : i + 1], pos)
        pos = pos + 1
        full = Batch(tokens=toks[:, : i + 2], loss_mask=jnp.ones((2, i + 2)))
        x, _, _ = m.forward(params, full)
        want = m.logits(params, x)[:, i, :]
        np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


def test_ring_cache_prefill_longer_than_window():
    """Prefill longer than the window must land the last `window` keys in
    the right ring slots."""
    cfg = smoke_variant(get_config("gemma3-27b"))
    cfg = dataclasses.replace(cfg, dtype="float32", window_size=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 21)), jnp.int32)
    b0 = Batch(tokens=toks[:, :20], loss_mask=jnp.ones((1, 20)))
    _, cache, pos = m.prefill(params, b0, max_len=32)
    lg, _ = m.decode_step(params, cache, toks[:, 20:21], pos)
    full = Batch(tokens=toks, loss_mask=jnp.ones((1, 21)))
    x, _, _ = m.forward(params, full)
    want = m.logits(params, x)[:, -1, :]
    np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
