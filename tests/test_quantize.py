"""Unit + property tests for the groupwise quantization library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests collect-and-skip without hypothesis
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.quant import (
    QTensor, dequantize, expert_nbytes, pack_codes, quantization_error,
    quantize, quantize_tree, unpack_codes,
)

jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_pack_unpack_roundtrip(bits):
    rng = np.random.default_rng(0)
    qmax = {8: 127, 4: 7, 2: 1}[bits]
    codes = rng.integers(-qmax, qmax + 1, size=(64, 16)).astype(np.int8)
    packed = pack_codes(jnp.asarray(codes), bits)
    un = unpack_codes(packed, bits)
    np.testing.assert_array_equal(np.asarray(un), codes)
    assert packed.shape[0] == 64 // {8: 1, 4: 2, 2: 4}[bits]


@pytest.mark.parametrize("bits,tol", [(8, 0.012), (4, 0.12), (2, 0.55)])
@pytest.mark.parametrize("shape", [(128, 32), (256, 64), (4, 128, 8)])
def test_quantize_reconstruction_error(bits, tol, shape):
    rng = np.random.default_rng(1)
    w = rng.normal(size=shape).astype(np.float32)
    err = quantization_error(jnp.asarray(w), bits=bits, group_size=64)
    assert err < tol, f"bits={bits} err={err}"


def test_quantize_exact_zero_and_scale_guard():
    w = jnp.zeros((128, 8), jnp.float32)
    q = quantize(w, bits=4, group_size=64)
    np.testing.assert_array_equal(np.asarray(dequantize(q)), 0.0)


def test_qtensor_is_pytree():
    w = jnp.ones((128, 8), jnp.float32)
    q = quantize(w, bits=4)
    leaves = jax.tree_util.tree_leaves(q)
    assert len(leaves) == 2
    q2 = jax.tree_util.tree_map(lambda x: x, q)
    assert isinstance(q2, QTensor) and q2.bits == 4


def test_quantize_under_jit_and_vmap():
    w = jnp.asarray(np.random.default_rng(2).normal(size=(3, 128, 16)), jnp.float32)
    q = jax.jit(lambda x: quantize(x, bits=8, group_size=64))(w)
    out = dequantize(q)
    assert out.shape == w.shape
    rel = float(jnp.linalg.norm(out - w) / jnp.linalg.norm(w))
    assert rel < 0.02


def test_quantize_tree_filters_small_and_int_leaves():
    tree = {
        "w": jnp.ones((128, 4), jnp.float32),
        "b": jnp.ones((4,), jnp.float32),
        "idx": jnp.ones((128, 4), jnp.int32),
    }
    qt = quantize_tree(tree, bits=8)
    assert isinstance(qt["w"], QTensor)
    assert not isinstance(qt["b"], QTensor)
    assert not isinstance(qt["idx"], QTensor)


def test_expert_nbytes_ordering():
    hi = expert_nbytes(512, 2048, 16)
    i8 = expert_nbytes(512, 2048, 8)
    i4 = expert_nbytes(512, 2048, 4)
    i2 = expert_nbytes(512, 2048, 2)
    assert hi > i8 > i4 > i2
    # int4 should be ~4x smaller than bf16 (modulo scale overhead).
    assert hi / i4 > 3.5


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([8, 4, 2]),
    k_groups=st.integers(1, 4),
    n=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_dequant_bounded_by_group_amax(bits, k_groups, n, seed):
    """|dequant| never exceeds the per-group max |w| (symmetric quant invariant)."""
    group = 32
    k = group * k_groups
    w = np.random.default_rng(seed).normal(size=(k, n)).astype(np.float32)
    q = quantize(jnp.asarray(w), bits=bits, group_size=group)
    wr = np.asarray(dequantize(q)).reshape(k_groups, group, n)
    wg = w.reshape(k_groups, group, n)
    amax = np.abs(wg).max(axis=1, keepdims=True)
    assert (np.abs(wr) <= amax + 1e-5).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_error_monotone_in_bits(seed):
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(256, 16)).astype(np.float32))
    errs = [quantization_error(w, bits=b, group_size=64) for b in (8, 4, 2)]
    assert errs[0] <= errs[1] <= errs[2]
