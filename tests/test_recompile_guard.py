"""Steady-state jit recompilation guards over the decode hot path.

The decode loop's performance story (fixed-P pow2-padded scatters, static
page tables, donated pools) collapses if any step retraces: one silent
recompile costs more than a hundred steps.  These tests warm a backend up,
snapshot every jitted callable's compiled-variant count (`_cache_size()`),
run more decode steps at identical shapes, and require the counts to be
bit-identical — for the grouped, paged-KV and multi-stream-staged engine
configurations plus the paged dense backend.
"""

import dataclasses

import jax
import numpy as np
import pytest

from conftest import assert_no_recompiles, jit_cache_sizes
from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine
from repro.models import build_model
from repro.serving.api import DenseBackend, HobbitBackend
from repro.serving.decode import sample_token

WARMUP, STEADY = 8, 8

ENGINE_CONFIGS = {
    # grouped batched dispatch, synchronous staging: isolates the grouped
    # decode jits (one gating matmul + hi GEMM + lo dequant-GEMM per layer)
    "grouped": dict(hi_slots=8, lo_slots=4, grouped=True, streams=1,
                    ordered=True, async_prefetch=False),
    # paged KV: decode runs through attn_paged over the shared page pool
    "paged": dict(hi_slots=8, lo_slots=4, paged_kv=True, kv_page_size=4,
                  kv_pages=32),
    # multi-stream byte-budgeted staging riding alongside decode
    "staged": dict(hi_slots=8, lo_slots=4, streams=2, async_prefetch=True),
}


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=2, d_model=64,
                        vocab=128)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _decode_steps(backend, tok, n):
    for _ in range(n):
        lg = backend.step(tok)
        tok = np.asarray(sample_token(lg, jax.random.PRNGKey(0), 0.0))
    return tok


def _drive(backend, fns):
    """Warm up, snapshot compile counts, decode more, snapshot again."""
    prompts = (np.arange(6, dtype=np.int32).reshape(2, 3) % 100) + 1
    backend.start_batch(2, 24)
    lg = backend.prefill(prompts)
    tok = np.asarray(sample_token(lg, jax.random.PRNGKey(0), 0.0))
    tok = _decode_steps(backend, tok, WARMUP)
    before = jit_cache_sizes(fns())
    _decode_steps(backend, tok, STEADY)
    after = jit_cache_sizes(fns())
    return before, after


@pytest.mark.parametrize("name", sorted(ENGINE_CONFIGS))
def test_engine_decode_steady_state_zero_recompiles(setup, name):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(**ENGINE_CONFIGS[name]))
    be = HobbitBackend(eng)
    try:
        before, after = _drive(be, lambda: dict(eng._jit_cache))
        assert before and any(v > 0 for v in before.values())
        assert_no_recompiles(before, after)
    finally:
        be.close()


def test_kernel_tier_decode_steady_state_zero_recompiles(setup, monkeypatch):
    """Grouped + paged decode with Pallas dispatch active (REPRO_KERNEL_MODE
    =pallas, interpret on CPU): the kernel tier's tiling/padding choices and
    scalar-prefetch operands (page table, combine rows) must not
    reintroduce per-step recompiles."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "pallas")
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(
        hi_slots=8, lo_slots=4, grouped=True, paged_kv=True, kv_page_size=4,
        kv_pages=32))
    be = HobbitBackend(eng)
    try:
        before, after = _drive(be, lambda: dict(eng._jit_cache))
        assert before and any(v > 0 for v in before.values())
        assert_no_recompiles(before, after)
    finally:
        be.close()


def test_paged_dense_decode_steady_state_zero_recompiles(setup):
    m, params = setup
    be = DenseBackend(m, params, paged=True, page_size=4, kv_pages=32,
                      prefill_chunk=4)

    def fns():
        return {"step": be._step, "paged_step": be._paged_step,
                "chunk_prefill": be._admission._fn,
                **{("prefill", k): v for k, v in be._prefill_fns.items()}}

    try:
        before, after = _drive(be, fns)
        assert any(v > 0 for v in before.values())
        assert_no_recompiles(before, after)
    finally:
        be.close()
