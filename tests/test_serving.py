"""Serving substrate tests: generate loop, batching server, per-slot
positions, sampling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import Batch, build_model
from repro.serving.batching import BatchingServer, Request
from repro.serving.decode import generate, sample_token


@pytest.fixture(scope="module")
def model_params():
    cfg = smoke_variant(get_config("granite-3-2b"), layers=2, d_model=64,
                        vocab=128)
    cfg = dataclasses.replace(cfg, dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def test_generate_greedy_deterministic(model_params):
    m, params = model_params
    prompts = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 8)),
                          jnp.int32)
    r1 = generate(m, params, prompts, 6)
    r2 = generate(m, params, prompts, 6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 14)
    assert r1.decode_tok_s > 0


def test_generate_matches_incremental_forward(model_params):
    """Greedy generation must equal argmax over repeated full forwards."""
    m, params = model_params
    prompt = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
    res = generate(m, params, prompt, 4)
    toks = list(map(int, prompt[0]))
    for _ in range(4):
        b = Batch(tokens=jnp.asarray([toks]), loss_mask=jnp.ones((1, len(toks))))
        x, _, _ = m.forward(params, b)
        nxt = int(jnp.argmax(m.logits(params, x)[0, -1]))
        toks.append(nxt)
    np.testing.assert_array_equal(res.tokens[0], np.asarray(toks))


def test_sample_token_temperature_and_topk():
    logits = jnp.asarray([[1.0, 5.0, 2.0, 0.0]])
    assert int(sample_token(logits, jax.random.PRNGKey(0), 0.0)[0]) == 1
    # top-1 sampling == greedy regardless of temperature
    assert int(sample_token(logits, jax.random.PRNGKey(1), 2.0, top_k=1)[0]) == 1


def test_batching_server_buckets_and_stats(model_params):
    m, params = model_params
    srv = BatchingServer(m, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(1)
    for i in range(5):
        plen = 8 if i < 3 else 12
        srv.submit(Request(rid=i, prompt=rng.integers(0, 128, plen),
                           max_new_tokens=4 + (i % 2)))
    srv.run()
    assert len(srv.completed) == 5
    for r in srv.completed:
        assert len(r.output) == r.max_new_tokens
    st = srv.stats()
    assert st["requests"] == 5 and st["decode_tok_s"] > 0


def test_server_consistent_with_generate(model_params):
    m, params = model_params
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
    srv = BatchingServer(m, params, max_batch=1, max_len=32)
    srv.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    srv.run()
    res = generate(m, params, jnp.asarray(prompt)[None], 5)
    np.testing.assert_array_equal(srv.completed[0].output, res.tokens[0, 8:])
