"""Mamba2 SSD tests: chunked matmul form == step-by-step recurrence,
chunk-size invariance, decode continuation after prefill."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import ssm as ssm_lib


def _cfg(chunk=16):
    cfg = smoke_variant(get_config("mamba2-780m"), d_model=64)
    return dataclasses.replace(
        cfg, dtype="float32", ssm=dataclasses.replace(cfg.ssm, chunk_size=chunk))


def test_ssd_chunked_matches_naive_recurrence():
    b, s, h, p, n = 2, 32, 3, 8, 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    d_skip = jnp.asarray(rng.normal(size=(h,)), jnp.float32)

    y, hf = ssm_lib.ssd_chunked(x, dt, a, bb, cc, d_skip, chunk=8)

    # naive recurrence
    hstate = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    xn, dtn, bn, cn = map(np.asarray, (x, dt, bb, cc))
    an = np.asarray(a)
    for t in range(s):
        da = np.exp(dtn[:, t] * an[None, :])                     # (b,h)
        hstate = hstate * da[:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dtn[:, t], bn[:, t, 0], xn[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", cn[:, t, 0], hstate)
    ys = ys + xn * np.asarray(d_skip)[None, None, :, None]
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), hstate, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("c1,c2", [(4, 16), (8, 32)])
def test_ssd_chunk_size_invariance(c1, c2):
    b, s, h, p, n = 1, 32, 2, 4, 4
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, s, h)), jnp.float32)
    a = -jnp.ones((h,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    d = jnp.zeros((h,), jnp.float32)
    y1, h1 = ssm_lib.ssd_chunked(x, dt, a, bb, cc, d, chunk=c1)
    y2, h2 = ssm_lib.ssd_chunked(x, dt, a, bb, cc, d, chunk=c2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


def test_ssm_forward_then_decode_continues_state():
    cfg = _cfg(chunk=8)
    p = ssm_lib.ssm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 17, cfg.d_model)) * 0.3, jnp.float32)
    # full forward over 17 tokens
    y_full, _ = ssm_lib.ssm_forward(p, x, cfg)
    # forward over 16, then one decode step
    y_pre, state = ssm_lib.ssm_forward(p, x[:, :16], cfg)
    y_dec, state2 = ssm_lib.ssm_decode(p, x[:, 16:17], state, cfg)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 16]),
                               rtol=5e-3, atol=5e-3)
