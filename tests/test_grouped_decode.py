"""Grouped-decode + async-loading tests: parity of the grouped path against
the per-expert reference path, O(1) expert-compute dispatches, async
double-buffered prefetch (wall-clock overlap accounting, in-flight
reservation safety), deduplicated pending-prediction bookkeeping, and the
union-overflow / all-hard-pinned cache corners at batch > 1."""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import (CacheStarvation, EngineConfig, LRU,
                        MultidimensionalCache, OffloadEngine, PREC_HI,
                        Thresholds)
from repro.models import build_model
from repro.serving.api import HobbitBackend, generate


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=256)
    # ample dispatch capacity so the dense prefill never drops tokens at
    # batch > 1 (batched-vs-batch1 comparisons share prefill numerics)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _reference(ecfg: EngineConfig) -> EngineConfig:
    """Same engine settings on the per-expert reference path."""
    return dataclasses.replace(ecfg, grouped=False, async_prefetch=False)


def _step_logits(m, params, ecfg, prompts, teacher):
    """Per-step logits (prefill + teacher-forced decode) through a backend."""
    be = HobbitBackend(OffloadEngine(m, params, ecfg))
    be.start_batch(prompts.shape[0], 32)
    out = [be.prefill(prompts)]
    for t in range(teacher.shape[0]):
        out.append(be.step(teacher[t]))
    return np.stack(out), be.engine


# ------------------------------------------------------------------ parity
def test_grouped_matches_per_expert_path_every_slot(setup):
    """Grouped decode (one hi GEMM + one lo dequant-GEMM per layer) must
    reproduce the per-expert reference path's logits for every batch slot,
    under mixed precision, a constrained cache and prefetch enabled."""
    m, params = setup
    ecfg = EngineConfig(hi_slots=6, lo_slots=4, thresholds=Thresholds(0.6, 0.9))
    rng = np.random.default_rng(11)
    prompts = rng.integers(0, 256, (4, 6))
    teacher = rng.integers(0, 256, (5, 4))
    lg_g, _ = _step_logits(m, params, ecfg, prompts, teacher)
    lg_r, _ = _step_logits(m, params, _reference(ecfg), prompts, teacher)
    np.testing.assert_allclose(lg_g, lg_r, atol=1e-3)


def test_grouped_step_pallas_kernels_match_xla(setup, monkeypatch):
    """Full grouped decode step with the Pallas kernel tier active
    (REPRO_KERNEL_MODE=pallas -> interpret mode on CPU): per-slot logits
    equal the XLA oracle path within tolerance, and the dispatch counters
    prove the fused kernels actually ran (auto fallback is never silent)."""
    m, params = setup
    ecfg = EngineConfig(hi_slots=6, lo_slots=4, thresholds=Thresholds(0.6, 0.9))
    rng = np.random.default_rng(21)
    prompts = rng.integers(0, 256, (3, 5))
    teacher = rng.integers(0, 256, (4, 3))
    monkeypatch.setenv("REPRO_KERNEL_MODE", "xla")
    lg_x, _ = _step_logits(m, params, ecfg, prompts, teacher)
    monkeypatch.setenv("REPRO_KERNEL_MODE", "pallas")
    lg_p, eng = _step_logits(m, params, ecfg, prompts, teacher)
    np.testing.assert_allclose(lg_p, lg_x, atol=1e-3)
    disp = eng.stats()["kernel_dispatch"]
    assert disp.get("gating_topk.pallas_interpret", 0) > 0
    assert disp.get("grouped_dequant_matmul.pallas_interpret", 0) > 0
    assert disp.get("grouped_dequant_combine.pallas_interpret", 0) > 0


def test_grouped_generate_tokens_equal_reference(setup):
    m, params = setup
    ecfg = EngineConfig(hi_slots=16, lo_slots=8)
    prompts = np.random.default_rng(12).integers(0, 256, (3, 5))
    res_g = generate(HobbitBackend(OffloadEngine(m, params, ecfg)),
                     prompts, 6, max_len=32)
    res_r = generate(HobbitBackend(OffloadEngine(m, params, _reference(ecfg))),
                     prompts, 6, max_len=32)
    np.testing.assert_array_equal(res_g.tokens, res_r.tokens)


# ------------------------------------------------ O(1) compute dispatches
def test_grouped_issues_one_dispatch_per_layer(setup):
    """Per MoE layer the grouped path issues exactly one expert-compute
    dispatch (the fused hi+lo grouped FFN), independent of batch and top_k —
    and never touches the per-expert jitted kernels."""
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=16, lo_slots=8))
    be = HobbitBackend(eng)
    prompts = np.random.default_rng(13).integers(0, 256, (4, 4))
    be.start_batch(4, 32)
    be.prefill(prompts)
    n_steps = 5
    for t in range(n_steps):
        be.step(np.full((4,), 7 + t, np.int32))
    assert eng._expert_dispatches == n_steps * eng.num_moe_layers
    # the per-expert kernels exist only on the reference path
    assert "hi" not in eng._jit_cache and "lo" not in eng._jit_cache
    assert "grouped_ffn" in eng._jit_cache


# ------------------------------------------------ async prefetch overlap
def test_async_prefetch_matches_sync_and_reports_overlap(setup):
    m, params = setup
    ecfg = EngineConfig(hi_slots=8, lo_slots=4)
    prompts = np.random.default_rng(14).integers(0, 256, (2, 5))
    res_async = generate(HobbitBackend(OffloadEngine(m, params, ecfg)),
                         prompts, 6, max_len=32)
    sync = dataclasses.replace(ecfg, async_prefetch=False)
    res_sync = generate(HobbitBackend(OffloadEngine(m, params, sync)),
                        prompts, 6, max_len=32)
    np.testing.assert_array_equal(res_async.tokens, res_sync.tokens)

    eng = OffloadEngine(m, params, ecfg)
    generate(HobbitBackend(eng), prompts, 6, max_len=32)
    s = eng.stats()
    assert s["prefetch_jobs"] > 0              # async staging actually ran
    assert 0.0 <= s["overlap_fraction"] <= 1.0
    assert s["copy_s"] > 0.0 and s["load_stall_s"] >= 0.0
    assert json.loads(json.dumps(s))           # serializable end to end


def test_fetch_many_writes_all_slots(setup):
    """Batched fetch: every admitted slot is written through one scatter per
    pool tensor and counted as loader traffic."""
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    eng.start_batch(1, 8)
    items = []
    for e in range(3):
        slot, _ = eng.cache.admit((0, e), True, 0)
        items.append((0, e, PREC_HI, slot))
    before = eng.loader.n_loads[PREC_HI]
    eng._fetch_many(items)
    assert eng.loader.n_loads[PREC_HI] == before + 3
    for _, e, _, slot in items:
        np.testing.assert_allclose(np.asarray(eng.pool_hi["wi"][slot]),
                                   eng.storage_hi[0]["wi"][e], rtol=1e-6)


def test_async_scheduler_commits_staged_weights(setup):
    """submit_prefetch reserves the slot immediately (in-flight), and
    wait(layer) lands the staged bytes in the device pool."""
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    eng.start_batch(1, 8)
    n = eng.scheduler.submit_prefetch(
        1, [0, 3], np.array([PREC_HI, PREC_HI]), current_layer=0)
    assert n == 2
    assert eng.cache.is_inflight((1, 0), True)
    eng.scheduler.wait(1)
    assert not eng.cache.is_inflight((1, 0), True)
    slot = eng.cache.lookup((1, 0), True)
    assert slot is not None
    np.testing.assert_allclose(np.asarray(eng.pool_hi["wi"][slot]),
                               eng.storage_hi[1]["wi"][0], rtol=1e-6)
    assert eng.scheduler.copy_s > 0.0


# ------------------------------------------------ prediction bookkeeping
def test_no_duplicate_pending_predictions(setup, monkeypatch):
    """Regression: the adaptive walk and the plain next-layer prediction
    used to both append a Prediction for the same (layer, slot), double-
    counting record_accuracy.  Now at most one pending entry exists per
    (layer, slot) at any point in the step."""
    m, params = setup
    dupes = []
    orig = OffloadEngine._score_pending_preds

    def spy(self, mi, tops):
        keys = [(p.layer, r) for p, _, r in self._pending_preds]
        if len(keys) != len(set(keys)):
            dupes.append(keys)
        return orig(self, mi, tops)

    monkeypatch.setattr(OffloadEngine, "_score_pending_preds", spy)
    # small cache so the adaptive walk regularly finds layer l+1 misses
    # (the condition that used to produce the duplicate entry)
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=4, lo_slots=2))
    prompts = np.random.default_rng(15).integers(0, 256, (2, 4))
    generate(HobbitBackend(eng), prompts, 5, max_len=32)
    assert not dupes
    # accuracy totals bound: <= one distance-1 sample per slot per layer
    # transition per decode step (4 decode calls x 2 slots x 3 transitions)
    c, t = eng.predictor._acc.get(1, [0, 0])
    assert t <= 4 * 2 * (eng.num_moe_layers - 1)
    assert 0 <= c <= t


# ------------------------------------------------ cache corner cases
def test_union_overflow_reload_stays_correct_at_batch2(setup):
    """Cache smaller than the layer's union demand at batch 2: same-layer
    neighbours evict each other's hard-pinned experts (pathological branch),
    the engine reloads on demand, and per-slot numerics still match the
    isolated batch=1 runs."""
    m, params = setup
    ecfg = EngineConfig(hi_slots=2, lo_slots=1, thresholds=Thresholds(1.0, 1.0),
                        prefetch=False)
    prompts = np.random.default_rng(16).integers(0, 256, (2, 6))
    eng = OffloadEngine(m, params, ecfg)
    res_b = generate(HobbitBackend(eng), prompts, 5, max_len=32)
    assert eng._union_reloads > 0          # contention actually happened
    assert eng.stats()["union_reloads"] == eng._union_reloads
    assert eng.cache.stats.misses > 0
    for r in range(2):
        res_1 = generate(HobbitBackend(OffloadEngine(m, params, ecfg)),
                         prompts[r : r + 1], 5, max_len=32)
        np.testing.assert_array_equal(res_b.tokens[r], res_1.tokens[0])


def test_select_victim_when_everything_hard_pinned():
    """Pool smaller than one layer's pinned set: admission must still
    succeed by sacrificing a hard-pinned resident (it reloads on demand)."""
    c = MultidimensionalCache(4, hi_slots=2, lo_slots=0, weights=LRU)
    c.new_sequence()
    c.advance_token()
    c.admit((0, 0), True, 0)
    c.admit((0, 1), True, 0)
    c.pin((0, 0), True, hard=True)
    c.pin((0, 1), True, hard=True)
    slot, evicted = c.admit((0, 2), True, 0)
    assert evicted in {(0, 0), (0, 1)}
    assert c.lookup((0, 2), True) == slot


def test_inflight_reservation_blocks_eviction():
    c = MultidimensionalCache(4, hi_slots=2, lo_slots=0, weights=LRU)
    c.new_sequence()
    c.advance_token()
    s0, _ = c.admit((0, 0), True, 0)
    c.begin_inflight((0, 0), True, s0)
    c.advance_token()
    c.admit((1, 0), True, 1)
    c.advance_token()
    # (0,0) is older (LRU victim) but in flight -> (1,0) must be evicted
    _, evicted = c.admit((2, 0), True, 2)
    assert evicted == (1, 0)
    assert c.lookup((0, 0), True) == s0


def test_cache_starvation_when_every_slot_inflight():
    c = MultidimensionalCache(4, hi_slots=1, lo_slots=0, weights=LRU)
    c.new_sequence()
    c.advance_token()
    s0, _ = c.admit((0, 0), True, 0)
    c.begin_inflight((0, 0), True, s0)
    assert not c.can_admit(True)
    with pytest.raises(CacheStarvation):
        c.admit((0, 1), True, 0)
    c.end_inflight((0, 0), True)
    assert c.can_admit(True)
    slot, evicted = c.admit((0, 1), True, 0)
    assert evicted == (0, 0) and slot == s0
