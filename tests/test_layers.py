"""Layer-level unit tests: masks, RoPE, softcap, chunked attention,
MLA absorbed-vs-naive equivalence, FFN variants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests collect-and-skip without hypothesis
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.configs import get_config, smoke_variant
from repro.models import layers as L


def test_causal_mask():
    pos = jnp.arange(4)[None, :]
    m = np.asarray(L.make_mask(pos, pos, "attn", 0))[0]
    assert m.tolist() == [[1, 0, 0, 0], [1, 1, 0, 0], [1, 1, 1, 0], [1, 1, 1, 1]]


def test_sliding_window_mask():
    pos = jnp.arange(6)[None, :]
    m = np.asarray(L.make_mask(pos, pos, "attn_local", 2))[0]
    # each query attends to itself and the previous token only
    for i in range(6):
        for j in range(6):
            assert m[i, j] == (j <= i and i - j < 2)


def test_chunked_attention_mask():
    pos = jnp.arange(8)[None, :]
    m = np.asarray(L.make_mask(pos, pos, "attn_chunked", 4))[0]
    for i in range(8):
        for j in range(8):
            assert m[i, j] == (j <= i and i // 4 == j // 4)


def test_rope_preserves_norm_and_relative_phase():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 64)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.asarray([[i]]), 1000.0)
        kj = L.apply_rope(k, jnp.asarray([[j]]), 1000.0)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)
    assert dot_at(3, 1) != pytest.approx(dot_at(7, 1), rel=1e-3)


def test_softcap_bounds_logits():
    x = jnp.asarray([-1e4, -10.0, 0.0, 10.0, 1e4])
    y = np.asarray(L._softcap(x, 50.0))
    assert (np.abs(y) <= 50.0 + 1e-5).all()
    assert y[2] == 0.0
    np.testing.assert_allclose(y[3], 10.0, atol=0.2)  # ~linear in the middle


@pytest.mark.parametrize("kind,window", [("attn", 0), ("attn_local", 3),
                                         ("attn_chunked", 4)])
def test_chunked_mha_equals_mha(kind, window):
    rng = np.random.default_rng(1)
    b, s, h, hd = 2, 16, 4, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = L.mha(q, k, v, L.make_mask(pos, pos, kind, window), 0.0, 0.35)
    chunked = L.chunked_mha(q, k, v, pos, pos, kind, window, 0.0, 0.35,
                            q_chunk=4)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_chunked_mha_ragged_tail():
    rng = np.random.default_rng(2)
    b, s, h, hd = 1, 10, 2, 8      # 10 = 2*4 + tail 2
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    pos = jnp.arange(s)[None]
    full = L.mha(q, k, v, L.make_mask(pos, pos, "attn", 0), 0.0, 0.35)
    chunked = L.chunked_mha(q, k, v, pos, pos, "attn", 0, 0.0, 0.35, q_chunk=4)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_mla_chunked_matches_unchunked():
    cfg = smoke_variant(get_config("deepseek-v2-236b"), d_model=128)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p = L.mla_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 12, 128)) * 0.3, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(12)[None], (2, 12))
    out_full, kv_full = L.mla_forward(p, x, pos, cfg, q_chunk=64)
    out_chunk, kv_chunk = L.mla_forward(p, x, pos, cfg, q_chunk=5)  # ragged
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_chunk),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["swiglu", "gelu", "sq_relu"])
def test_ffn_variants(act):
    cfg = smoke_variant(get_config("granite-3-2b"), d_model=64)
    cfg = dataclasses.replace(cfg, dtype="float32", ffn_activation=act)
    p = L.ffn_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(2, 8, 64)), jnp.float32)
    y = L.ffn_forward(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    if act == "sq_relu":
        # squared relu of zero input (zero weights on x=0) stays zero
        y0 = L.ffn_forward(p, jnp.zeros_like(x), cfg)
        np.testing.assert_allclose(np.asarray(y0), 0.0, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(2, 12), window=st.integers(1, 6), seed=st.integers(0, 999))
def test_property_window_mask_never_exceeds_causal(s, window, seed):
    pos = jnp.arange(s)[None]
    causal = np.asarray(L.make_mask(pos, pos, "attn", 0))
    local = np.asarray(L.make_mask(pos, pos, "attn_local", window))
    chunked = np.asarray(L.make_mask(pos, pos, "attn_chunked", window))
    assert not (local & ~causal).any()
    assert not (chunked & ~causal).any()
    # diagonal always attendable
    assert local[0].diagonal().all() and chunked[0].diagonal().all()
