"""OffloadEngine integration tests: numerics vs the reference decode path,
precision-substitution effects, cooperative (host) mode, stats coherence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine, Thresholds
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=256)
    cfg = dataclasses.replace(cfg, dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return m, params


def _reference_nll(m, params, toks):
    cache = m.init_cache(1, len(toks) + 1)
    pos = jnp.zeros((1,), jnp.int32)
    nll, n = 0.0, 0
    lg, cache = m.decode_step(params, cache, jnp.asarray([[toks[0]]]), pos)
    for t in toks[1:]:
        p = np.asarray(lg[0], np.float64)
        p -= p.max()
        p -= np.log(np.exp(p).sum())
        nll -= p[t]
        n += 1
        pos = pos + 1
        lg, cache = m.decode_step(params, cache, jnp.asarray([[t]]), pos)
    return nll / n


def test_all_hi_engine_matches_reference_exactly(setup):
    m, params = setup
    toks = [1, 5, 9, 13, 2, 7, 20, 33]
    eng = OffloadEngine(m, params, EngineConfig(
        hi_slots=32, lo_slots=1, thresholds=Thresholds(1.0, 1.0),
        prefetch=False))
    got = eng.score_nll(toks)
    want = _reference_nll(m, params, toks)
    assert abs(got - want) < 1e-4


def test_mixed_precision_close_but_not_identical(setup):
    m, params = setup
    toks = [1, 5, 9, 13, 2, 7, 20, 33, 40, 41]
    base = OffloadEngine(m, params, EngineConfig(
        hi_slots=32, lo_slots=1, thresholds=Thresholds(1.0, 1.0), prefetch=False))
    mixed = OffloadEngine(m, params, EngineConfig(
        hi_slots=32, lo_slots=32, thresholds=Thresholds(0.55, 1.0),
        prefetch=False))
    nb, nm = base.score_nll(toks), mixed.score_nll(toks)
    assert nm != nb                       # int4 substitution changes numerics
    assert abs(nm - nb) / nb < 0.15       # ... but only slightly
    assert mixed.loader.n_loads[1] > 0    # some lo-precision loads happened


@pytest.mark.xfail(strict=False,
                   reason="statistical property of trained routers; on "
                          "random-init smoke models the ordering is a coin "
                          "flip (failed at seed too)")
def test_skip_degrades_more_than_replace(setup):
    m, params = setup
    toks = list(range(1, 24))
    base = OffloadEngine(m, params, EngineConfig(
        hi_slots=32, lo_slots=4, thresholds=Thresholds(1.0, 1.0), prefetch=False))
    rep = OffloadEngine(m, params, EngineConfig(
        hi_slots=32, lo_slots=32, thresholds=Thresholds(0.5, 1.0), prefetch=False))
    skp = OffloadEngine(m, params, EngineConfig(
        hi_slots=32, lo_slots=4, thresholds=Thresholds(0.5, 0.5), prefetch=False))
    nb = base.score_nll(toks)
    assert abs(rep.score_nll(toks) - nb) <= abs(skp.score_nll(toks) - nb) + 1e-6


def test_host_compute_mode_matches_device(setup):
    m, params = setup
    toks = [3, 8, 1, 4]
    kw = dict(hi_slots=32, lo_slots=8, thresholds=Thresholds(1.0, 1.0),
              prefetch=False)
    dev = OffloadEngine(m, params, EngineConfig(**kw))
    host = OffloadEngine(m, params, EngineConfig(compute_mode="host", **kw))
    assert abs(dev.score_nll(toks) - host.score_nll(toks)) < 1e-3


def test_engine_stats_consistent(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    eng.generate([1, 2, 3], 6)
    s = eng.stats()
    cs = s["cache"]
    assert cs["hits"] + cs["misses"] > 0
    assert cs["hit_ratio"] == pytest.approx(
        cs["hits"] / (cs["hits"] + cs["misses"]))
    assert s["loads_hi"] + s["loads_lo"] > 0
    assert s["loaded_bytes"] > 0
    # every trace token covers every MoE layer
    assert all(len(tok) == eng.num_moe_layers for tok in eng.trace)
    # the whole stats dict round-trips through JSON (serving API contract)
    import json
    assert json.loads(json.dumps(s))["cache"]["hits"] == cs["hits"]
    for key in ("load_stall_s", "overlap_fraction", "gating_s"):
        assert s[key] >= 0.0


def test_engine_small_cache_thrashes_but_stays_correct(setup):
    m, params = setup
    toks = [1, 5, 9, 13]
    tiny = OffloadEngine(m, params, EngineConfig(
        hi_slots=2, lo_slots=1, thresholds=Thresholds(1.0, 1.0), prefetch=False))
    want = _reference_nll(m, params, toks)
    assert abs(tiny.score_nll(toks) - want) < 1e-4
    assert tiny.cache.stats.hit_ratio() < 0.6   # lots of misses with 2 slots
