"""Real multi-device SPMD execution (8 CPU devices in a subprocess):
sharded train/decode must match single-device numerics.  This is the
strongest correctness evidence for the sharding rules — not just that the
partitioned program compiles, but that it computes the same thing."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.configs import get_config, smoke_variant
    from repro.launch import sharding as sh
    from repro.models import Batch, build_model
    from repro.training.optimizer import OptimizerConfig, init_opt_state
    from repro.training.train_loop import TrainState, make_train_step

    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=2, d_model=128,
                        vocab=512)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, init_opt_state(params))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(model, ocfg, remat=True)

    rng = np.random.default_rng(0)
    batch = Batch(tokens=jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32),
                  loss_mask=jnp.ones((8, 32)))

    # single-device reference
    s_ref, m_ref = jax.jit(step)(state, batch)
    loss_ref = float(m_ref["loss"])

    # 2x4 (data, model) mesh with the production sharding rules
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    p_sh = sh.param_shardings(mesh, params)
    from repro.training.optimizer import OptState
    repl = NamedSharding(mesh, P())
    opt_sh = OptState(step=repl, mu=sh.param_shardings(mesh, state.opt.mu),
                      nu=sh.param_shardings(mesh, state.opt.nu))
    b_sh = Batch(tokens=NamedSharding(mesh, P("data", None)),
                 loss_mask=NamedSharding(mesh, P("data", None)))
    with mesh:
        f = jax.jit(step, in_shardings=(TrainState(p_sh, opt_sh), b_sh))
        s_sp, m_sp = f(state, batch)
    loss_sp = float(m_sp["loss"])

    # compare a few updated param leaves
    la = jax.tree_util.tree_leaves(s_ref.params)
    lb = jax.tree_util.tree_leaves(s_sp.params)
    max_diff = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
                   for a, b in zip(la, lb))
    print(json.dumps({"loss_ref": loss_ref, "loss_sp": loss_sp,
                      "max_param_diff": max_diff}))
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss_ref"] - res["loss_sp"]) < 1e-4, res
    assert res["max_param_diff"] < 5e-4, res
