"""SLO-aware scheduling + preemption tests (PR 9): pause/resume logits
parity on every backend/KV-layout combination, prefix-sharing refcount
safety when a victim holding aliased pages is paused, the deterministic
`ServingTimeline` SLO-vs-FIFO gates, the aging starvation bound, the
BackendConfig deprecation shim, stats-after-close, and a live
BatchingServer preemption round trip."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine, Thresholds
from repro.core.simulator import ServingTimeline, TimelineConfig
from repro.models import build_model
from repro.serving.api import (BackendConfig, DenseBackend, HobbitBackend,
                               make_backend)
from repro.serving.batching import BatchingServer, Request
from repro.serving.workload import (RequestClass, WorkloadConfig,
                                    effective_priority, generate_workload,
                                    slo_urgency)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=256)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _unconstrained(m):
    n = m.cfg.num_layers * m.cfg.moe.num_experts
    return EngineConfig(hi_slots=n, lo_slots=1,
                        thresholds=Thresholds(1.0, 1.0), prefetch=False)


def _reference_logits(backend, prompt, teacher):
    """Per-step logits of `prompt` decoding teacher-forced in slot 0 with no
    pause anywhere (the unpreempted baseline)."""
    backend.start_batch(3, 48)
    for s in range(3):
        backend.release(s)
    out = [backend.join(0, prompt)]
    for t in teacher:
        vec = np.zeros(3, np.int32)
        vec[0] = t
        out.append(backend.step(vec)[0])
    return out


def _paused_logits(backend, prompt, teacher, pause_after, *, resume_slot=0,
                   disturb_prompt=None):
    """Same decode, but paused after `pause_after` steps, disturbed by an
    unrelated admission while parked, then resumed into `resume_slot`."""
    backend.start_batch(3, 48)
    for s in range(3):
        backend.release(s)
    out = [backend.join(0, prompt)]
    slot = 0
    for i, t in enumerate(teacher):
        if i == pause_after:
            snap = backend.pause(slot)
            if disturb_prompt is not None:
                # another request churns the KV pool / caches meanwhile
                backend.join(1, disturb_prompt)
                backend.step(np.asarray([0, 7, 0], np.int32))
            backend.resume(resume_slot, snap)
            slot = resume_slot
        vec = np.zeros(3, np.int32)
        vec[slot] = t
        out.append(backend.step(vec)[slot])
    return out


# ------------------------------------------------ pause/resume parity
def test_pause_resume_logits_identical_dense(setup):
    m, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 256, 6)
    teacher = rng.integers(0, 256, 5)
    ref = _reference_logits(DenseBackend(m, params), prompt, teacher)
    got = _paused_logits(DenseBackend(m, params), prompt, teacher, 2,
                         disturb_prompt=rng.integers(0, 256, 4))
    for a, b in zip(ref, got):
        np.testing.assert_allclose(a, b, atol=1e-5)
        assert int(np.argmax(a)) == int(np.argmax(b))


def test_pause_resume_logits_identical_dense_paged_new_slot(setup):
    """Paged KV: the snapshot restores into a DIFFERENT slot (fresh private
    pages) and decode continues logits-identical."""
    m, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 256, 6)
    teacher = rng.integers(0, 256, 5)

    def mk():
        return DenseBackend(m, params, paged=True, page_size=8, kv_pages=24,
                            prefill_chunk=8)

    ref = _reference_logits(mk(), prompt, teacher)
    got = _paused_logits(mk(), prompt, teacher, 2, resume_slot=2,
                         disturb_prompt=rng.integers(0, 256, 4))
    for a, b in zip(ref, got):
        np.testing.assert_allclose(a, b, atol=1e-5)
        assert int(np.argmax(a)) == int(np.argmax(b))


def test_pause_resume_logits_identical_hobbit(setup):
    """Offload engine: pausing drops the slot's pending predictions and
    releases it; resume restores KV rows and position bit-identically (the
    unconstrained cache keeps every expert hi, so numerics are exact)."""
    m, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 256, 6)
    teacher = rng.integers(0, 256, 5)

    def mk():
        return HobbitBackend(OffloadEngine(m, params, _unconstrained(m)))

    ref_b, got_b = mk(), mk()
    try:
        ref = _reference_logits(ref_b, prompt, teacher)
        got = _paused_logits(got_b, prompt, teacher, 2,
                             disturb_prompt=rng.integers(0, 256, 4))
        for a, b in zip(ref, got):
            np.testing.assert_allclose(a, b, atol=1e-4)
            assert int(np.argmax(a)) == int(np.argmax(b))
    finally:
        ref_b.close()
        got_b.close()


def test_pause_keeps_shared_page_refcounts(setup):
    """Pausing a victim whose prompt aliases another slot's prefix pages
    must only drop the victim's own references: sharers keep the pages, the
    victim's exclusive pages return to the free list, and resume draws
    fresh private pages."""
    m, params = setup
    rng = np.random.default_rng(3)
    sys_p = rng.integers(0, 256, 16)
    p0 = np.concatenate([sys_p, rng.integers(0, 256, 8)]).astype(np.int32)
    p1 = np.concatenate([sys_p, rng.integers(0, 256, 8)]).astype(np.int32)

    be = DenseBackend(m, params, paged=True, page_size=8, kv_pages=24,
                      prefill_chunk=8, prefix_sharing=True)
    be.start_batch(3, 48)
    for s in range(3):
        be.release(s)
    be.join(0, p0)
    be.join(1, p1)                  # aliases the 16-token (2-page) prefix
    assert be.kv.stats()["prefix_hit_tokens"] >= 16
    shared = [p for p in be.kv.owned[1] if be.kv.refcount[p] >= 2]
    assert shared and all(p in be.kv.owned[0] for p in shared)
    exclusive = [p for p in be.kv.owned[1] if be.kv.refcount[p] == 1]
    be.step(np.asarray([5, 9, 0], np.int32))

    snap = be.pause(1)
    # sharers keep the aliased pages (refcount drops by exactly one)...
    assert all(be.kv.refcount[p] == 1 and p in be.kv.owned[0]
               for p in shared)
    # ...and the victim's exclusive pages went back to the pool
    assert all(be.kv.refcount[p] == 0 and p in be.kv.free
               for p in exclusive)

    pos_ref = int(np.asarray(be.positions)[0])
    be.resume(2, snap)              # fresh private pages, any free slot
    assert all(be.kv.refcount[p] == 1 for p in be.kv.owned[2])
    assert int(np.asarray(be.positions)[2]) == pos_ref
    lg = be.step(np.asarray([5, 9, 9], np.int32))
    assert np.isfinite(lg[2]).all()


# ------------------------------------------------ deterministic timeline
def _burst_trace():
    return generate_workload(WorkloadConfig(
        classes=(
            RequestClass("batch", weight=1.0, priority=0,
                         prompt_tokens=(192, 256), new_tokens=(48, 64)),
            RequestClass("interactive", weight=1.0, priority=2,
                         ttft_slo_s=1.5, prompt_tokens=(16, 48),
                         new_tokens=(8, 16), shared_prefix=True),
        ),
        num_requests=24, arrival_rate=2.0, burst_factor=6.0,
        burst_every_s=6.0, burst_len_s=1.5, seed=7))


def _run_timeline(policy):
    return ServingTimeline(TimelineConfig(
        slots=3, kv_tokens=1024, prefill_tok_s=2048.0, decode_step_s=0.05,
        policy=policy)).run(_burst_trace())


def test_timeline_slo_beats_fifo_on_burst_trace():
    """The PR-9 acceptance scenario (also CI-gated via baseline.json):
    SLO-aware scheduling lifts attainment >= 1.3x over FIFO, actually
    preempts, starves nobody, and still completes every request."""
    fifo, slo = _run_timeline("fifo"), _run_timeline("slo")
    assert fifo["completed"] == slo["completed"] == 24
    assert slo["slo_attainment"] >= 1.3 * fifo["slo_attainment"]
    assert slo["preemptions"] >= 1
    assert slo["starved"] == 0
    assert fifo["preemptions"] == 0     # FIFO never preempts


def test_timeline_aging_bounds_every_wait():
    """No request — including the requeued preemption victims — waits past
    the aging starvation bound `(p_max - prio + margin + 1) * aging_s`."""
    res = _run_timeline("slo")
    tc = TimelineConfig()
    p_max = max(r["prio"] for r in res["requests"])
    for r in res["requests"]:
        assert r["admitted"] is not None
        bound = (p_max - r["prio"] + tc.preempt_margin + 1) * tc.aging_s
        assert r["admitted"] - r["arrival"] <= bound


def test_effective_priority_aging_bound_math():
    """A priority-0 request that has waited (p1 + margin) * aging_s
    outranks a fresh priority-p1 request by the preemption margin."""
    aging, margin, p1 = 10.0, 1.0, 3
    now = 100.0
    old = effective_priority(0, now - (p1 + margin) * aging, now, aging)
    fresh = effective_priority(p1, now, now, aging)
    assert old >= fresh + margin
    # urgency ordering: the aged request now sorts first
    assert slo_urgency(0, now - (p1 + margin) * aging, None, now, aging) \
        < slo_urgency(p1, now, None, now, aging)


# ------------------------------------------------ BackendConfig shim
def test_make_backend_legacy_kwargs_deprecated_and_equivalent(setup):
    m, params = setup
    with pytest.warns(DeprecationWarning):
        old = make_backend("dense", m, params, paged=True, page_size=32,
                           kv_pages=24, prefill_chunk=16,
                           prefix_sharing=False)
    new = make_backend(BackendConfig(
        kind="dense", paged=True, page_size=32, kv_pages=24,
        prefill_chunk=16, prefix_sharing=False), m, params)
    for attr in ("paged", "page_size", "kv_pages", "prefill_chunk",
                 "prefix_sharing", "_jit"):
        assert getattr(old, attr) == getattr(new, attr), attr

    ecfg = EngineConfig(hi_slots=4, lo_slots=2)
    with pytest.warns(DeprecationWarning):
        old_h = make_backend("hobbit", m, params, engine_config=ecfg)
    new_h = make_backend(BackendConfig(kind="hobbit", engine=ecfg),
                         m, params)
    try:
        assert old_h.engine.ecfg == new_h.engine.ecfg
    finally:
        old_h.close()
        new_h.close()


def test_make_backend_rejects_config_plus_kwargs(setup):
    m, params = setup
    with pytest.raises(TypeError):
        make_backend(BackendConfig(), m, params, paged=True)


def test_make_backend_bare_kind_no_warning(setup):
    import warnings

    m, params = setup
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        be = make_backend("dense", m, params)
    assert isinstance(be, DenseBackend)


# ------------------------------------------------ stats after close
def test_server_stats_after_close_returns_snapshot(setup):
    """Regression (PR 9): stats() after close() must serve the snapshot
    taken at close instead of calling into the closed backend."""
    m, params = setup

    class ClosingBackend(DenseBackend):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self._dead = False

        def close(self):
            self._dead = True
            super().close()

        def stats(self):
            if self._dead:
                raise RuntimeError("backend closed")
            return super().stats()

    srv = BatchingServer(ClosingBackend(m, params), max_batch=2, max_len=48)
    rng = np.random.default_rng(4)
    for i in range(3):
        srv.submit(Request(rid=i, prompt=rng.integers(0, 256, 6),
                           max_new_tokens=3))
    srv.run()
    before = srv.stats()
    srv.close()
    srv.close()                     # idempotent
    after = srv.stats()             # must not raise
    assert after["requests"] == before["requests"] == 3
    assert after["backend"]["backend"] == "dense"


# ------------------------------------------------ live preemption
class _InjectingBackend(DenseBackend):
    """Submits a high-priority request to the server mid-decode (the
    single-threaded analogue of traffic arriving while the batch is busy)."""

    def __init__(self, model, params, *, inject_after, make_req):
        super().__init__(model, params)
        self._steps = 0
        self._inject_after = inject_after
        self._make_req = make_req
        self.server = None

    def step(self, tokens):
        self._steps += 1
        if self._steps == self._inject_after:
            self.server.submit(self._make_req())
        return super().step(tokens)


def test_server_preempts_and_resumes_victim_exactly(setup):
    """Live end-to-end: a priority-2 arrival preempts the lone priority-0
    decode (pause -> snapshot -> requeue), runs to completion, then the
    victim resumes and finishes with output IDENTICAL to its isolated run."""
    m, params = setup
    rng = np.random.default_rng(5)
    p_victim = rng.integers(0, 256, 6)
    p_urgent = rng.integers(0, 256, 4)

    be = _InjectingBackend(
        m, params, inject_after=3,
        make_req=lambda: Request(rid=1, prompt=p_urgent, max_new_tokens=4,
                                 priority=2, ttft_slo_s=10.0))
    srv = BatchingServer(be, max_batch=1, max_len=48, preempt_margin=0.5)
    be.server = srv
    srv.submit(Request(rid=0, prompt=p_victim, max_new_tokens=12))
    srv.run()

    assert srv.preemptions == 1
    kinds = [e[0] for e in srv.events]
    assert "preempt" in kinds and "resume" in kinds
    assert kinds.index("preempt") < kinds.index("resume")
    by_rid = {r.rid: r for r in srv.completed}
    assert len(by_rid[1].output) == 4

    # the preempted victim's full output equals its unpreempted run
    from repro.serving.api import generate
    ref = generate(DenseBackend(m, params), p_victim[None], 12, max_len=48)
    np.testing.assert_array_equal(by_rid[0].output,
                                  ref.tokens[0, len(p_victim):])


def test_server_fifo_policy_never_preempts(setup):
    m, params = setup
    rng = np.random.default_rng(6)
    srv = BatchingServer(DenseBackend(m, params), max_batch=1, max_len=48,
                         policy="fifo")
    for i in range(3):
        srv.submit(Request(rid=i, prompt=rng.integers(0, 256, 5),
                           max_new_tokens=3, priority=i))
    srv.run()
    assert srv.preemptions == 0
    assert [r.rid for r in srv.completed] == [0, 1, 2]  # arrival order
