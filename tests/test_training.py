"""Training substrate tests: learning progress, microbatch equivalence,
checkpoint roundtrip, bf16-moment mode, data pipeline determinism."""

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.data.pipeline import DataConfig, batches, eval_batches, unigram_entropy
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import OptimizerConfig, init_opt_state, lr_at
from repro.training.train_loop import TrainState, init_state, make_train_step, train


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_variant(get_config("granite-3-2b"), layers=2, d_model=64,
                        vocab=128)
    cfg = dataclasses.replace(cfg, dtype="float32")
    return build_model(cfg)


def test_lr_schedule_shape():
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(ocfg, 0)) == 0.0
    assert float(lr_at(ocfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_at(ocfg, 100)) == pytest.approx(1e-4, rel=1e-2)


def test_training_reduces_loss(tiny):
    dc = DataConfig(vocab_size=128, seq_len=32, batch_size=8, seed=1)
    it = batches(dc)
    state, hist = train(tiny, OptimizerConfig(lr=2e-3, warmup_steps=10,
                                              total_steps=100),
                        it, 60, log_every=59, log=lambda *_: None)
    assert hist[-1]["nll"] < hist[0]["nll"] - 0.5
    assert hist[-1]["nll"] < unigram_entropy(dc)


def test_microbatched_step_matches_monolithic(tiny):
    dc = DataConfig(vocab_size=128, seq_len=32, batch_size=8, seed=2)
    batch = next(batches(dc))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    s0 = init_state(tiny, seed=3)
    mono = jax.jit(make_train_step(tiny, ocfg, microbatches=1))
    micro = jax.jit(make_train_step(tiny, ocfg, microbatches=4))
    s1, m1 = mono(s0, batch)
    s2, m2 = micro(init_state(tiny, seed=3), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_bf16_moment_mode_trains(tiny):
    dc = DataConfig(vocab_size=128, seq_len=32, batch_size=8, seed=4)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=20,
                           moment_dtype="bfloat16")
    params = tiny.init(jax.random.PRNGKey(0))
    state = TrainState(params, init_opt_state(params, "bfloat16"))
    step = jax.jit(make_train_step(tiny, ocfg, microbatches=2))
    it = batches(dc)
    for _ in range(3):
        state, metrics = step(state, next(it))
    assert np.isfinite(float(metrics["loss"]))
    assert jax.tree_util.tree_leaves(state.opt.mu)[0].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path, tiny):
    params = tiny.init(jax.random.PRNGKey(7))
    state = TrainState(params, init_opt_state(params))
    ckpt.save(str(tmp_path), state, step=5)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path, tiny):
    params = tiny.init(jax.random.PRNGKey(7))
    ckpt.save(str(tmp_path), params, step=0)
    bad = jax.tree_util.tree_map(lambda x: jnp.zeros((*x.shape, 2)), params)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), bad)


# --------------------------------------------------------------- data
def test_data_deterministic_and_host_disjoint():
    dc = DataConfig(vocab_size=128, seq_len=32, batch_size=8, seed=5)
    b1 = next(batches(dc))
    b2 = next(batches(dc))
    np.testing.assert_array_equal(np.asarray(b1.tokens), np.asarray(b2.tokens))
    h0 = next(batches(dc, host_id=0, num_hosts=2))
    h1 = next(batches(dc, host_id=1, num_hosts=2))
    assert h0.tokens.shape[0] == 4
    assert not np.array_equal(np.asarray(h0.tokens), np.asarray(h1.tokens))


def test_data_resume_by_step():
    dc = DataConfig(vocab_size=128, seq_len=16, batch_size=4, seed=6)
    it = batches(dc)
    next(it)
    second = next(it)
    resumed = next(batches(dc, start_step=1))
    np.testing.assert_array_equal(np.asarray(second.tokens),
                                  np.asarray(resumed.tokens))


def test_eval_batches_disjoint_from_train():
    dc = DataConfig(vocab_size=128, seq_len=16, batch_size=4, seed=7)
    tr = next(batches(dc))
    ev = eval_batches(dc, 1)[0]
    assert not np.array_equal(np.asarray(tr.tokens), np.asarray(ev.tokens))
