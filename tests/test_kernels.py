"""Pallas kernel validation: interpret-mode kernel body vs pure-jnp oracle,
swept over shapes, dtypes and bit widths."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dequant_matmul import (
    dequant_matmul_pallas,
    grouped_dequant_combine_pallas,
    grouped_dequant_matmul_pallas,
)
from repro.kernels.stacked_gating import gating_topk_pallas, stacked_gating_pallas
from repro.kernels.ops import dequant_matmul, stacked_gating
from repro.quant import quantize


def _mk(m, k, n, bits, group, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    q = quantize(w, bits=bits, group_size=group)
    return x, q


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("m,k,n", [(8, 256, 128), (16, 512, 256), (8, 256, 384)])
def test_dequant_matmul_kernel_vs_oracle(bits, m, k, n):
    x, q = _mk(m, k, n, bits, 128, jnp.float32)
    got = dequant_matmul_pallas(
        x, q.data, q.scale, bits=bits, group_size=128,
        block_m=8, block_n=128, block_k=256, interpret=True)
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits,group", [(8, 64), (4, 128)])
def test_dequant_matmul_dtypes_and_groups(dtype, bits, group):
    x, q = _mk(8, 256, 128, bits, group, dtype, seed=3)
    got = dequant_matmul_pallas(
        x, q.data, q.scale, bits=bits, group_size=group,
        block_m=8, block_n=128, block_k=256, interpret=True)
    want = ref.dequant_matmul_ref(x, q)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_dequant_matmul_multi_kstep_accumulation():
    """k split across grid steps must accumulate identically."""
    x, q = _mk(8, 1024, 128, 4, 128, jnp.float32, seed=5)
    got = dequant_matmul_pallas(
        x, q.data, q.scale, bits=4, group_size=128,
        block_m=8, block_n=128, block_k=256, interpret=True)
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)


def test_ops_wrapper_pads_ragged_shapes():
    """ops.dequant_matmul must handle M/N/K not divisible by blocks (forced pallas)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(5, 384)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(384, 96)), jnp.float32)
    q = quantize(w, bits=8, group_size=128)
    got = dequant_matmul(x, q, mode="pallas")
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_ops_wrapper_leading_batch_dims():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(2, 3, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    q = quantize(w, bits=4, group_size=128)
    got = dequant_matmul(x, q, mode="pallas")
    assert got.shape == (2, 3, 128)
    want = ref.dequant_matmul_ref(x.reshape(-1, 256), q).reshape(2, 3, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("p,b,d,e", [(1, 1, 256, 8), (4, 2, 512, 16), (3, 8, 1024, 64)])
def test_stacked_gating_kernel_vs_oracle(p, b, d, e):
    rng = np.random.default_rng(p * 100 + e)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(p, d, e)), jnp.float32)
    got = stacked_gating_pallas(x, g, block_d=256, interpret=True)
    want = ref.stacked_gating_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_stacked_gating_bf16_inputs():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(2, 512)), jnp.bfloat16)
    g = jnp.asarray(rng.normal(size=(2, 512, 8)), jnp.bfloat16)
    got = stacked_gating_pallas(x, g, block_d=512, interpret=True)
    want = ref.stacked_gating_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-2, atol=3e-2)


def test_stacked_gating_wrapper_pads_d():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(2, 384)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(3, 384, 8)), jnp.float32)
    got = stacked_gating(x, g, mode="pallas", block_d=256)
    want = ref.stacked_gating_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_auto_mode_cpu_uses_oracle_path(monkeypatch):
    """On CPU 'auto' must route to the XLA dense path (fast) and agree."""
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    x, q = _mk(4, 256, 128, 8, 128, jnp.float32, seed=17)
    ops.reset_dispatch_counts()
    got = dequant_matmul(x, q, mode="auto")
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)
    assert ops.dispatch_counts() == {"dequant_matmul.xla": 1}


def test_env_override_routes_auto_to_pallas_on_cpu(monkeypatch):
    """REPRO_KERNEL_MODE=pallas flips 'auto' to the interpret-mode kernel
    (the CI parity job's dispatch), and the counter records the flip."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "pallas")
    x, q = _mk(4, 256, 128, 8, 128, jnp.float32, seed=19)
    ops.reset_dispatch_counts()
    got = dequant_matmul(x, q, mode="auto")
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    assert ops.dispatch_counts() == {"dequant_matmul.pallas_interpret": 1}


# ----------------------------------------------------------- flash decode
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.ops import flash_decode


@pytest.mark.parametrize("b,s,h,hd,bs", [(2, 512, 4, 64, 128),
                                          (1, 256, 2, 128, 256),
                                          (3, 1024, 8, 64, 256)])
def test_flash_decode_kernel_vs_oracle(b, s, h, hd, bs):
    rng = np.random.default_rng(b * 100 + s)
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    got = flash_decode_pallas(q, k, v, lengths, block_s=bs, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_dtypes(dtype):
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 512, 4, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 512, 4, 64)), dtype)
    lengths = jnp.asarray([100, 512], jnp.int32)
    got = flash_decode_pallas(q, k, v, lengths, block_s=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_flash_decode_wrapper_gqa_and_ragged():
    """Wrapper expands kv heads and pads ragged cache length (forced pallas)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.float32)   # hq=8
    k = jnp.asarray(rng.normal(size=(2, 300, 2, 64)), jnp.float32)  # hkv=2, S=300
    v = jnp.asarray(rng.normal(size=(2, 300, 2, 64)), jnp.float32)
    lengths = jnp.asarray([300, 17], jnp.int32)
    got = flash_decode(q, k, v, lengths, mode="pallas", block_s=128)
    kx = jnp.repeat(k, 4, axis=2)
    vx = jnp.repeat(v, 4, axis=2)
    want = ref.flash_decode_ref(q, kx, vx, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_length_zero_block_safe():
    """Blocks fully beyond `length` contribute nothing (numerically stable)."""
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    lengths = jnp.asarray([1], jnp.int32)
    got = flash_decode_pallas(q, k, v, lengths, block_s=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


# ----------------------------------------------------- paged flash decode
from repro.kernels.flash_decode import paged_flash_decode_pallas


def _mk_paged(b, hq, hkv, hd, psz, maxp, num_pages, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, hd)), dtype)
    pk = jnp.asarray(rng.normal(size=(num_pages, psz, hkv, hd)), dtype)
    pv = jnp.asarray(rng.normal(size=(num_pages, psz, hkv, hd)), dtype)
    table = jnp.asarray(rng.integers(0, num_pages, (b, maxp)), jnp.int32)
    return q, pk, pv, table


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("psz,maxp", [(4, 6), (8, 3)])
def test_paged_flash_decode_kernel_vs_oracle(hq, hkv, psz, maxp):
    """Table-driven kernel == gather + masked-softmax oracle, incl. GQA
    (kernel reads kv head hh // g through the index map, never repeats)."""
    q, pk, pv, table = _mk_paged(3, hq, hkv, 32, psz, maxp, 12,
                                 seed=hq * 10 + psz)
    rng = np.random.default_rng(1)
    lengths = jnp.asarray(rng.integers(1, psz * maxp + 1, (3,)), jnp.int32)
    got = paged_flash_decode_pallas(q, pk, pv, table, lengths, interpret=True)
    want = ref.paged_flash_decode_ref(q, pk, pv, table, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("lengths", [[0, 0], [1, 0], [4, 8], [12, 1]])
def test_paged_flash_decode_edge_lengths(lengths):
    """Length 0 (released slot) returns exact zeros; lengths exactly on a
    page boundary and single-token sequences match the oracle."""
    q, pk, pv, table = _mk_paged(2, 4, 2, 16, 4, 3, 8, seed=3)
    ln = jnp.asarray(lengths, jnp.int32)
    got = paged_flash_decode_pallas(q, pk, pv, table, ln, interpret=True)
    want = ref.paged_flash_decode_ref(q, pk, pv, table, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()
    for r, n in enumerate(lengths):
        if n == 0:
            np.testing.assert_array_equal(np.asarray(got[r]), 0.0)


def test_paged_flash_decode_junk_table_rows_isolated():
    """An inactive slot's page-table row may point at pages now owned by a
    neighbour: its garbage must stay confined to its own output row."""
    q, pk, pv, table = _mk_paged(3, 4, 2, 16, 4, 3, 8, seed=5)
    ln = jnp.asarray([7, 12, 3], jnp.int32)
    base = paged_flash_decode_pallas(q, pk, pv, table, ln, interpret=True)
    # rewrite row 1's table to junk (all pages alias a neighbour's)
    junk = table.at[1].set(table[0, 0])
    got = paged_flash_decode_pallas(q, pk, pv, junk, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(base[0]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(base[2]),
                               rtol=1e-6, atol=1e-6)
    want = ref.paged_flash_decode_ref(q, pk, pv, junk, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode_dtypes(dtype):
    q, pk, pv, table = _mk_paged(2, 4, 2, 32, 4, 4, 8, dtype=dtype, seed=7)
    ln = jnp.asarray([5, 16], jnp.int32)
    got = paged_flash_decode_pallas(q, pk, pv, table, ln, interpret=True)
    want = ref.paged_flash_decode_ref(q, pk, pv, table, ln)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_paged_flash_decode_softcap():
    """Logit softcap applies BEFORE masking, matching layers.mha's order."""
    q, pk, pv, table = _mk_paged(2, 4, 4, 16, 4, 3, 8, seed=9)
    ln = jnp.asarray([5, 11], jnp.int32)
    got = paged_flash_decode_pallas(q, pk, pv, table, ln, interpret=True,
                                    softcap=5.0)
    want = ref.paged_flash_decode_ref(q, pk, pv, table, ln, softcap=5.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    uncapped = paged_flash_decode_pallas(q, pk, pv, table, ln, interpret=True)
    assert np.abs(np.asarray(got) - np.asarray(uncapped)).max() > 1e-6


# ---------------------------------------- grouped dequant GEMM + combine
def _mk_grouped(p, k, n, bits, group, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p, k)), jnp.float32)
    data, scale = [], []
    for i in range(p):
        qt = quantize(jnp.asarray(rng.normal(size=(k, n)), jnp.float32),
                      bits=bits, group_size=group)
        data.append(qt.data)
        scale.append(qt.scale)
    return x, jnp.stack(data), jnp.stack(scale)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_grouped_dequant_matmul_single_launch_vs_oracle(bits):
    """The (P, K/bk)-grid kernel == dense dequantize + einsum oracle."""
    x, data, scale = _mk_grouped(6, 256, 64, bits, 64, seed=bits)
    got = grouped_dequant_matmul_pallas(x, data, scale, bits=bits,
                                        group_size=64, block_k=128,
                                        interpret=True)
    want = ops.grouped_dequant_matmul(x, data, scale, bits=bits,
                                      group_size=64, mode="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_k", [64, 128, 256])
def test_grouped_dequant_combine_vs_oracle(block_k):
    """Fused GEMM + gated combine-scatter == einsum + .at[].add oracle,
    across k-step counts (accumulation over both kk and same-row pairs)."""
    b, k_, n = 4, 256, 64
    x, data, scale = _mk_grouped(8, k_, n, 4, 64, seed=block_k)
    rows = jnp.asarray([0, 0, 1, 1, 2, 2, 3, 3], jnp.int32)
    wts = jnp.asarray(np.random.default_rng(1).uniform(0.1, 1.0, 8),
                      jnp.float32)
    got = grouped_dequant_combine_pallas(x, data, scale, rows, wts, bits=4,
                                         group_size=64, num_rows=b,
                                         block_k=block_k, interpret=True)
    want = ref.grouped_dequant_combine_ref(x, data, scale, rows, wts, bits=4,
                                           group_size=64, num_rows=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_grouped_dequant_combine_pads_and_unvisited_rows():
    """Pad pairs (row == num_rows, weight 0) are dropped in-kernel; rows no
    real pair visits come back as exact zeros, never NaN garbage."""
    b = 5
    x, data, scale = _mk_grouped(6, 128, 32, 4, 32, seed=11)
    # rows 0 and 2 visited (twice / once), rows 1/3/4 unvisited; pads at end
    rows = jnp.asarray([0, 0, 2, b, b, b], jnp.int32)
    wts = jnp.asarray([0.7, 0.3, 1.0, 0.0, 0.0, 0.0], jnp.float32)
    got = grouped_dequant_combine_pallas(x, data, scale, rows, wts, bits=4,
                                         group_size=32, num_rows=b,
                                         block_k=64, interpret=True)
    want = ref.grouped_dequant_combine_ref(x, data, scale, rows, wts, bits=4,
                                           group_size=32, num_rows=b)
    assert np.isfinite(np.asarray(got)).all()
    for r in (1, 3, 4):
        np.testing.assert_array_equal(np.asarray(got[r]), 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_grouped_dequant_combine_ops_wrapper_matches_ref():
    """ops-level dispatch: forced pallas == forced xla on the same inputs."""
    b = 3
    x, data, scale = _mk_grouped(4, 128, 48, 8, 64, seed=13)
    rows = jnp.asarray([0, 1, 1, b], jnp.int32)
    wts = jnp.asarray([1.0, 0.4, 0.6, 0.0], jnp.float32)
    kw = dict(bits=8, group_size=64, num_rows=b)
    got = ops.grouped_dequant_combine(x, data, scale, rows, wts,
                                      mode="pallas", **kw)
    want = ops.grouped_dequant_combine(x, data, scale, rows, wts,
                                       mode="xla", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ----------------------------------------------------------- gating top-k
@pytest.mark.parametrize("p,b,d,e,k", [(1, 2, 256, 8, 2), (3, 4, 512, 16, 4),
                                       (2, 1, 128, 8, 1)])
def test_gating_topk_kernel_vs_oracle(p, b, d, e, k):
    """Fused matmul+softmax+top-k == einsum + jax.nn.softmax + lax.top_k,
    including across multiple D blocks (selection runs on the last k-step)."""
    rng = np.random.default_rng(p * 10 + e)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(p, d, e)), jnp.float32)
    got_l, got_v, got_i = gating_topk_pallas(x, g, top_k=k, block_d=128,
                                             interpret=True)
    want_l, want_v, want_i = ref.gating_topk_ref(x, g, top_k=k)
    np.testing.assert_allclose(np.asarray(got_l), np.asarray(want_l),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_gating_topk_ties_select_lowest_index():
    """Exactly tied logits pick the lowest expert index on both paths."""
    x = jnp.ones((2, 4), jnp.float32)
    g = jnp.zeros((1, 4, 6), jnp.float32)          # all logits identical
    _, v_p, i_p = gating_topk_pallas(x, g, top_k=3, interpret=True)
    _, v_r, i_r = ref.gating_topk_ref(x, g, top_k=3)
    np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(i_p[0, 0]), [0, 1, 2])
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r), rtol=1e-6)


def test_gating_topk_ops_wrapper_pads_d():
    """ops.gating_topk pads ragged D; selected experts and probs agree."""
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(3, 96)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(2, 96, 8)), jnp.float32)
    _, v_p, i_p = ops.gating_topk(x, g, top_k=2, mode="pallas", block_d=64)
    _, v_r, i_r = ops.gating_topk(x, g, top_k=2, mode="xla")
    np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------- dispatch observability
def test_dispatch_counters_record_every_op(monkeypatch):
    """Each public op records the implementation that ran, keyed
    "<op>.<impl>" — the engine surfaces these via stats()["kernel_dispatch"]
    so an auto fallback is never silent."""
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    ops.reset_dispatch_counts()
    x, data, scale = _mk_grouped(2, 128, 32, 4, 32, seed=23)
    ops.grouped_dequant_matmul(x, data, scale, bits=4, group_size=32,
                               mode="auto")
    ops.grouped_dequant_matmul(x, data, scale, bits=4, group_size=32,
                               mode="pallas")
    rows = jnp.asarray([0, 1], jnp.int32)
    wts = jnp.ones((2,), jnp.float32)
    ops.grouped_dequant_combine(x, data, scale, rows, wts, bits=4,
                                group_size=32, num_rows=2, mode="auto")
    rng = np.random.default_rng(0)
    xg = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    gg = jnp.asarray(rng.normal(size=(1, 64, 8)), jnp.float32)
    ops.gating_topk(xg, gg, top_k=2, mode="auto")
    q, pk, pv, table = _mk_paged(2, 2, 2, 16, 4, 2, 4, seed=29)
    ops.paged_flash_decode(q, pk, pv, table, jnp.asarray([3, 5], jnp.int32),
                           mode="auto")
    c = ops.dispatch_counts()
    assert c["grouped_dequant_matmul.xla"] == 1
    assert c["grouped_dequant_matmul.pallas_interpret"] == 1
    assert c["grouped_dequant_combine.xla"] == 1
    assert c["gating_topk.xla"] == 1
    assert c["paged_flash_decode.xla"] == 1
