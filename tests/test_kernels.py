"""Pallas kernel validation: interpret-mode kernel body vs pure-jnp oracle,
swept over shapes, dtypes and bit widths."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.stacked_gating import stacked_gating_pallas
from repro.kernels.ops import dequant_matmul, stacked_gating
from repro.quant import quantize


def _mk(m, k, n, bits, group, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    q = quantize(w, bits=bits, group_size=group)
    return x, q


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("m,k,n", [(8, 256, 128), (16, 512, 256), (8, 256, 384)])
def test_dequant_matmul_kernel_vs_oracle(bits, m, k, n):
    x, q = _mk(m, k, n, bits, 128, jnp.float32)
    got = dequant_matmul_pallas(
        x, q.data, q.scale, bits=bits, group_size=128,
        block_m=8, block_n=128, block_k=256, interpret=True)
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits,group", [(8, 64), (4, 128)])
def test_dequant_matmul_dtypes_and_groups(dtype, bits, group):
    x, q = _mk(8, 256, 128, bits, group, dtype, seed=3)
    got = dequant_matmul_pallas(
        x, q.data, q.scale, bits=bits, group_size=group,
        block_m=8, block_n=128, block_k=256, interpret=True)
    want = ref.dequant_matmul_ref(x, q)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_dequant_matmul_multi_kstep_accumulation():
    """k split across grid steps must accumulate identically."""
    x, q = _mk(8, 1024, 128, 4, 128, jnp.float32, seed=5)
    got = dequant_matmul_pallas(
        x, q.data, q.scale, bits=4, group_size=128,
        block_m=8, block_n=128, block_k=256, interpret=True)
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)


def test_ops_wrapper_pads_ragged_shapes():
    """ops.dequant_matmul must handle M/N/K not divisible by blocks (forced pallas)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(5, 384)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(384, 96)), jnp.float32)
    q = quantize(w, bits=8, group_size=128)
    got = dequant_matmul(x, q, mode="pallas")
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_ops_wrapper_leading_batch_dims():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(2, 3, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    q = quantize(w, bits=4, group_size=128)
    got = dequant_matmul(x, q, mode="pallas")
    assert got.shape == (2, 3, 128)
    want = ref.dequant_matmul_ref(x.reshape(-1, 256), q).reshape(2, 3, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("p,b,d,e", [(1, 1, 256, 8), (4, 2, 512, 16), (3, 8, 1024, 64)])
def test_stacked_gating_kernel_vs_oracle(p, b, d, e):
    rng = np.random.default_rng(p * 100 + e)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(p, d, e)), jnp.float32)
    got = stacked_gating_pallas(x, g, block_d=256, interpret=True)
    want = ref.stacked_gating_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_stacked_gating_bf16_inputs():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(2, 512)), jnp.bfloat16)
    g = jnp.asarray(rng.normal(size=(2, 512, 8)), jnp.bfloat16)
    got = stacked_gating_pallas(x, g, block_d=512, interpret=True)
    want = ref.stacked_gating_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-2, atol=3e-2)


def test_stacked_gating_wrapper_pads_d():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(2, 384)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(3, 384, 8)), jnp.float32)
    got = stacked_gating(x, g, mode="pallas", block_d=256)
    want = ref.stacked_gating_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_auto_mode_cpu_uses_oracle_path():
    """On CPU 'auto' must route to the XLA dense path (fast) and agree."""
    x, q = _mk(4, 256, 128, 8, 128, jnp.float32, seed=17)
    got = dequant_matmul(x, q, mode="auto")
    want = ref.dequant_matmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------- flash decode
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.ops import flash_decode


@pytest.mark.parametrize("b,s,h,hd,bs", [(2, 512, 4, 64, 128),
                                          (1, 256, 2, 128, 256),
                                          (3, 1024, 8, 64, 256)])
def test_flash_decode_kernel_vs_oracle(b, s, h, hd, bs):
    rng = np.random.default_rng(b * 100 + s)
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    got = flash_decode_pallas(q, k, v, lengths, block_s=bs, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_dtypes(dtype):
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 512, 4, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 512, 4, 64)), dtype)
    lengths = jnp.asarray([100, 512], jnp.int32)
    got = flash_decode_pallas(q, k, v, lengths, block_s=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_flash_decode_wrapper_gqa_and_ragged():
    """Wrapper expands kv heads and pads ragged cache length (forced pallas)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.float32)   # hq=8
    k = jnp.asarray(rng.normal(size=(2, 300, 2, 64)), jnp.float32)  # hkv=2, S=300
    v = jnp.asarray(rng.normal(size=(2, 300, 2, 64)), jnp.float32)
    lengths = jnp.asarray([300, 17], jnp.int32)
    got = flash_decode(q, k, v, lengths, mode="pallas", block_s=128)
    kx = jnp.repeat(k, 4, axis=2)
    vx = jnp.repeat(v, 4, axis=2)
    want = ref.flash_decode_ref(q, kx, vx, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_length_zero_block_safe():
    """Blocks fully beyond `length` contribute nothing (numerically stable)."""
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    lengths = jnp.asarray([1], jnp.int32)
    got = flash_decode_pallas(q, k, v, lengths, block_s=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()
