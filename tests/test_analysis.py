"""tools.analysis invariant-checker tests.

Each known-bad fixture under tests/fixtures/analysis/ must trip exactly its
targeted invariants (pinning the call-graph resolution power the checkers
depend on), the real tree must stay clean — the clean-tree test is the
regression for the two violations this analyzer found and fixed (the
un-donated pool-scatter jit in core/engine.py and the undocumented
`hits`/`misses` cache counters) — and the TSan-lite runtime guard must fire
from a non-owner thread.
"""

import pathlib
import shutil
import threading

import pytest

from tools.analysis import CHECKERS, run_all
from tools.analysis import astutil
from tools.analysis.cli import main

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"


def _invariants(violations):
    return {v.invariant for v in violations}


def _run(checker, root):
    return CHECKERS[checker](root)


# ------------------------------------------------ known-bad fixtures
def test_thread_confinement_fixture_flags_all_three_invariants():
    vs = _run("thread-confinement", FIXTURES / "bad_thread_confinement")
    assert _invariants(vs) == {"main-thread-owned-call",
                               "main-thread-owned-mutation",
                               "main-thread-owned-write"}
    # the PR 4 review bug: eviction decided at copy time on the executor
    admit = [v for v in vs if "'admit'" in v.message]
    assert admit and "submit at" in admit[0].message
    # transitive reachability: _stage_one -> _finish -> cache.pin
    pin = [v for v in vs if "'pin'" in v.message]
    assert pin and "_finish" in pin[0].message
    # the PR 8 class: paged-KV refcounts / free list reclaimed at
    # copy-completion time on the executor instead of the scheduler thread
    refc = [v for v in vs if "'self.refcount'" in v.message]
    assert refc and refc[0].file.endswith("models/kv_pages.py")
    assert any("'self.free'" in v.message and ".append()" in v.message
               for v in vs)
    rsv = [v for v in vs if "'reserve'" in v.message]
    assert rsv and "_drop_reservation" in rsv[0].message
    # the PR-9 class: the fleet heat map fed from the stream executor
    # instead of the routing (main) thread
    obs = [v for v in vs if "'observe'" in v.message]
    assert obs and "fleet_heat.py" in obs[0].message


def test_hot_path_fixture_flags_syncs_and_donation():
    vs = _run("hot-path-purity", FIXTURES / "bad_hot_path")
    assert _invariants(vs) == {"host-sync-in-jit", "undonated-pool-buffer"}
    msgs = " ".join(v.message for v in vs)
    assert ".item()" in msgs and "np.asarray" in msgs
    assert "k_pages" in msgs        # receiver-hint jit of a bound method


def test_stats_fixture_flags_all_five_invariants():
    vs = _run("stats-schema", FIXTURES / "bad_stats")
    assert _invariants(vs) == {"engine-sim-parity", "staging-sim-drift",
                               "undocumented-stat", "stale-doc-field",
                               "slo-sim-parity"}
    msgs = " ".join(v.message for v in vs)
    assert "link_utilization" in msgs and "secret_local_counter" in msgs
    assert "ghost_metric" in msgs
    # the PR-9 SLO family: the fixture timeline dropped 'preemptions' and
    # the fixture cache stats lost the fleet-informed counter
    slo = [v for v in vs if v.invariant == "slo-sim-parity"]
    assert any("'preemptions'" in v.message and "timeline" in v.message
               for v in slo)
    assert any("fleet_heat_hits" in v.message for v in slo)


def test_protocol_fixture_flags_drifted_backend():
    vs = _run("protocol-conformance", FIXTURES / "bad_protocol")
    assert _invariants(vs) == {"missing-protocol-method",
                               "signature-mismatch",
                               "missing-protocol-attr"}
    msgs = " ".join(v.message for v in vs)
    assert "release" in msgs                    # missing method
    assert "pause" in msgs                      # missing preemption method
    assert "toks" in msgs                       # renamed positional
    assert "snap" in msgs                       # resume() renamed its param
    assert "reserve_tokens" in msgs             # optional made required
    assert "self.model" in msgs                 # protocol attr never assigned


# ------------------------------------------------ CLI behavior
@pytest.mark.parametrize("fixture,checker", [
    ("bad_thread_confinement", "thread-confinement"),
    ("bad_hot_path", "hot-path-purity"),
    ("bad_stats", "stats-schema"),
    ("bad_protocol", "protocol-conformance"),
])
def test_cli_exits_nonzero_on_fixture(capsys, fixture, checker):
    rc = main(["--root", str(FIXTURES / fixture), "--checker", checker])
    assert rc == 1
    out = capsys.readouterr().out
    assert f"[{checker}]" in out
    # failures name file:line and the violated invariant
    first = next(ln for ln in out.splitlines() if f"[{checker}]" in ln)
    loc = first.split(" ")[0]
    assert loc.count(":") == 2 and loc.split(":")[1].isdigit()


def test_cli_clean_on_real_tree(capsys):
    rc = main(["--root", str(REPO)])
    assert rc == 0
    assert "OK (4 checker(s) clean)" in capsys.readouterr().out


def test_run_all_clean_on_real_tree():
    # would have failed before the scatter-donation and hits/misses fixes
    results = run_all(REPO)
    assert set(results) == set(CHECKERS)
    assert all(vs == [] for vs in results.values()), results


def test_unknown_checker_rejected():
    with pytest.raises(KeyError):
        run_all(REPO, names=["no-such-checker"])


# ------------------------------------------------ suppression + parsing
def test_inline_suppression_silences_only_named_invariant(tmp_path):
    shutil.copytree(FIXTURES / "bad_stats", tmp_path / "t")
    eng = tmp_path / "t" / "src" / "repro" / "core" / "engine.py"
    # stats-schema violations anchor on the producer's `def stats` line;
    # a named suppression there must silence only that invariant
    eng.write_text(eng.read_text().replace(
        "def stats(self):",
        "def stats(self):  # analysis: ignore[undocumented-stat]"))
    vs = run_all(tmp_path / "t", names=["stats-schema"])["stats-schema"]
    # parity shares the suppressed anchor line but is a different invariant
    assert "engine-sim-parity" in _invariants(vs)
    # the engine-anchored undocumented-stat is gone; the loader one remains
    undoc = [v for v in vs if v.invariant == "undocumented-stat"]
    assert undoc and all("secret_local_counter" in v.message for v in undoc)


def test_bare_suppression_matches_any_invariant(tmp_path):
    shutil.copytree(FIXTURES / "bad_protocol", tmp_path / "t")
    api = tmp_path / "t" / "src" / "repro" / "serving" / "api.py"
    api.write_text(api.read_text().replace(
        "class BrokenBackend:", "class BrokenBackend:  # analysis: ignore"))
    vs = run_all(tmp_path / "t",
                 names=["protocol-conformance"])["protocol-conformance"]
    # class-anchored violations (missing method/attr) suppressed; the
    # def-anchored signature mismatches still fire
    assert _invariants(vs) == {"signature-mismatch"}


def test_owner_annotation_trailing_and_above(tmp_path):
    src = tmp_path / "m.py"
    src.write_text(
        "class C:\n"
        "    def __init__(self):\n"
        "        self.q = []            # owner: main-thread\n"
        "        self.free = 0\n"
        "\n"
        "    # owner: main-thread\n"
        "    # (eviction decisions happen at submit time)\n"
        "    def admit(self, k):\n"
        "        pass\n"
        "\n"
        "    def lookup(self, k):       # owner: other-thread\n"
        "        pass\n")
    sf = astutil.load_source(tmp_path, "m.py")
    methods, attrs = astutil.owner_annotations([sf])
    assert set(methods) == {"admit"}        # above + intermediate comment
    assert set(attrs) == {"q"}              # trailing marker
    assert methods["admit"][1] == 8


# ------------------------------------------------ trace-time jaxpr auditor
def _audit_fixture_findings():
    from tools.analysis import jaxpr_audit

    registry = list(jaxpr_audit.load_registry_module(
        FIXTURES / "bad_audit" / "registry.py"))
    return registry, jaxpr_audit.run_audit(registry)


def test_audit_fixture_each_rule_fires_exactly():
    from tools.analysis import jaxpr_audit

    _, findings = _audit_fixture_findings()
    by_entry = {}
    for f in findings:
        by_entry.setdefault(f.entrypoint, set()).add(f.rule)
    assert by_entry == {
        "bad.host_sync": {"no-host-sync"},
        "bad.donation": {"donation-honored"},
        "bad.dense_gather": {"no-dense-gather"},
        "bad.upcast": {"dtype-policy"},
        "bad.quant_widen": {"dtype-policy"},
        "bad.variant_budget": {"variant-budget"},
        "bad.vanished": {"config-drift"},
    }
    # every rule is proven live by at least one known-bad entry
    assert {f.rule for f in findings} == set(jaxpr_audit.RULES) | {
        "config-drift"}


def test_audit_finding_format_and_slice():
    _, findings = _audit_fixture_findings()
    sync = next(f for f in findings if f.rule == "no-host-sync")
    # `entrypoint: [rule] primitive @ eqn — message` with the jaxpr slice
    assert sync.render().startswith(
        "bad.host_sync: [no-host-sync] debug_callback @ eqn ")
    assert "host-sync" in sync.render()
    assert "debug_callback" in sync.jaxpr_slice
    dense = next(f for f in findings if f.rule == "no-dense-gather")
    assert "(2, 8, 2, 4)" in dense.message and "mode=pallas" in dense.message


def test_audit_suppression_silences_entry():
    registry, findings = _audit_fixture_findings()
    sup = next(e for e in registry if e.name == "ok.suppressed")
    assert sup.suppresses("no-host-sync")
    assert not sup.suppresses("donation-honored")
    assert not any(f.entrypoint == "ok.suppressed" for f in findings)


def test_audit_config_drift_names_vanished_target():
    _, findings = _audit_fixture_findings()
    drift = [f for f in findings if f.rule == "config-drift"]
    assert len(drift) == 1
    assert "repro.kernels.ops:this_got_renamed" in drift[0].message


def test_audit_dense_oracle_control_self_validates():
    # an entry whose declared dense shape the xla oracle never materializes
    # must report the CHECK as broken instead of silently passing
    import jax
    import jax.numpy as jnp

    from tools.analysis import jaxpr_audit
    from tools.analysis.entrypoints import entry

    e = entry(name="ctl.no_gather",
              target="repro.kernels.ops:paged_flash_decode",
              fn=lambda x: x * 2.0,
              args=(jax.ShapeDtypeStruct((4,), jnp.float32),),
              dense_shapes=((2, 8, 2, 4),))
    findings = jaxpr_audit.audit_entry(e)
    assert [f.rule for f in findings] == ["no-dense-gather"]
    assert "positive control failed" in findings[0].message


def test_audit_cli_fixture_and_cache(tmp_path, capsys):
    rc = main(["--audit", "--audit-registry",
               str(FIXTURES / "bad_audit" / "registry.py")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "[no-host-sync]" in out and "[donation-honored]" in out
    assert "violation(s)" in out

    # cache round-trip: a recorded clean digest short-circuits, a different
    # digest does not
    from tools.analysis import jaxpr_audit
    cache = tmp_path / "audit_cache.json"
    jaxpr_audit.write_cache(cache, "abc123")
    assert jaxpr_audit.cached_ok(cache, "abc123")
    assert not jaxpr_audit.cached_ok(cache, "def456")
    assert not jaxpr_audit.cached_ok(tmp_path / "missing.json", "abc123")
    d1 = jaxpr_audit.tree_digest(REPO)
    assert d1 == jaxpr_audit.tree_digest(REPO)   # deterministic


def test_audit_real_registry_clean_under_both_modes():
    # the acceptance gate: every registered hot-path entry point traces
    # under both kernel modes with zero violations (donation honored, no
    # host syncs, no dense pool gathers, dtype policy kept, variant
    # budgets exact)
    from tools.analysis import jaxpr_audit
    from tools.analysis.entrypoints import build_registry

    registry, drift = build_registry()
    assert drift == []
    names = {e.name for e in registry}
    assert {"ops.paged_flash_decode", "engine.grouped_ffn",
            "engine.attn_paged", "engine.commit_scatter_hi",
            "model.decode_step_paged", "model.prefill_chunk_paged",
            "kv.copy_page"} <= names
    findings = jaxpr_audit.run_audit(registry, drift=drift)
    assert findings == [], [f.render() for f in findings]


# ------------------------------------------------ runtime TSan-lite guard
def test_instrumented_cache_fires_off_thread():
    from repro.core.cache_guard import InstrumentedCache, ThreadConfinementError

    c = InstrumentedCache(2, 2, 2)
    c.new_sequence()
    c.advance_token()
    assert ("new_sequence", threading.current_thread().name) in c.mutation_log

    caught = []

    def rogue():
        try:
            c.admit((0, 0), "hi", 1.0)
        except ThreadConfinementError as e:
            caught.append(e)

    t = threading.Thread(target=rogue, name="rogue-stager")
    t.start()
    t.join()
    assert caught and "rogue-stager" in str(caught[0])


def test_suite_runs_engines_under_instrumented_cache():
    # the autouse conftest fixture patches the engine's constructor binding,
    # so every OffloadEngine built by the staging/engine suites gets the
    # runtime race detector
    from repro.core import engine as engine_mod
    from repro.core.cache_guard import InstrumentedCache

    assert engine_mod.MultidimensionalCache is InstrumentedCache
    cache = engine_mod.MultidimensionalCache(2, 2, 2)
    assert isinstance(cache, InstrumentedCache)
    assert hasattr(cache, "mutation_log")
