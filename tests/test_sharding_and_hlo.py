"""Sharding rules + HLO analyzer tests (pure logic; mesh built on 1 CPU
device with size-1 axes where needed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import hlo_analysis as ha
from repro.launch import sharding as sh


@pytest.fixture(scope="module")
def mesh1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_fit_spec_drops_nondivisible(mesh1):
    # with axis size 1 everything divides; emulate via explicit helper math
    spec = sh.fit_spec(mesh1, (8, 3), P("data", "model"))
    assert spec == P("data", "model")


def test_param_rules_cover_all_paths(mesh1):
    from repro.configs import get_config, smoke_variant
    from repro.models import build_model
    for arch in ("mixtral-8x7b", "jamba-v0.1-52b", "whisper-tiny",
                 "deepseek-v2-236b", "mamba2-780m"):
        cfg = smoke_variant(get_config(arch))
        m = build_model(cfg)
        shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        tree = sh.param_shardings(mesh1, shapes)
        # every leaf got a NamedSharding
        for leaf in jax.tree_util.tree_leaves(tree):
            assert hasattr(leaf, "spec")


def test_decode_mode_flips_expert_sharding(mesh1):
    spec_t = sh.spec_for_param("blocks/0/ffn/experts/wi", (4, 64, 128),
                               mesh1, "data", "model", mode="train")
    spec_d = sh.spec_for_param("blocks/0/ffn/experts/wi", (4, 64, 128),
                               mesh1, "data", "model", mode="decode")
    assert spec_t == P("model", "data", None)
    assert spec_d == P("model", None, "data")


def test_cache_shardings_structure(mesh1):
    from repro.configs import get_config, smoke_variant
    from repro.models import build_model
    cfg = smoke_variant(get_config("jamba-v0.1-52b"))
    m = build_model(cfg)
    cache = jax.eval_shape(lambda: m.init_cache(4, 64))
    tree = sh.cache_shardings(mesh1, cache, 4)
    for leaf in jax.tree_util.tree_leaves(tree):
        assert hasattr(leaf, "spec")


# ------------------------------------------------------------- hlo analysis
def test_hlo_flops_exact_for_scan():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    a = ha.analyze(c.as_text())
    assert a["flops"] == 2 * 64**3 * 8


def test_hlo_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    a = ha.analyze(c.as_text())
    assert a["flops"] == 2 * 32**3 * 15


def test_hlo_bytes_nonzero_and_shape_parse():
    assert ha._nbytes("f32[4,4]{1,0}") == 64
    assert ha._nbytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert ha._nbytes("bf16[10]") == 20
    d = ha._parse_def("%x.1 = f32[256,256]{1,0} parameter(0), metadata={}")
    assert d == ("x.1", "f32[256,256]{1,0}", "parameter", "0")


def test_collective_parse_from_text():
    fake = """
HloModule m
ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(%a), replica_groups={}, to_apply=%add
}
"""
    from repro.launch.roofline import collective_bytes
    c = collective_bytes(fake)
    assert c["all-reduce"] == 32
