"""Paged KV cache + chunked prefill admission tests: paged-vs-dense logits
parity through both backends, chunked-prefill equivalence to one-shot
prefill, page reclamation on mid-flight release with immediate re-admission,
pool-exhaustion behavior (queued request waits, never crashes), and the new
stats fields (kv_pages_used / kv_page_fraction / admission_wait_s)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine, Thresholds
from repro.models import build_model
from repro.models.kv_pages import PagedKVPool, PagePoolExhausted
from repro.serving.api import DenseBackend, HobbitBackend, generate
from repro.serving.batching import BatchingServer, Request


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=2, d_model=64,
                        vocab=128)
    # ample capacity: MoE token drops would otherwise differ between chunked
    # and one-shot prefill (capacity is computed per call)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _mk(kind, m, params, *, paged, **kw):
    if kind == "dense":
        return DenseBackend(m, params, paged=paged, **kw)
    ecfg = EngineConfig(hi_slots=16, lo_slots=8,
                        thresholds=Thresholds(0.6, 0.9))
    if paged:
        ecfg = dataclasses.replace(
            ecfg, paged_kv=True,
            kv_page_size=kw.get("page_size", 64),
            kv_pages=kw.get("kv_pages"),
            prefill_chunk=kw.get("prefill_chunk", 64))
    return HobbitBackend(OffloadEngine(m, params, ecfg))


# ---------------------------------------------------------------- parity
@pytest.mark.parametrize("kind", ["dense", "hobbit"])
def test_paged_vs_dense_logits_parity(setup, kind):
    """Per-step decode logits under the paged layout equal the dense-layout
    run on both backends (page size chosen so slots span several pages)."""
    m, params = setup
    prompts = np.random.default_rng(0).integers(0, 128, (2, 9))
    teacher = np.random.default_rng(1).integers(0, 128, (4, 2))
    d = _mk(kind, m, params, paged=False)
    p = _mk(kind, m, params, paged=True, page_size=4, prefill_chunk=5)
    d.start_batch(2, 32)
    p.start_batch(2, 32)
    lg_d, lg_p = d.prefill(prompts), p.prefill(prompts)
    np.testing.assert_allclose(lg_d, lg_p, atol=1e-4)
    for t in range(4):
        np.testing.assert_allclose(d.step(teacher[t]), p.step(teacher[t]),
                                   atol=1e-4)


@pytest.mark.parametrize("kind", ["dense", "hobbit"])
def test_paged_generate_tokens_equal(setup, kind):
    m, params = setup
    prompts = np.random.default_rng(2).integers(0, 128, (2, 7))
    res_d = generate(_mk(kind, m, params, paged=False), prompts, 6)
    res_p = generate(_mk(kind, m, params, paged=True, page_size=4,
                         prefill_chunk=3), prompts, 6)
    np.testing.assert_array_equal(res_d.tokens, res_p.tokens)


def test_paged_decode_pallas_kernel_matches_xla_path(setup, monkeypatch):
    """Engine-level kernel parity: decode through the table-driven paged
    flash kernel (REPRO_KERNEL_MODE=pallas -> interpret mode on CPU) equals
    the gathered-oracle XLA path per step, and the dispatch counter proves
    the kernel ran."""
    m, params = setup
    prompts = np.random.default_rng(8).integers(0, 128, (2, 9))
    teacher = np.random.default_rng(9).integers(0, 128, (4, 2))
    outs = {}
    for mode in ("xla", "pallas"):
        monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
        be = _mk("hobbit", m, params, paged=True, page_size=4,
                 prefill_chunk=5)
        be.start_batch(2, 32)
        lgs = [be.prefill(prompts)]
        for t in range(4):
            lgs.append(be.step(teacher[t]))
        outs[mode] = np.stack(lgs)
        if mode == "pallas":
            disp = be.engine.stats()["kernel_dispatch"]
            assert disp.get("paged_flash_decode.pallas_interpret", 0) > 0
    np.testing.assert_allclose(outs["pallas"], outs["xla"], atol=1e-4)


def test_chunked_prefill_matches_oneshot(setup):
    """Admission logits are identical whether the prompt prefills in one
    chunk or many (chunk boundaries are invisible to the attention math)."""
    m, params = setup
    prompt = np.random.default_rng(3).integers(0, 128, 11)
    outs = []
    for chunk in (32, 11, 4, 3):
        be = DenseBackend(m, params, paged=True, page_size=4,
                          prefill_chunk=chunk)
        be.start_batch(1, 32)
        be.release(0)
        outs.append(be.join(0, prompt))
    ref = DenseBackend(m, params)
    ref.start_batch(1, 32)
    ref.release(0)
    lg_ref = ref.join(0, prompt)
    for lg in outs:
        np.testing.assert_allclose(lg, outs[0], atol=1e-5)
    np.testing.assert_allclose(outs[0], lg_ref, atol=1e-4)


# ------------------------------------------------------------ reclamation
def test_release_reclaims_pages_and_readmits(setup):
    """Mid-flight release returns a slot's pages to the pool and a new
    request admitted into the same slot immediately reuses them, decoding
    exactly like its isolated run."""
    m, params = setup
    rng = np.random.default_rng(4)
    pa, pb = rng.integers(0, 128, 9), rng.integers(0, 128, 6)
    be = DenseBackend(m, params, paged=True, page_size=4, kv_pages=8,
                      prefill_chunk=4)
    be.start_batch(2, 16)
    for s in (0, 1):
        be.release(s)
    assert be.kv.pages_used == 0
    be.join(0, pa)                      # 9 tokens -> 3 pages (reserve 16 -> 4)
    used_a = be.kv.pages_used
    assert used_a == 3 and be.stats()["kv_page_fraction"] == 3 / 8
    be.release(0)
    assert be.kv.pages_used == 0        # reclaimed, reservation dropped
    lg = be.join(0, pb)                 # immediate re-admission, same slot
    toks = [int(np.argmax(lg))]
    for _ in range(4):
        vec = np.zeros((2,), np.int32)
        vec[0] = toks[-1]
        lg = be.step(vec)
        toks.append(int(np.argmax(lg[0])))
    want = generate(DenseBackend(m, params), pb[None], 5, max_len=16)
    np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                  want.tokens[0, len(pb):])


def test_pool_exhaustion_raises_without_reservation():
    """ensure() without an admission reservation raises PagePoolExhausted
    instead of corrupting a neighbour's pages."""
    pool = PagedKVPool(num_layers=1, num_kv_heads=1, head_dim=4,
                       dtype="float32", num_pages=2, page_size=4)
    pool.start(2)
    pool.ensure(0, 8)                   # slot 0 takes both pages
    with pytest.raises(PagePoolExhausted):
        pool.ensure(1, 4)
    pool.release(0)
    pool.ensure(1, 4)                   # reclaimed pages are reusable
    assert pool.pages_used == 1


def test_reservation_blocks_new_admission():
    """Admission reservations protect an in-flight request's decode budget:
    can_reserve must refuse a second request that would starve the first."""
    pool = PagedKVPool(num_layers=1, num_kv_heads=1, head_dim=4,
                       dtype="float32", num_pages=5, page_size=4)
    pool.start(2)
    pool.reserve(0, 13)                 # 4 pages promised
    pool.ensure(0, 5)                   # only 2 drawn so far; 2 still owed
    assert not pool.can_reserve(8)      # 2 pages would overlap the promise
    assert pool.can_reserve(4)          # 1 page genuinely free
    pool.ensure(0, 13)                  # the promise is honored
    assert pool.pages_used == 4


def test_unreserved_growth_cannot_steal_reserved_pages():
    """Regression: ensure() must not hand out pages another slot's
    reservation promises — the unreserved grower raises PagePoolExhausted
    at its own call site, and the reserved slot still grows to its full
    budget afterwards (the reservation contract)."""
    pool = PagedKVPool(num_layers=1, num_kv_heads=1, head_dim=4,
                       dtype="float32", num_pages=4, page_size=4)
    pool.start(2)
    pool.reserve(0, 16)                 # all 4 pages promised to slot 0
    with pytest.raises(PagePoolExhausted):
        pool.ensure(1, 4)               # unreserved growth would steal one
    pool.ensure(0, 16)                  # the promise is honored in full
    assert pool.pages_used == 4


def test_growth_past_own_reservation_cannot_steal():
    """A slot growing past its own reservation competes as unreserved: it
    must raise rather than take a page promised to a neighbour, and the
    neighbour's reservation stays drawable."""
    pool = PagedKVPool(num_layers=1, num_kv_heads=1, head_dim=4,
                       dtype="float32", num_pages=4, page_size=4)
    pool.start(2)
    pool.reserve(0, 8)                  # 2 pages promised to slot 0
    pool.reserve(1, 8)                  # 2 pages promised to slot 1
    pool.ensure(0, 8)                   # slot 0 draws its own 2
    with pytest.raises(PagePoolExhausted):
        pool.ensure(0, 12)              # a 3rd page would rob slot 1
    pool.ensure(1, 8)                   # slot 1's promise intact
    assert pool.pages_used == 4


# ------------------------------------------------------- scheduler behavior
@pytest.mark.parametrize("kind", ["dense", "hobbit"])
def test_exhausted_pool_queues_request_until_pages_free(setup, kind):
    """A request that does not fit the remaining pool waits in the queue
    (no crash) and is admitted as soon as a retirement frees pages; every
    request still completes with its isolated-run output."""
    m, params = setup
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, 128, 8),
                    max_new_tokens=4) for i in range(3)]
    prompts = [np.array(r.prompt) for r in reqs]
    # pool of 8 4-token pages; each request needs ceil((8+4+1)/4)=4 pages,
    # so only two fit concurrently — rid=2 must wait for a retirement
    be = _mk(kind, m, params, paged=True, page_size=4, kv_pages=8,
             prefill_chunk=4)
    srv = BatchingServer(be, max_batch=3, max_len=16)
    for r in reqs:
        srv.submit(r)
    srv.run()
    assert len(srv.completed) == 3
    first_retire = min(e[3] for e in srv.events if e[0] == "retire")
    late_admits = [e for e in srv.events if e[0] == "admit"
                   and e[3] >= first_retire]
    assert late_admits, "third request should admit only after pages freed"
    for i, p in enumerate(prompts):
        got = next(r for r in srv.completed if r.rid == i)
        want = generate(_mk(kind, m, params, paged=False), p[None], 4,
                        max_len=16)
        np.testing.assert_array_equal(got.output, want.tokens[0, len(p):])
    st = srv.stats()
    assert st["admission_wait_s"] >= st["mean_queue_wait_s"] >= 0.0
    assert st["mean_occupancy"] > 0


def test_oversized_request_raises_not_hangs(setup):
    """A request larger than the entire pool can never be served: the
    scheduler raises instead of spinning forever."""
    m, params = setup
    be = DenseBackend(m, params, paged=True, page_size=4, kv_pages=2,
                      prefill_chunk=4)
    srv = BatchingServer(be, max_batch=2, max_len=16)
    srv.submit(Request(rid=0, prompt=np.arange(10) % 128, max_new_tokens=4))
    with pytest.raises(RuntimeError, match="cannot hold"):
        srv.run()


def test_request_wider_than_page_table_rejected_cleanly(setup):
    """A request that fits the pool's page count but exceeds the per-slot
    page-table width (max_len bound) is rejected by the same clean
    RuntimeError — never a mid-run crash that loses in-flight requests."""
    m, params = setup
    # pool of 12 pages but max_len=16 -> only 4 pages per slot
    be = DenseBackend(m, params, paged=True, page_size=4, kv_pages=12,
                      prefill_chunk=4)
    srv = BatchingServer(be, max_batch=2, max_len=16)
    srv.submit(Request(rid=0, prompt=np.arange(18) % 128, max_new_tokens=4))
    with pytest.raises(RuntimeError, match="cannot hold"):
        srv.run()


def test_chunked_admission_interleaves_with_decode(setup):
    """A long prompt admitted mid-flight prefills in chunks across several
    scheduler iterations while the in-flight request keeps decoding: its
    admit->join span covers decode steps, and the decoding request's output
    is unchanged."""
    m, params = setup
    rng = np.random.default_rng(6)
    long_p = rng.integers(0, 128, 20)
    short_p = rng.integers(0, 128, 4)
    be = DenseBackend(m, params, paged=True, page_size=4, kv_pages=16,
                      prefill_chunk=4)
    srv = BatchingServer(be, max_batch=2, max_len=32, admit_k=2)
    srv.submit(Request(rid=0, prompt=short_p, max_new_tokens=10))
    srv.submit(Request(rid=1, prompt=long_p, max_new_tokens=3))
    srv.run()
    assert len(srv.completed) == 2
    ev = {(e[0], e[2]): e[3] for e in srv.events}
    # the long prompt's chunked admission spans >= 20/4 scheduler steps
    assert ev[("join", 1)] - ev[("admit", 1)] >= 4
    want = generate(DenseBackend(m, params), short_p[None], 10, max_len=32)
    got = next(r for r in srv.completed if r.rid == 0)
    np.testing.assert_array_equal(got.output, want.tokens[0, len(short_p):])


# ------------------------------------------------------- prefix sharing
def test_prefix_sharing_differential_three_ways(setup):
    """The same shared-prefix workload produces fp-identical decode logits
    through paged-with-sharing, paged-without-sharing and the dense layout:
    aliased pages, COW copies and write-dropped re-feeds are invisible to
    the math.  Covers a mid-page divergence (forces a partial-page COW) and
    a page-boundary fork (pure aliasing, no COW)."""
    m, params = setup
    rng = np.random.default_rng(10)
    base = rng.integers(0, 128, 10)         # 2 full 4-token pages + 2 extra
    # v1 extends base past its end: aliases all 10 tokens (incl. the shared
    # partial page) and its first divergent write COWs that page
    v1 = np.concatenate([base, rng.integers(0, 128, 3)])
    # v2 forks exactly at a page boundary: pure full-page aliasing, no COW
    v2 = np.concatenate([base[:8], [(base[8] + 1) % 128],
                         rng.integers(0, 128, 2)])
    teacher = rng.integers(0, 128, (4, 3))
    outs = {}
    for mode in ("sharing", "plain", "dense"):
        be = DenseBackend(m, params, paged=mode != "dense", page_size=4,
                          prefill_chunk=4, prefix_sharing=mode == "sharing")
        be.start_batch(3, 32)
        for s in range(3):
            be.release(s)
        lgs = [be.join(s, p) for s, p in enumerate((base, v1, v2))]
        for t in range(4):
            lgs.append(be.step(teacher[t]).reshape(-1))
        outs[mode] = np.concatenate([np.asarray(x).reshape(-1) for x in lgs])
        if mode == "sharing":
            st = be.kv.stats()
            # v1 aliases base whole (10); v2 its first two pages (8).  A
            # prompt diverging INSIDE the partial page's written tokens
            # would alias only the full pages (a page is aliased as a
            # unit); v1 instead extends base, so its divergence starts at
            # base's end and its first write COWs the shared partial page.
            assert st["prefix_hit_tokens"] == 10 + 8
            assert st["cow_copies"] >= 1
            assert st["aliased_page_fraction"] > 0
        elif mode == "plain":
            st = be.kv.stats()
            assert st["prefix_hit_tokens"] == 0 and st["cow_copies"] == 0
    np.testing.assert_allclose(outs["sharing"], outs["plain"], atol=1e-4)
    np.testing.assert_allclose(outs["sharing"], outs["dense"], atol=1e-4)


def test_identical_prompt_aliases_whole_prefix(setup):
    """Length-0 divergence: an identical prompt aliases every page (full
    pages AND the trailing partial), re-prefills nothing but the final
    token's logits, and pays its first COW only when decode appends into
    the shared partial page.  Logits stay equal to the unshared run."""
    m, params = setup
    rng = np.random.default_rng(11)
    pa = rng.integers(0, 128, 10)
    teacher = rng.integers(0, 128, (3, 2))
    runs = {}
    for sharing in (True, False):
        be = DenseBackend(m, params, paged=True, page_size=4,
                          prefill_chunk=4, prefix_sharing=sharing)
        be.start_batch(2, 32)
        for s in range(2):
            be.release(s)
        lg0, lg1 = be.join(0, pa), be.join(1, pa)
        np.testing.assert_allclose(lg0, lg1, atol=1e-5)
        if sharing:
            assert be.kv.stats()["prefix_hit_tokens"] == len(pa)
            assert be.kv.pages_used == 3        # not 6: all 3 pages shared
            assert be.kv.aliased_pages == 3
        steps = [be.step(teacher[t]) for t in range(3)]
        if sharing:
            # both slots' first append hits the shared partial page: one
            # COW (the other writer is by then the sole referent)
            assert be.kv.stats()["cow_copies"] == 1
        runs[sharing] = np.stack([np.asarray(s) for s in steps])
    np.testing.assert_allclose(runs[True], runs[False], atol=1e-4)


def test_prefix_sharing_pallas_kernel_parity(setup, monkeypatch):
    """Decode through the Pallas paged flash kernel over *aliased* page
    tables (two slots pointing at shared physical pages) matches the XLA
    gather path step for step — sharing needs no kernel changes."""
    m, params = setup
    rng = np.random.default_rng(12)
    pa = rng.integers(0, 128, 9)
    pb = np.concatenate([pa[:8], [(pa[8] + 1) % 128], rng.integers(0, 128, 2)])
    teacher = rng.integers(0, 128, (3, 2))
    outs = {}
    for mode in ("xla", "pallas"):
        monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
        be = _mk("hobbit", m, params, paged=True, page_size=4,
                 prefill_chunk=5)
        be.start_batch(2, 32)
        for s in range(2):
            be.release(s)
        lgs = [be.join(0, pa), be.join(1, pb)]
        for t in range(3):
            lgs.append(be.step(teacher[t]).reshape(-1))
        outs[mode] = np.concatenate([np.asarray(x).reshape(-1) for x in lgs])
        st = be.engine.stats()
        assert st["prefix_hit_tokens"] > 0, "workload must actually share"
        if mode == "pallas":
            disp = st["kernel_dispatch"]
            assert disp.get("paged_flash_decode.pallas_interpret", 0) > 0
    np.testing.assert_allclose(outs["pallas"], outs["xla"], atol=1e-4)


@pytest.mark.parametrize("kind", ["dense", "hobbit"])
def test_scheduler_shared_prefix_outputs_unchanged(setup, kind):
    """Continuous batching with a common system prompt: every request's
    output equals its isolated dense run whether sharing is on or off, and
    the sharing run reports prefix hits (admit_k=1 so each prompt is in
    the trie before the next admission matches it)."""
    m, params = setup
    rng = np.random.default_rng(13)
    sys_prompt = rng.integers(0, 128, 8)
    prompts = [np.concatenate([sys_prompt, rng.integers(0, 128, 3 + i)])
               for i in range(3)]
    for sharing in (True, False):
        be = _mk(kind, m, params, paged=True, page_size=4, prefill_chunk=4)
        if kind == "dense":
            be.prefix_sharing = sharing
        else:
            be.engine.ecfg = dataclasses.replace(
                be.engine.ecfg, prefix_sharing=sharing)
        srv = BatchingServer(be, max_batch=3, max_len=32, admit_k=1)
        for i, p in enumerate(prompts):
            srv.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        srv.run()
        assert len(srv.completed) == 3
        hits = srv.stats()["backend"].get("prefix_hit_tokens", 0)
        assert (hits >= 2 * len(sys_prompt)) == sharing
        for i, p in enumerate(prompts):
            got = next(r for r in srv.completed if r.rid == i)
            want = generate(_mk(kind, m, params, paged=False), p[None], 4,
                            max_len=32)
            np.testing.assert_array_equal(got.output,
                                          want.tokens[0, len(p):])


def test_second_release_of_shared_slot_is_noop(setup):
    """Model-level double release: releasing a retired slot again must not
    free a sharer's still-referenced pages out from under it (the logits of
    the surviving slot are unchanged afterwards)."""
    m, params = setup
    rng = np.random.default_rng(14)
    pa = rng.integers(0, 128, 9)
    be = DenseBackend(m, params, paged=True, page_size=4, prefill_chunk=4)
    be.start_batch(2, 32)
    for s in range(2):
        be.release(s)
    be.join(0, pa)
    lg1 = be.join(1, pa)                # aliases slot 0's pages
    be.release(0)
    used = be.kv.pages_used
    before = be.kv.refcount.copy()
    be.release(0)                       # double release: clean no-op
    assert be.kv.pages_used == used
    np.testing.assert_array_equal(be.kv.refcount, before)
    # the sharer still decodes correctly over its (now exclusive) pages
    toks = [int(np.argmax(lg1))]
    vec = np.zeros((2,), np.int32)
    for _ in range(3):
        vec[1] = toks[-1]
        lg = be.step(vec)
        toks.append(int(np.argmax(lg[1])))
    want = generate(DenseBackend(m, params), pa[None], 4, max_len=32)
    np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                  want.tokens[0, len(pa):])


def test_backend_stats_have_kv_fields(setup):
    """kv_pages_used / kv_pages_total / kv_page_fraction are part of the
    uniform stats contract on both layouts (zeros when dense)."""
    m, params = setup
    d = DenseBackend(m, params)
    d.start_batch(1, 8)
    s = d.stats()
    assert s["kv_pages_total"] == 0 and s["kv_page_fraction"] == 0.0
    e = _mk("hobbit", m, params, paged=True, page_size=4)
    e.start_batch(1, 8)
    e.prefill(np.random.default_rng(7).integers(0, 128, (1, 5)))
    s = e.stats()
    assert s["kv_pages_total"] == 2 and s["kv_pages_used"] == 2
    assert s["kv_page_fraction"] == 1.0
