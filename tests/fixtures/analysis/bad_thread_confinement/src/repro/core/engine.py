"""Fixture: engine wiring the broken loader to the cache."""

from repro.core.cache import MultidimensionalCache
from repro.core.loader import BrokenStagingEngine


class OffloadEngine:
    def __init__(self):
        self.cache = MultidimensionalCache()
        self.scheduler = BrokenStagingEngine(self.cache)
