"""Fixture: fleet heat map whose mutators are main-thread-owned."""


class FleetHeat:
    def __init__(self):
        self._heat = {}      # owner: main-thread
        self._max = 0.0      # owner: main-thread

    # owner: main-thread
    def observe(self, key, weight=1.0):
        h = self._heat.get(key, 0.0) + weight
        self._heat[key] = h
        self._max = max(self._max, h)

    # owner: main-thread
    def retire_request(self):
        self._heat = {k: v * 0.9 for k, v in self._heat.items()}
