"""Fixture: mini expert cache with main-thread-owned metadata."""


class MultidimensionalCache:
    def __init__(self):
        self.pinned = set()         # owner: main-thread
        self.slots = {}

    # owner: main-thread
    def admit(self, eid):
        self.slots[eid] = True

    # owner: main-thread
    def pin(self, eid):
        self.pinned.add(eid)
