"""Fixture: staging loader whose background worker touches cache metadata.

Reproduces the PR 4 review bug — the eviction decision (`cache.admit`) made
at *copy* time on the stream executor instead of at *submit* time on the
main thread — plus an off-thread mutation of an owned queue, an off-thread
rebind, and the PR-9 variant: feeding the fleet heat map from the stream
executor instead of the routing (main) thread.
"""

from concurrent.futures import ThreadPoolExecutor

from repro.core.fleet_heat import FleetHeat


class BrokenStagingEngine:
    def __init__(self, cache):
        self.cache = cache
        self.fleet = FleetHeat()
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = []          # owner: main-thread

    def submit(self, task):
        self._pending.append(task)          # fine: caller thread
        self._pool.submit(self._stage_one, task)

    def _stage_one(self, task):
        self.cache.admit(task)              # BAD: eviction at copy time
        self.fleet.observe(task)            # BAD: fleet heat fed off-thread
        self._pending.append(task)          # BAD: owned queue, executor thread
        self._finish(task)

    def _finish(self, task):
        self.cache.pin(task)                # BAD: reached transitively
        self._pending = []                  # BAD: owned attr rebound
