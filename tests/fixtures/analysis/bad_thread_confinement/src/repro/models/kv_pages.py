"""Fixture: paged-KV pool whose page reclamation runs on the copy stream.

Reproduces the prefix-sharing hazard class: refcounts, the free list and
the admission reservations are main-thread-owned (the scheduler reads them
between every step), so deciding a page's fate at copy-completion time on
the executor races concurrent admissions — a page can be re-drawn while a
stale table still references it.
"""

from concurrent.futures import ThreadPoolExecutor


class BrokenPagedKVPool:
    def __init__(self, num_pages):
        self.refcount = [0] * num_pages     # owner: main-thread
        self.free = list(range(num_pages))  # owner: main-thread
        self.owned = {}
        self._pool = ThreadPoolExecutor(max_workers=1)

    def release_async(self, slot):
        # BUG: reclamation decided when the copy completes, on the executor,
        # instead of on the scheduler thread at release time
        self._pool.submit(self._reclaim, slot)

    def _reclaim(self, slot):
        for pid in self.owned.get(slot, []):
            self.refcount[pid] -= 1         # BAD: owned refcount, executor
            if self.refcount[pid] == 0:
                self.free.append(pid)       # BAD: owned free list, executor
        self._drop_reservation(slot)

    def _drop_reservation(self, slot):
        self.reserve(slot, 0)               # BAD: reached transitively

    # owner: main-thread
    def reserve(self, slot, tokens):
        self.owned[slot] = [tokens]
