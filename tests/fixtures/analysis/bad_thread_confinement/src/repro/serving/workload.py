"""Fixture: SLO ordering helpers (clean — scanned for coverage only)."""


# owner: main-thread
def effective_priority(priority, submitted_at, now, aging_s=10.0):
    return float(priority) + max(0.0, now - submitted_at) / aging_s


# owner: main-thread
def slo_urgency(priority, submitted_at, ttft_slo_s, now, aging_s=10.0):
    slack = ((submitted_at + ttft_slo_s - now) if ttft_slo_s is not None
             else 1e12 + submitted_at - now)
    return (-effective_priority(priority, submitted_at, now, aging_s), slack)
