"""Fixture: protocol + a backend that drifted from it."""

from typing import Optional, Protocol


class InferenceBackend(Protocol):
    model: object

    def start_batch(self, batch: int, max_len: int) -> None: ...

    def step(self, tokens) -> object: ...

    def release(self, slot: int) -> None: ...

    def join_begin(self, slot: int, prompt,
                   reserve_tokens: Optional[int] = None) -> None: ...

    def pause(self, slot: int) -> dict: ...

    def resume(self, slot: int, snapshot: dict) -> None: ...

    def stats(self) -> dict: ...


class BrokenBackend:
    """Missing release() and pause(); step() and resume() renamed their
    parameters; join_begin() made an optional protocol parameter required;
    never assigns self.model."""

    def __init__(self, cfg):
        self.cfg = cfg

    def start_batch(self, batch, max_len):
        pass

    def step(self, toks):                       # signature-mismatch
        return toks

    def join_begin(self, slot, prompt, reserve_tokens):  # optional->required
        pass

    def resume(self, slot, snap):               # signature-mismatch
        pass

    def stats(self):
        return {}
