"""Fixture: decode hot path with host syncs and an un-donated pool jit."""

import jax
import jax.numpy as jnp
import numpy as np


class Model:
    def decode_step(self, params, cache, tokens):
        probs = jnp.ones((4,))
        best = probs.item()                  # BAD: host sync inside jit
        return best, cache

    def decode_step_paged(self, params, k_pages, v_pages, tokens):
        x = np.asarray(tokens)               # BAD: device->host transfer
        return jnp.asarray(x), k_pages, v_pages

    def prefill_chunk_paged(self, params, k_pages, v_pages, tokens):
        return k_pages, v_pages


def make_backend(model):
    step = jax.jit(model.decode_step)
    paged = jax.jit(model.decode_step_paged)     # BAD: pools not donated
    prefill = jax.jit(model.prefill_chunk_paged, donate_argnums=(1, 2))
    return step, paged, prefill
