"""Fixture stub (keeps the checker's default file set resolvable)."""
