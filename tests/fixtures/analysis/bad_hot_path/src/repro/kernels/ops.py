"""Fixture: clean kernel-tier dispatch wrapper (no syncs, nothing jitted)."""


def paged_flash_decode(q, pages_k, pages_v, table, lengths):
    return q
