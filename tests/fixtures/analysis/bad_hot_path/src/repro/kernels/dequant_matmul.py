"""Fixture: clean grouped dequant kernel wrappers (entry-point presence)."""


def grouped_dequant_matmul_pallas(x, data, scale):
    return x


def grouped_dequant_combine_pallas(x, data, scale, rows, weights):
    return x
