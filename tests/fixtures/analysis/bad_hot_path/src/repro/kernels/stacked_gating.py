"""Fixture: clean fused gating top-k wrapper (entry-point presence only)."""


def gating_topk_pallas(x, gates):
    return x
