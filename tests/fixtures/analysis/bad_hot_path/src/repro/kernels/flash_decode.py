"""Fixture: clean paged flash-decode wrapper (entry-point presence only)."""


def paged_flash_decode_pallas(q, pages_k, pages_v, table, lengths):
    return q
