"""Fixture: jnp reference oracles placeholder (no jit roots, no syncs)."""


def paged_flash_decode_ref(q, pages_k, pages_v, table, lengths):
    return q
