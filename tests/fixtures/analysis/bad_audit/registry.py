"""Known-bad audit registry: one entry per auditor rule, each violating
exactly that rule, plus a suppressed entry and a vanished-target entry.

Loaded by tests/test_analysis.py (and ``--audit-registry``) via
``tools.analysis.jaxpr_audit.load_registry_module`` to pin that every rule
is live — a rule regression shows up as a missing expected violation, the
same convention as the PR-6 known-bad fixture trees.
"""

import jax
import jax.numpy as jnp

from tools.analysis.entrypoints import PALLAS, XLA, entry

S = jax.ShapeDtypeStruct


def _host_sync_fn(x):
    # a registered jit smuggling a host callback into the decode path
    jax.debug.callback(lambda v: None, x)
    return x * 2.0


def _broken_donation_fn(pool, x):
    # `pool` is annotated donated by the entry below but never flows to any
    # output, so the lowering drops the donation (double-buffered pool)
    return x + 1.0


def _dense_gather_fn(pages, table):
    # materializes the dense (B, maxp*psz, H, hd) gathered cache view in
    # EVERY mode — the xla oracle control passes, the pallas tier does not
    b, maxp = table.shape
    _, psz, h, hd = pages.shape
    gathered = pages[table].reshape(b, maxp * psz, h, hd)
    return gathered.sum(axis=1)


def _upcast_fn(h, w):
    # silent f32 GEMM on bf16 activations, result immediately downcast back
    y = h.astype(jnp.float32) @ w.astype(jnp.float32)
    return y.astype(jnp.bfloat16)


def _quant_widen_fn(x, wq, scale):
    # dequantizes int8 weights with plain jnp ops outside any pallas kernel
    w = wq.astype(jnp.float32) * scale
    return x.astype(jnp.float32) @ w


def _identity_fn(x):
    return x + 1.0


REGISTRY = [
    entry(name="bad.host_sync",
          target="repro.kernels.ops:paged_flash_decode",
          fn=_host_sync_fn,
          args=(S((4,), jnp.float32),),
          modes=(XLA,)),
    entry(name="bad.donation",
          target="repro.models.kv_pages:_copy_page",
          fn=_broken_donation_fn,
          args=(S((4, 8), jnp.float32), S((3,), jnp.float32)),
          donate=(0,), pool_args=(0,),
          modes=(XLA,)),
    entry(name="bad.dense_gather",
          target="repro.kernels.ops:paged_flash_decode",
          fn=_dense_gather_fn,
          args=(S((4, 2, 2, 4), jnp.float32), S((2, 4), jnp.int32)),
          dense_shapes=((2, 8, 2, 4),)),
    entry(name="bad.upcast",
          target="repro.core.engine:OffloadEngine._grouped_ffn",
          fn=_upcast_fn,
          args=(S((4, 16), jnp.bfloat16), S((16, 16), jnp.bfloat16)),
          activation_dtype="bfloat16",
          modes=(XLA,)),
    entry(name="bad.quant_widen",
          target="repro.kernels.ops:grouped_dequant_combine",
          fn=_quant_widen_fn,
          args=(S((4, 8), jnp.bfloat16), S((8, 16), jnp.int8),
                S((8, 16), jnp.float32)),
          quant_dtypes=("int8",),
          modes=(PALLAS,)),
    entry(name="bad.variant_budget",
          target="repro.core.engine:OffloadEngine._scatter_fn",
          fn=_identity_fn,
          args=(S((2,), jnp.float32),),
          variant_builds=((S((2,), jnp.float32),),
                          (S((3,), jnp.float32),),
                          (S((5,), jnp.float32),)),
          variant_budget=1,
          modes=(XLA,)),
    entry(name="ok.suppressed",  # audit: ignore[no-host-sync]
          target="repro.kernels.ops:paged_flash_decode",
          fn=_host_sync_fn,
          args=(S((4,), jnp.float32),),
          modes=(XLA,)),
    entry(name="bad.vanished",
          target="repro.kernels.ops:this_got_renamed",
          fn=_identity_fn,
          args=(S((2,), jnp.float32),),
          modes=(XLA,)),
]
