"""Fixture: KV page-pool stats."""


class PagedKVPool:
    def stats(self):
        return {"kv_pages_used": 0}
