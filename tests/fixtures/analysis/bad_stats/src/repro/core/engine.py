"""Fixture: engine stats() missing a parity key + an undocumented key."""


class OffloadEngine:
    def stats(self):
        s = {
            "cache": self.cache.stats.to_dict(),
            "load_stall_s": 0.0,
            "overlap_fraction": 0.0,
            "per_stream_bytes": [],
            "issue_reorders": 0,
            "precision_downgrades": 0,
            "upgrades": 0,
            "upgrade_bytes": 0,
            "served_lo_expert_steps": 0,
            # "link_utilization" dropped -> engine-sim-parity
            "mystery_counter": 1,           # undocumented-stat
        }
        return s
