"""Fixture: staging stats() grew a counter the simulator never mirrors."""


class StagingEngine:
    def stats(self):
        return {
            "load_stall_s": 0.0,
            "overlap_fraction": 0.0,
            "per_stream_bytes": [],
            "issue_reorders": 0,
            "precision_downgrades": 0,
            "upgrades": 0,
            "upgrade_bytes": 0,
            "served_lo_expert_steps": 0,
            "link_utilization": 0.0,
            "copy_s": 0.0,
            "secret_local_counter": 3,      # staging-sim-drift
        }
