"""Fixture: cache stats snapshot."""


class CacheStats:
    def to_dict(self):
        return {"hits": 0, "misses": 0, "hit_ratio": 0.0}
