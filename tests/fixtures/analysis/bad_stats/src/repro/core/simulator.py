"""Fixture: simulator emitting the full parity-key set, plus a timeline
that dropped an SLO attainment counter (`preemptions`)."""


class ServingTimeline:
    def run(self, trace):
        return {
            "policy": "slo",
            "completed": 0,
            "slo_attainment": 1.0,
            "p99_ttft_s": 0.0,
        }


class OffloadSimulator:
    def run(self):
        return {
            "cache": {},
            "load_stall_s": 0.0,
            "overlap_fraction": 0.0,
            "per_stream_bytes": [],
            "issue_reorders": 0,
            "precision_downgrades": 0,
            "upgrades": 0,
            "upgrade_bytes": 0,
            "served_lo_expert_steps": 0,
            "link_utilization": 0.0,
        }
