"""Fixture: batching server stats passthrough."""


class BatchingServer:
    def stats(self):
        return {"requests": 0, "backend": {}}
