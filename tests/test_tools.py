"""Tests for the standalone CI gates: tools/check_bench.py (bench-regression
detection, invariant bounds, --update-baseline) and tools/check_docs.py
(markdown-link and docstring checks), on synthetic JSON / tmp trees."""

import json

import pytest

from tools import check_bench, check_docs


def _bench(rows):
    return {"rows": rows}


BASELINE = {
    "config": {"stall_regress_pct": 20.0, "stall_abs_slack_s": 0.01,
               "overlap_drop": 0.2},
    "metrics": {"contended_load_stall_s": 1.0,
                "prefetch_overlap_fraction": 0.8,
                "wallclock_load_stall_s": 0.5},
    "invariants": {"contended_stall_ratio": {"max": 1.0,
                                             "why": "multi-stream must win"},
                   "precision_downgrades": {"min": 1,
                                            "why": "budget path exercised"}},
}

GOOD_ROWS = {"contended_load_stall_s": 1.05,
             "prefetch_overlap_fraction": 0.75,
             "wallclock_load_stall_s": 0.6,
             "contended_stall_ratio": 0.8,
             "precision_downgrades": 3}


# ------------------------------------------------------------ check_bench
def test_compare_passes_within_slack():
    failures, table = check_bench.compare(_bench(GOOD_ROWS), BASELINE)
    assert failures == []
    assert {t[0] for t in table} == set(BASELINE["metrics"]) | set(
        BASELINE["invariants"])
    assert all(t[-1] == "ok" for t in table)


def test_compare_flags_stall_regression():
    rows = dict(GOOD_ROWS, contended_load_stall_s=1.5)   # +50% > +20%+slack
    failures, table = check_bench.compare(_bench(rows), BASELINE)
    assert any("contended_load_stall_s" in f and "regressed" in f
               for f in failures)
    assert ("contended_load_stall_s" in t[0] and t[-1] == "FAIL"
            for t in table)


def test_compare_flags_overlap_floor():
    rows = dict(GOOD_ROWS, prefetch_overlap_fraction=0.5)   # < 0.8 - 0.2
    failures, _ = check_bench.compare(_bench(rows), BASELINE)
    assert any("overlap_fraction" in f and "floor" in f for f in failures)


def test_compare_flags_invariant_min_and_max():
    rows = dict(GOOD_ROWS, contended_stall_ratio=1.3, precision_downgrades=0)
    failures, _ = check_bench.compare(_bench(rows), BASELINE)
    assert any("contended_stall_ratio" in f and "max" in f for f in failures)
    assert any("precision_downgrades" in f and "min" in f for f in failures)
    assert any("multi-stream must win" in f for f in failures)


def test_compare_flags_missing_metric():
    rows = {k: v for k, v in GOOD_ROWS.items()
            if k != "prefetch_overlap_fraction"}
    failures, table = check_bench.compare(_bench(rows), BASELINE)
    assert any("missing" in f for f in failures)
    assert any(t[-1] == "MISSING" for t in table)


def test_wallclock_stall_not_gated():
    # non-contended wall-clock stalls swing with runner load: informational
    rows = dict(GOOD_ROWS, wallclock_load_stall_s=50.0)
    failures, _ = check_bench.compare(_bench(rows), BASELINE)
    assert failures == []
    assert check_bench._gated("wallclock_load_stall_s") == ""
    assert check_bench._gated("contended_load_stall_s") == "stall"
    assert check_bench._gated("prefetch_overlap_fraction") == "overlap"


def test_markdown_table_marks_failures():
    failures, table = check_bench.compare(
        _bench(dict(GOOD_ROWS, contended_load_stall_s=9.9)), BASELINE)
    md = check_bench.markdown_table(table, failures)
    assert "| `contended_load_stall_s` |" in md and "FAIL" in md
    assert f"**{len(failures)} failure(s)**" in md


def test_update_baseline_keeps_config_and_invariants(tmp_path):
    bp = tmp_path / "baseline.json"
    bp.write_text(json.dumps(BASELINE))
    check_bench.update_baseline(_bench(GOOD_ROWS), bp)
    out = json.loads(bp.read_text())
    # only gated metrics are refreshed; bounds and config survive
    assert out["metrics"] == {"contended_load_stall_s": 1.05,
                              "prefetch_overlap_fraction": 0.75}
    assert out["invariants"] == BASELINE["invariants"]
    assert out["config"]["stall_abs_slack_s"] == 0.01


def test_bench_main_exit_codes(tmp_path):
    res = tmp_path / "results.json"
    bp = tmp_path / "baseline.json"
    res.write_text(json.dumps(_bench(GOOD_ROWS)))
    # missing baseline -> failure with a hint
    assert check_bench.main([str(res), "--baseline", str(bp)]) == 1
    # create it, then gate cleanly
    assert check_bench.main([str(res), "--baseline", str(bp),
                             "--update-baseline"]) == 0
    assert check_bench.main([str(res), "--baseline", str(bp)]) == 0
    # regress a gated metric -> nonzero
    bad = dict(GOOD_ROWS, contended_load_stall_s=9.9)
    res.write_text(json.dumps(_bench(bad)))
    assert check_bench.main([str(res), "--baseline", str(bp)]) == 1


# ------------------------------------------------------------ check_docs
@pytest.fixture
def docs_tree(tmp_path, monkeypatch):
    (tmp_path / "docs").mkdir()
    readme = tmp_path / "README.md"
    readme.write_text("[arch](docs/ARCH.md) and [web](https://x.invalid)\n")
    (tmp_path / "docs" / "ARCH.md").write_text("see [up](../README.md)\n")
    mod = tmp_path / "mod.py"
    mod.write_text('"""Module doc."""\n\n\n'
                   'def public():\n    """Doc."""\n\n\n'
                   'def _private():\n    pass\n')
    monkeypatch.setattr(check_docs, "ROOT", tmp_path)
    monkeypatch.setattr(check_docs, "MD_FILES",
                        [readme, tmp_path / "docs" / "ARCH.md"])
    monkeypatch.setattr(check_docs, "DOCSTRING_MODULES", [mod])
    return tmp_path


def test_docs_main_clean(docs_tree, capsys):
    assert check_docs.main() == 0
    assert "check_docs: OK" in capsys.readouterr().out


def test_docs_flags_broken_link(docs_tree):
    (docs_tree / "README.md").write_text("[gone](docs/NOPE.md)\n")
    errors = []
    check_docs.check_markdown_links(errors)
    assert errors and "broken link" in errors[0] and "NOPE.md" in errors[0]
    assert check_docs.main() == 1


def test_docs_flags_missing_docstring(docs_tree):
    mod = docs_tree / "mod.py"
    mod.write_text('"""Module doc."""\n\n\n'
                   'class Pool:\n    """Doc."""\n\n'
                   '    def stats(self):\n        return {}\n')
    errors = []
    check_docs.check_docstrings(errors)
    assert any("Pool.stats" in e for e in errors)
    assert check_docs.main() == 1


def test_docs_private_symbols_exempt(docs_tree):
    errors = []
    check_docs.check_docstrings(errors)
    assert errors == []     # _private carries no docstring yet passes
