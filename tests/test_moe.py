"""MoE layer tests: dispatch invariants (hypothesis), dense-oracle
equivalence, capacity drops, shared experts, quantized expert weights."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests collect-and-skip without hypothesis
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.configs import get_config, smoke_variant
from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
from repro.quant import quantize


def _cfg(e=4, k=2, cf=8.0):
    cfg = smoke_variant(get_config("mixtral-8x7b"), d_model=128)
    return dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, num_experts=e, top_k=k,
                                capacity_factor=cf))


def test_moe_matches_dense_oracle_when_capacity_ample():
    cfg = _cfg(cf=8.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 128)), jnp.float32)
    y, aux, r = moe_lib.moe_forward(p, x, cfg)
    y_ref, r_ref = moe_lib.moe_forward_dense_eval(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_reduce_output_norm():
    hi = _cfg(cf=8.0)
    lo = _cfg(cf=0.25)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), hi)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 64, 128)), jnp.float32)
    y_hi, _, _ = moe_lib.moe_forward(p, x, hi)
    y_lo, _, _ = moe_lib.moe_forward(p, x, lo)
    # drops zero-out contributions -> strictly less energy
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


@settings(max_examples=20, deadline=None)
@given(e=st.sampled_from([2, 4, 8]), k=st.integers(1, 2),
       t=st.integers(1, 40), seed=st.integers(0, 10_000))
def test_property_dispatch_indices(e, k, t, seed):
    mc = MoEConfig(num_experts=e, top_k=min(k, e), d_ff_expert=64)
    cap = moe_lib._capacity(t, mc)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, e, (t, mc.top_k)), jnp.int32)
    slot, keep = moe_lib.dispatch_indices(idx, mc, cap)
    slot, keep = np.asarray(slot), np.asarray(keep)
    # kept slots are unique and within range; expert of slot matches choice
    kept = slot[keep]
    assert len(np.unique(kept)) == len(kept)
    assert (kept < e * cap).all() and (kept >= 0).all()
    assert (kept // cap == np.asarray(idx)[keep]).all()
    # dropped slots all point at the trash row
    assert (slot[~keep] == e * cap).all()
    # per-expert occupancy never exceeds capacity
    occ = np.bincount(kept // cap, minlength=e)
    assert (occ <= cap).all()


def test_dispatch_token_mask_frees_capacity():
    """Dead tokens (inactive continuous-batching slots) must occupy no expert
    capacity: live tokens behind them in arrival order are never crowded out."""
    mc = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8)
    idx = jnp.zeros((12, 1), jnp.int32)          # every token -> expert 0
    cap = 8
    slot, keep = moe_lib.dispatch_indices(idx, mc, cap)
    keep = np.asarray(keep)[:, 0]
    assert keep[:8].all() and not keep[8:].any()  # unmasked: overflow drops
    # first 8 arrivals are dead slots: the 4 live tokens behind them all fit
    mask = jnp.asarray([0] * 8 + [1] * 4, jnp.int32)
    slot_m, keep_m = moe_lib.dispatch_indices(idx, mc, cap, token_mask=mask)
    slot_m, keep_m = np.asarray(slot_m)[:, 0], np.asarray(keep_m)[:, 0]
    assert keep_m[8:].all() and not keep_m[:8].any()
    assert sorted(slot_m[8:].tolist()) == [0, 1, 2, 3]
    assert (slot_m[:8] == mc.num_experts * cap).all()  # dead -> trash row


def test_router_aux_loss_penalizes_imbalance():
    mc = MoEConfig(num_experts=4, top_k=1, d_ff_expert=64, router_aux_weight=1.0,
                   router_z_weight=0.0)
    d = 32
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, d)), jnp.float32)
    w_uniform = jnp.zeros((d, 4), jnp.float32)
    # biased router: all tokens to expert 0
    w_biased = jnp.zeros((d, 4), jnp.float32).at[:, 0].set(
        jnp.asarray(rng.normal(size=(d,)) * 3, jnp.float32))
    r_u = moe_lib.route(w_uniform, x, mc)
    r_b = moe_lib.route(w_biased, x, mc)
    assert float(r_b.aux_loss) > float(r_u.aux_loss)


def test_moe_with_quantized_experts_close_to_dense():
    cfg = _cfg(cf=8.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(1, 32, 128)), jnp.float32)
    y_fp, _, _ = moe_lib.moe_forward(p, x, cfg)
    pq = dict(p)
    pq["experts"] = {
        "wi": quantize(p["experts"]["wi"], bits=8, group_size=64),
        "wo": quantize(p["experts"]["wo"], bits=8, group_size=64),
    }
    y_q, _, _ = moe_lib.moe_forward(pq, x, cfg)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05


def test_shared_experts_always_contribute():
    cfg = smoke_variant(get_config("deepseek-v2-236b"), d_model=128)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p = moe_lib.moe_init(jax.random.PRNGKey(4), cfg)
    assert "shared" in p
    x = jnp.asarray(np.random.default_rng(5).normal(size=(1, 8, 128)), jnp.float32)
    y_with, _, _ = moe_lib.moe_forward(p, x, cfg)
    p_no = {k: v for k, v in p.items() if k != "shared"}
    y_without, _, _ = moe_lib.moe_forward(p_no, x, cfg)
    assert float(jnp.linalg.norm(y_with - y_without)) > 1e-3


def test_grouped_dispatch_matches_global_with_ample_capacity():
    """The GShard-style grouped dispatch (g>1) must be numerically identical
    to global dispatch when capacity is ample (no drops either way)."""
    cfg = _cfg(cf=8.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(7), cfg)
    x = jnp.asarray(np.random.default_rng(8).normal(size=(4, 16, 128)), jnp.float32)
    y1, aux1, _ = moe_lib.moe_forward(p, x, cfg, groups=1)
    y4, aux4, _ = moe_lib.moe_forward(p, x, cfg, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux4), rtol=1e-5)


def test_grouped_dispatch_capacity_is_per_group():
    """With tight capacity, drops happen per group (local capacities)."""
    cfg = _cfg(cf=0.5)
    p = moe_lib.moe_init(jax.random.PRNGKey(9), cfg)
    x = jnp.asarray(np.random.default_rng(10).normal(size=(4, 16, 128)), jnp.float32)
    y1, _, _ = moe_lib.moe_forward(p, x, cfg, groups=1)
    y4, _, _ = moe_lib.moe_forward(p, x, cfg, groups=4)
    # both run; grouped drops differ from global drops but stay bounded
    assert np.isfinite(np.asarray(y4)).all()
    n1 = float(jnp.linalg.norm(y1))
    n4 = float(jnp.linalg.norm(y4))
    assert 0.3 < n4 / max(n1, 1e-9) < 3.0
