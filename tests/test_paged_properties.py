"""Property-based paging suite: random interleavings of admit /
fork-with-shared-prefix / decode / release driven through BOTH the real
``PagedKVPool`` and a pure-python reference pool (same sharing semantics,
implemented over path-keyed dicts instead of a linked radix trie), asserting
after every operation that

  * ``refcount[p]`` equals the number of slot tables referencing page ``p``
    (and, for trie-registered pages, the node's ref-set size),
  * the free list is duplicate-free, disjoint from every referenced page,
    and together with the referenced pages partitions the pool (no leaks),
  * outstanding reservations plus pending copy-on-write debt never exceed
    the free list (the no-deadlock guarantee: a properly admitted slot can
    always draw its promised pages and fund its COWs),
  * releasing a slot returns exactly its exclusively-owned pages, and a
    second ``release`` of the same slot is a clean no-op,

plus differential checks against the reference (free-page count, matched
prefix lengths, admission verdicts, COW copy counts).

The hypothesis test shrinks failures to minimal op sequences; the scripted
and pseudo-random tests below run the same interpreter deterministically so
the invariant machinery is exercised even where hypothesis is absent.
"""

import os

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    # >= 200 random interleavings locally; a lighter profile under CI where
    # the suite runs on every push (tier-1 --timeout guard)
    settings.register_profile("paged_local", max_examples=200, deadline=None)
    settings.register_profile("paged_ci", max_examples=60, deadline=None)
    settings.load_profile("paged_ci" if os.environ.get("CI") else
                          "paged_local")
except ImportError:  # property tests collect-and-skip without hypothesis
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.models.kv_pages import PagedKVPool

PSZ = 4         # tokens per page
NPAGES = 16
BATCH = 5
MAXP = 6        # page-table width -> 24-token per-slot ceiling
MAXTOK = MAXP * PSZ
CHUNK = 3       # prefill chunk; not page-aligned so chunks cross pages


def _prompt(seed: int, length: int):
    """Deterministic prompt; tiny vocab so accidental shared prefixes (and
    trie collisions between unrelated prompts) actually occur."""
    return [int(x) for x in
            np.random.default_rng(seed).integers(0, 7, length)]


# --------------------------------------------------------------- reference
class RefPool:
    """Pure-python reference for the sharing/COW/reservation semantics.

    Same rules as ``PagedKVPool`` but a different implementation: nodes are
    keyed by their page-content *path* in flat dicts (no parent/child links,
    no physical page ids — pages are counted, not named), so structural bugs
    in the real pool's trie linkage, pruning, refcounting or debt accounting
    show up as divergence rather than being mirrored."""

    def __init__(self, sharing: bool = True):
        self.sharing = sharing
        self.free = NPAGES
        self.reserved = [0] * BATCH
        self.npages = [0] * BATCH               # logical pages per slot
        self.keys = [dict() for _ in range(BATCH)]  # li -> node key
        # full node key: ("F", path); partial key: ("P", path, content)
        # where path is a tuple of full-page content tuples
        self.refs = {}                          # key -> set of slots
        self.partial = {}                       # path -> [contents] in
        #                                         registration order
        self.hit_tokens = 0
        self.cow_copies = 0

    def debt(self) -> int:
        return sum(max(0, len(s) - 1)
                   for k, s in self.refs.items() if k[0] == "P")

    def reservable(self) -> int:
        return self.free - sum(self.reserved) - self.debt()

    def _match(self, prompt):
        L, path, off, chain = len(prompt), (), 0, []
        while L - off >= PSZ:
            c = tuple(prompt[off:off + PSZ])
            if ("F", path + (c,)) not in self.refs:
                break
            path += (c,)
            chain.append(("F", path))
            off += PSZ
        best, bestk = None, 0
        for c in self.partial.get(path, []):    # registration order: the
            k = min(len(c), L - off)            # same tie-break as the pool
            if k > bestk and c[:k] == tuple(prompt[off:off + k]):
                best, bestk = ("P", path, c), k
        return chain, best, off, bestk

    def _plan(self, tokens, prompt):
        need = -(-tokens // PSZ)
        chain, best, off, bestk = self._match(prompt)
        plans = []
        if best is not None and bestk > 0:
            plans.append((chain + [best], off + bestk, 1))
        if chain:
            plans.append((list(chain), off, 0))
        for keys, matched, dbt in plans:
            if len(keys) > need:
                continue
            if (need - len(keys)) + dbt <= self.reservable():
                return keys, matched, need - len(keys)
        return None

    def can_admit(self, tokens, prompt) -> bool:
        need = -(-tokens // PSZ)
        if need > min(MAXP, NPAGES):
            return False
        if need <= self.reservable():
            return True
        if not (self.sharing and prompt is not None):
            return False
        return self._plan(tokens, list(prompt)) is not None

    def reserve(self, slot, tokens, prompt) -> int:
        need = -(-tokens // PSZ)
        if self.sharing and prompt is not None and self.npages[slot] == 0:
            plan = self._plan(tokens, list(prompt))
            if plan is not None:
                keys, matched, extra = plan
                for li, key in enumerate(keys):
                    self.refs[key].add(slot)
                    self.keys[slot][li] = key
                self.npages[slot] = len(keys)
                self.reserved[slot] = extra
                self.hit_tokens += matched
                return matched
        self.reserved[slot] = max(self.reserved[slot],
                                  need - self.npages[slot])
        return 0

    def ensure(self, slot, length):
        target = -(-length // PSZ)
        while self.npages[slot] < target:
            self.free -= 1
            self.npages[slot] += 1
            if self.reserved[slot] > 0:
                self.reserved[slot] -= 1

    def _drop_ref(self, key, slot):
        s = self.refs[key]
        s.discard(slot)
        if not s:
            del self.refs[key]
            if key[0] == "P":
                self.partial[key[1]].remove(key[2])

    def make_writable(self, slot, start, end):
        if not self.sharing or start >= end:
            return
        for li in range(start // PSZ, (end - 1) // PSZ + 1):
            if li >= self.npages[slot]:
                break
            key = self.keys[slot].get(li)
            if key is None:
                continue
            recorded = len(key[2]) if key[0] == "P" else PSZ
            if len(self.refs[key]) > 1:         # shared: copy-on-write
                self.free -= 1
                self.cow_copies += 1
            elif max(start, li * PSZ) - li * PSZ >= recorded:
                continue    # sole-owner append past the record: stays shared
            self._drop_ref(key, slot)           # overlap: detach the record
            del self.keys[slot][li]

    def register(self, slot, prompt):
        if not self.sharing:
            return
        prompt, path = list(prompt), ()
        L = len(prompt)
        for i in range(L // PSZ):
            c = tuple(prompt[i * PSZ:(i + 1) * PSZ])
            key = ("F", path + (c,))
            if key in self.refs:
                if self.keys[slot].get(i) != key:
                    return      # duplicate content registered first
            else:
                if self.keys[slot].get(i) is not None:
                    return      # own page indexed under other content
                self.refs[key] = {slot}
                self.keys[slot][i] = key
            path += (c,)
        rem = L % PSZ
        if rem == 0:
            return
        li = L // PSZ
        if self.keys[slot].get(li) is not None:
            return              # trailing page is itself an alias
        c = tuple(prompt[L - rem:])
        if c in self.partial.get(path, []):
            return              # identical partial already registered
        self.refs[("P", path, c)] = {slot}
        self.partial.setdefault(path, []).append(c)
        self.keys[slot][li] = ("P", path, c)

    def release(self, slot):
        freed = 0
        for li in range(self.npages[slot]):
            key = self.keys[slot].get(li)
            if key is None or len(self.refs[key]) == 1:
                freed += 1      # exclusively owned -> back to the free list
        for key in list(self.keys[slot].values()):
            self._drop_ref(key, slot)
        self.keys[slot] = {}
        self.free += freed
        self.npages[slot] = 0
        self.reserved[slot] = 0


# ------------------------------------------------------------------ driver
class Driver:
    """Runs the real pool and the reference in lockstep, checking every
    invariant after every pool call (not just per high-level op)."""

    def __init__(self, sharing: bool = True):
        self.pool = PagedKVPool(
            num_layers=1, num_kv_heads=1, head_dim=2, dtype="float32",
            num_pages=NPAGES, page_size=PSZ, max_pages_per_slot=MAXP,
            prefix_sharing=sharing)
        self.pool.start(BATCH)
        self.ref = RefPool(sharing)
        self.live = {}          # slot -> [prompt, current_len, token_budget]
        self.history = []       # prompts seen, for fork prefixes

    def check(self):
        pool, ref = self.pool, self.ref
        # 1) refcount[p] == number of slot-table references to p; the
        #    exported table rows mirror the owned lists; registered pages'
        #    refcount equals their trie node's ref-set size
        counts = np.zeros(NPAGES, np.int64)
        for own in pool.owned:
            for pid in own:
                counts[pid] += 1
        np.testing.assert_array_equal(pool.refcount, counts)
        for s, own in enumerate(pool.owned):
            np.testing.assert_array_equal(pool.table[s, :len(own)], own)
        for pid, node in pool._page_node.items():
            assert node.page == pid
            assert pool.refcount[pid] == len(node.refs)
        # 2) free list: duplicate-free, disjoint from referenced pages, and
        #    together they partition the pool (no leaked pages)
        free = set(pool.free)
        assert len(free) == len(pool.free)
        referenced = {pid for own in pool.owned for pid in own}
        assert not free & referenced
        assert free | referenced == set(range(NPAGES))
        # 3) promises + pending COW debt never exceed the free list, and
        #    cow_debt matches its definition (one per extra sharer of each
        #    shared partial page)
        debt = sum(max(0, len(n.refs) - 1)
                   for n in set(pool._page_node.values())
                   if len(n.tokens) < PSZ)
        assert pool.cow_debt == debt
        assert int(pool.reserved.sum()) + pool.cow_debt <= len(pool.free)
        # differential: the independent reference agrees exactly
        assert len(pool.free) == ref.free
        assert pool.cow_copies == ref.cow_copies
        assert pool.prefix_hit_tokens == ref.hit_tokens
        assert [len(o) for o in pool.owned] == ref.npages
        assert [int(r) for r in pool.reserved] == ref.reserved

    def admit(self, prompt, new_tokens: int):
        free_slots = [s for s in range(BATCH) if s not in self.live]
        if not free_slots or not prompt:
            return
        slot = free_slots[0]
        need = len(prompt) + new_tokens + 1
        parr = np.asarray(prompt, np.int32)
        ok = self.pool.can_reserve(need, prompt=parr)
        assert ok == self.ref.can_admit(need, prompt)
        if not ok:
            return
        matched = self.pool.reserve(slot, need, prompt=parr)
        assert matched == self.ref.reserve(slot, need, prompt)
        self.check()
        # chunked prefill: resume at the matched length (re-feeding at least
        # the last prompt token), write floor at the matched length
        ws = matched
        fed = min(matched, len(prompt) - 1)
        while fed < len(prompt):
            n = min(CHUNK, len(prompt) - fed)
            self.pool.ensure(slot, fed + n)
            self.ref.ensure(slot, fed + n)
            self.pool.make_writable(slot, max(fed, ws), fed + n)
            self.ref.make_writable(slot, max(fed, ws), fed + n)
            fed += n
            self.check()
        self.pool.register_prefix(slot, parr)
        self.ref.register(slot, prompt)
        self.live[slot] = [list(prompt), len(prompt), need]
        self.history.append(list(prompt))
        self.check()

    def decode(self, pick: int):
        if not self.live:
            return
        slot = sorted(self.live)[pick % len(self.live)]
        _, length, budget = self.live[slot]
        if length + 1 > budget:
            return
        self.pool.ensure(slot, length + 1)
        self.ref.ensure(slot, length + 1)
        self.pool.make_writable(slot, length, length + 1)
        self.ref.make_writable(slot, length, length + 1)
        self.live[slot][1] = length + 1
        self.check()

    def release(self, pick: int, double: bool = False):
        if not self.live:
            return
        slot = sorted(self.live)[pick % len(self.live)]
        exclusive = [pid for pid in self.pool.owned[slot]
                     if self.pool.refcount[pid] == 1]
        self.pool.release(slot)
        self.ref.release(slot)
        del self.live[slot]
        # 4) every exclusively-owned page came back to the free list
        assert set(exclusive) <= set(self.pool.free)
        self.check()
        if double:
            snap = self._snapshot()
            self.pool.release(slot)             # second release: clean no-op
            assert self._snapshot() == snap
            self.check()

    def _snapshot(self):
        p = self.pool
        return (sorted(p.free), p.refcount.tolist(),
                [list(o) for o in p.owned], p.reserved.tolist(),
                p.cow_debt, p.cow_copies, p.prefix_hit_tokens)


def _run_ops(ops, sharing: bool):
    """Interpret an abstract op stream (opcode + 3 raw ints, mapped onto the
    current driver state) — shared by the hypothesis and scripted tests."""
    d = Driver(sharing)
    for op, a, b, c in ops:
        if op == "admit":
            plen = 1 + a % 18
            d.admit(_prompt(b, plen), c % max(1, MAXTOK - plen - 1))
        elif op == "fork":
            if d.history:
                base = d.history[a % len(d.history)]
                cut = b % (len(base) + 1)
                p = (base[:cut] + _prompt(b + 1, 1 + c % 8))[:MAXTOK - 4]
                d.admit(p, 3)
        elif op == "decode":
            d.decode(a)
        elif op == "release":
            d.release(a)
        else:                   # double_release
            d.release(a, double=True)
    # drain: every release path (shared and exclusive pages) re-checked
    for pick in [0] * len(d.live):
        d.release(pick, double=True)
    assert d.pool.pages_used == 0 and d.ref.free == NPAGES
    return d


_OPS = st.lists(
    st.tuples(
        st.sampled_from(["admit", "fork", "decode", "release",
                         "double_release"]),
        st.integers(0, 2 ** 16), st.integers(0, 2 ** 16),
        st.integers(0, 2 ** 16)),
    min_size=1, max_size=30)


@given(ops=_OPS, sharing=st.booleans())
def test_random_interleavings_hold_pool_invariants(ops, sharing):
    """Hypothesis-shrunk random interleavings of admit / fork / decode /
    release keep every pool invariant and track the reference exactly."""
    _run_ops(ops, sharing)


def test_pseudorandom_interleavings_deterministic():
    """The same interpreter over numpy-generated op streams: deterministic
    coverage of the property (runs even where hypothesis is absent)."""
    names = ["admit", "fork", "decode", "release", "double_release"]
    for seed in range(30):
        rng = np.random.default_rng(seed)
        ops = [(names[int(rng.integers(0, 5))], int(rng.integers(0, 2**16)),
                int(rng.integers(0, 2**16)), int(rng.integers(0, 2**16)))
               for _ in range(25)]
        _run_ops(ops, sharing=bool(seed % 2 == 0))


def test_scripted_shared_prefix_lifecycle():
    """Deterministic end-to-end: register, alias (identical + divergent
    fork), COW on divergence and on decode-into-partial, donor released
    before sharer, everything drained."""
    d = Driver(True)
    base = _prompt(1, 14)               # 3 full pages + 2-token partial
    d.admit(base, 4)
    assert d.pool.prefix_hit_tokens == 0
    d.admit(list(base), 4)              # identical prompt: length-0
    hit = d.pool.prefix_hit_tokens      # divergence, full 14-token alias
    assert hit == 14 and d.pool.cow_copies == 0
    assert d.pool.aliased_pages == 4
    d.admit(base[:9] + _prompt(2, 5), 4)    # diverges mid-page 3: aliases
    assert d.pool.prefix_hit_tokens == hit + 8  # 2 full pages only
    d.decode(0)                         # base writes token 14 into the
    assert d.pool.cow_copies == 1       # shared partial page -> COW
    d.release(0)                        # donor gone; sharers keep pages
    assert d.pool.aliased_pages > 0
    for _ in range(len(d.live)):
        d.release(0, double=True)
    assert d.pool.pages_used == 0


def test_second_release_is_clean_noop():
    """Releasing an already-released slot must not decrement refcounts
    again, re-free pages, or disturb other slots (the double-free class)."""
    d = Driver(True)
    d.admit(_prompt(3, 10), 4)
    d.admit(_prompt(3, 10), 4)          # aliases slot 0's pages
    d.release(0, double=True)           # donor released twice
    d.release(0, double=True)           # sharer released twice
    assert d.pool.pages_used == 0
    # never-admitted slot: also a no-op
    snap = d._snapshot()
    d.pool.release(BATCH - 1)
    assert d._snapshot() == snap


def test_sharing_disabled_never_aliases():
    """prefix_sharing=False: no matches, no COWs, zero sharing stats, and
    the reference agrees on plain reservation arithmetic."""
    d = _run_ops([("admit", i, 3, 4) for i in range(4)] +
                 [("fork", 0, 2, 2), ("decode", 0, 0, 0)], sharing=False)
    assert d.pool.prefix_hit_tokens == 0 and d.pool.cow_copies == 0
    assert d.pool.aliased_pages == 0
