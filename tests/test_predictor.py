"""Adaptive Expert Predictor tests: stacked prediction, adaptive walk
semantics, pinning, accuracy bookkeeping."""

import numpy as np
import pytest

from repro.core import (AdaptiveExpertPredictor, MultidimensionalCache,
                        Thresholds)
from repro.core.policies import LRU


def _routers(l=4, d=32, e=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=(d, e)).astype(np.float32) for _ in range(l)]


def test_predict_layers_shapes_and_range():
    pred = AdaptiveExpertPredictor(_routers(), top_k=2, p=3)
    h = np.random.default_rng(1).normal(size=32).astype(np.float32)
    out = pred.predict_layers(h, 0)
    assert [p.layer for p in out] == [1, 2, 3]
    for p in out:
        assert len(p.experts) == 2
        assert all(0 <= e < 8 for e in p.experts)
        assert (p.gate_vals[:-1] >= p.gate_vals[1:]).all()  # sorted desc


def test_predict_layers_clips_at_model_end():
    pred = AdaptiveExpertPredictor(_routers(l=3), top_k=1, p=4)
    h = np.zeros(32, np.float32)
    out = pred.predict_layers(h, 1)
    assert [p.layer for p in out] == [2]
    assert pred.predict_layers(h, 2) == []


def test_adaptive_walk_stops_at_first_missing_layer():
    pred = AdaptiveExpertPredictor(_routers(), top_k=2, p=3)
    cache = MultidimensionalCache(4, hi_slots=16, lo_slots=8, weights=LRU)
    cache.new_sequence()
    cache.advance_token()
    h = np.random.default_rng(2).normal(size=32).astype(np.float32)
    th = Thresholds(1.0, 1.0)  # everything high precision
    # empty cache: layer 1 prediction must be the one returned
    walk = pred.adaptive_walk(h, 0, cache, th)
    assert len(walk) == 1 and walk[0][0].layer == 1
    # admit layer-1 predictions -> walk advances to layer 2
    for e in walk[0][0].experts:
        cache.admit((1, e), True, 0)
    walk2 = pred.adaptive_walk(h, 0, cache, th)
    assert len(walk2) == 1 and walk2[0][0].layer == 2


def test_adaptive_walk_pins_resident_predictions():
    pred = AdaptiveExpertPredictor(_routers(), top_k=2, p=1)
    cache = MultidimensionalCache(4, hi_slots=4, lo_slots=2, weights=LRU)
    cache.new_sequence()
    cache.advance_token()
    h = np.random.default_rng(3).normal(size=32).astype(np.float32)
    preds = pred.predict_layers(h, 0, 1)
    for e in preds[0].experts:
        cache.admit((1, e), True, 0)
    pred.adaptive_walk(h, 0, cache, Thresholds(1.0, 1.0))
    for e in preds[0].experts:
        assert ((1, e), True) in cache.pinned


def test_accuracy_bookkeeping():
    pred = AdaptiveExpertPredictor(_routers(), top_k=2, p=1)
    h = np.random.default_rng(4).normal(size=32).astype(np.float32)
    p1 = pred.predict_layers(h, 0, 1)[0]
    pred.record_accuracy(p1, [p1.experts[0]], distance=1)     # correct
    pred.record_accuracy(p1, [(p1.experts[0] + 1) % 8], 1)    # wrong
    assert pred.accuracy()[1] == pytest.approx(0.5)


def test_stacked_prediction_matches_per_layer():
    routers = _routers()
    pred = AdaptiveExpertPredictor(routers, top_k=2, p=3)
    h = np.random.default_rng(5).normal(size=32).astype(np.float32)
    out = pred.predict_layers(h, 0)
    for p in out:
        logits = h @ routers[p.layer]
        want = np.argsort(-logits)[:2]
        assert p.experts == want.tolist()
