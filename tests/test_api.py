"""Unified serving API tests: protocol conformance, dense-vs-offload logits
parity under an unconstrained cache, batched HobbitBackend decode vs batch=1,
continuous batching with mid-flight slot reuse through both backends, and
the decode-only latency accounting of BatchingServer.stats()."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import EngineConfig, OffloadEngine, Thresholds
from repro.models import build_model
from repro.serving.api import (DenseBackend, HobbitBackend, InferenceBackend,
                               generate, make_backend, score_nll)
from repro.serving.batching import BatchingServer, Request
from repro.serving.decode import generate as dense_generate


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=256)
    # ample capacity so the dense MoE dispatch never drops tokens at batch>1
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _unconstrained(m):
    """EngineConfig whose hi pool holds every (layer, expert) entity at full
    precision: the offload path must then match dense numerics."""
    n = m.cfg.num_layers * m.cfg.moe.num_experts
    return EngineConfig(hi_slots=n, lo_slots=1, thresholds=Thresholds(1.0, 1.0),
                        prefetch=False)


# ------------------------------------------------------------- protocol
def test_backends_satisfy_protocol(setup):
    m, params = setup
    assert isinstance(DenseBackend(m, params), InferenceBackend)
    eng = OffloadEngine(m, params, _unconstrained(m))
    assert isinstance(HobbitBackend(eng), InferenceBackend)
    assert isinstance(make_backend("dense", m, params), DenseBackend)
    assert isinstance(make_backend("hobbit", m, params), HobbitBackend)
    with pytest.raises(ValueError):
        make_backend("nope", m, params)


def test_dense_backend_matches_legacy_generate(setup):
    m, params = setup
    prompts = np.random.default_rng(0).integers(0, 256, (2, 8))
    res_api = generate(DenseBackend(m, params), prompts, 6)
    res_old = dense_generate(m, params, jnp.asarray(prompts, jnp.int32), 6)
    np.testing.assert_array_equal(res_api.tokens, res_old.tokens)


# ------------------------------------------------- dense vs offload parity
def test_dense_vs_hobbit_logits_parity_unconstrained(setup):
    """With every expert resident at high precision, per-step logits of the
    offload path must match the dense path."""
    m, params = setup
    prompts = np.random.default_rng(1).integers(0, 256, (2, 6))
    teacher = np.random.default_rng(2).integers(0, 256, (4, 2))

    dense = DenseBackend(m, params)
    hob = HobbitBackend(OffloadEngine(m, params, _unconstrained(m)))
    dense.start_batch(2, 32)
    hob.start_batch(2, 32)
    lg_d = dense.prefill(prompts)
    lg_h = hob.prefill(prompts)
    np.testing.assert_allclose(lg_d, lg_h, atol=1e-3)
    for t in range(4):
        lg_d = dense.step(teacher[t])
        lg_h = hob.step(teacher[t])
        np.testing.assert_allclose(lg_d, lg_h, atol=1e-3)


def test_dense_vs_hobbit_generate_tokens_equal(setup):
    m, params = setup
    prompts = np.random.default_rng(3).integers(0, 256, (2, 8))
    res_d = generate(DenseBackend(m, params), prompts, 6)
    res_h = generate(HobbitBackend(OffloadEngine(m, params, _unconstrained(m))),
                     prompts, 6)
    np.testing.assert_array_equal(res_d.tokens, res_h.tokens)


def test_score_nll_parity_unconstrained(setup):
    m, params = setup
    toks = np.random.default_rng(4).integers(0, 256, 10)
    nll_d = score_nll(DenseBackend(m, params), toks)
    nll_h = score_nll(HobbitBackend(OffloadEngine(m, params, _unconstrained(m))),
                      toks)
    assert abs(nll_d - nll_h) < 1e-4


# ------------------------------------------------- batched hobbit decode
def test_hobbit_batched_matches_batch1(setup):
    """Per-slot outputs of a batch=2 mixed-precision HOBBIT decode equal the
    corresponding batch=1 runs (per-slot precision decisions; expert loading
    is the union of slots, but numerics stay per-slot)."""
    m, params = setup
    ecfg = EngineConfig(hi_slots=16, lo_slots=8, thresholds=Thresholds(0.6, 0.9))
    prompts = np.random.default_rng(5).integers(0, 256, (2, 8))
    res_b = generate(HobbitBackend(OffloadEngine(m, params, ecfg)), prompts, 5,
                     max_len=32)
    for r in range(2):
        res_1 = generate(HobbitBackend(OffloadEngine(m, params, ecfg)),
                         prompts[r : r + 1], 5, max_len=32)
        np.testing.assert_array_equal(res_b.tokens[r], res_1.tokens[0])


def test_hobbit_batched_trace_and_stats(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=16, lo_slots=8))
    backend = HobbitBackend(eng)
    generate(backend, np.random.default_rng(6).integers(0, 256, (3, 4)), 4)
    # one trace entry per active slot per decode step, each covering all layers
    assert len(eng.trace) == 3 * 3  # (new_tokens - 1) steps x 3 slots
    assert all(len(tok) == eng.num_moe_layers for tok in eng.trace)
    s = backend.stats()
    assert s["backend"] == "hobbit" and s["loaded_bytes"] > 0


# ------------------------------------------------- continuous batching
def _mixed_workload(rng):
    return [Request(rid=i, prompt=rng.integers(0, 256, 4 + 2 * (i % 2)),
                    max_new_tokens=[3, 7, 4, 2][i]) for i in range(4)]


def _backend_factory(kind, m, params):
    if kind == "dense":
        return lambda: DenseBackend(m, params)
    ecfg = EngineConfig(hi_slots=16, lo_slots=8)
    return lambda: HobbitBackend(OffloadEngine(m, params, ecfg))


@pytest.mark.parametrize("kind", ["dense", "hobbit"])
def test_continuous_batching_mid_flight(setup, kind):
    """More requests than slots with mixed max_new_tokens: finished requests
    free their slots mid-flight, queued requests join at the next step, and
    every request's output equals its isolated single-request run."""
    m, params = setup
    mk = _backend_factory(kind, m, params)
    rng = np.random.default_rng(7)
    reqs = _mixed_workload(rng)
    prompts = [np.array(r.prompt) for r in reqs]

    srv = BatchingServer(mk(), max_batch=2, max_len=64)
    for r in reqs:
        srv.submit(r)
    srv.run()

    assert len(srv.completed) == 4
    by_rid = {r.rid: r for r in srv.completed}
    for i, p in enumerate(prompts):
        assert by_rid[i].output.shape[0] == [3, 7, 4, 2][i]
        res = generate(mk(), p[None], [3, 7, 4, 2][i], max_len=64)
        np.testing.assert_array_equal(by_rid[i].output,
                                      res.tokens[0, len(p):])
    # at least one queued request joined after decoding had already started
    assert any(e[0] == "join" and e[3] > 0 for e in srv.events)
    # and some retirement happened while another request was still in flight
    retire_steps = [e[3] for e in srv.events if e[0] == "retire"]
    assert min(retire_steps) < max(retire_steps)


def test_dense_backend_wide_batch_junk_slots_inert(setup):
    """Released slots' junk rows must not crowd live tokens out of MoE
    dispatch capacity at production capacity_factor (1.25): a single live
    request in the highest slot of a 10-slot batch decodes identically to
    its isolated run (9 identical junk rows route together, so without the
    active-mask they could fill an expert's capacity ahead of the live row)."""
    m, params = setup
    cfg = dataclasses.replace(
        m.cfg, moe=dataclasses.replace(m.cfg.moe, capacity_factor=1.25))
    m125 = build_model(cfg)
    prompt = np.random.default_rng(9).integers(0, 256, (1, 5))
    want = generate(DenseBackend(m125, params), prompt, 6, max_len=32)
    be = DenseBackend(m125, params)
    be.start_batch(10, 32)
    for s in range(10):
        be.release(s)
    lg = be.join(9, prompt[0])
    toks = [int(np.argmax(lg))]
    for _ in range(5):
        vec = np.zeros((10,), np.int32)
        vec[9] = toks[-1]
        lg = be.step(vec)
        toks.append(int(np.argmax(lg[9])))
    np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                  want.tokens[0, 5:])


def test_batching_server_stats_decode_only(setup):
    """stats() reports per-request queue wait separately; decode_tok_s is
    computed over decode-step wall time only (not prefill, not queue wait)."""
    m, params = setup
    srv = BatchingServer(DenseBackend(m, params), max_batch=2, max_len=64)
    rng = np.random.default_rng(8)
    for r in _mixed_workload(rng):
        srv.submit(r)
    srv.run()
    st = srv.stats()
    assert st["requests"] == 4
    assert st["decode_tok_s"] > 0
    for key in ("mean_queue_wait_s", "mean_prefill_s", "mean_decode_s",
                "mean_total_s"):
        assert st[key] >= 0.0
    # queued requests (more requests than slots) must see nonzero queue wait
    assert max(r.queue_wait_s for r in srv.completed) > 0
    # per-request prefill is its own join, not the whole batch's
    assert all(r.prefill_latency_s > 0 for r in srv.completed)
    assert st["backend"]["backend"] == "dense"
