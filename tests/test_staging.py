"""Multi-stream byte-budgeted staging engine tests: parity of the ordered
single-stream configuration against the per-expert reference path, issue-time
precision downgrades under a tight link budget, biggest-gate-first issue
reordering, in-flight reservation cancellation, idempotent engine/server
teardown, and the stats() JSON round-trip covering the new per-stream
fields (engine, simulator and BatchingServer)."""

import dataclasses
import json
import time

import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, LRU, MultidimensionalCache,
                        OffloadEngine, PREC_HI, PREC_LO, StagingEngine,
                        Thresholds)
from repro.core.loader import DynamicExpertLoader
from repro.core.simulator import (HobbitSimConfig, OffloadSimulator, RTX4090,
                                  TraceLayer)
from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.serving.api import DenseBackend, HobbitBackend, generate
from repro.serving.batching import BatchingServer, Request

HI_BYTES, LO_BYTES = 1000, 100


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=256)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _unit_engine(*, streams=2, ordered=False, link_bps=None,
                 stage_sleep=0.0, hi_slots=4, lo_slots=4):
    """A StagingEngine over a fake host store: stage_fn logs its call order
    (and optionally sleeps, keeping copies in flight while the test pumps);
    commit_fn collects landed entries."""
    cache = MultidimensionalCache(4, hi_slots, lo_slots, LRU)
    cache.new_sequence()
    cache.advance_token()
    loader = DynamicExpertLoader(
        cache, Thresholds(1.0, 1.0), lambda *a: None,
        lambda prec: HI_BYTES if prec == PREC_HI else LO_BYTES)
    staged_order, committed = [], []

    def stage_fn(layer, expert, precision):
        staged_order.append((layer, expert, precision))
        if stage_sleep:
            time.sleep(stage_sleep)
        return {"layer": layer, "expert": expert}

    eng = StagingEngine(loader, stage_fn, committed.extend,
                        streams=streams, ordered=ordered, link_bps=link_bps)
    return eng, cache, staged_order, committed


# ------------------------------------------------------------- parity
def test_ordered_single_stream_matches_reference_and_default(setup):
    """`EngineConfig(streams=1, ordered=True)` is the pre-PR FIFO scheduler:
    its tokens must equal the per-expert reference path's; the default
    multi-stream budgeted config must also agree (no downgrades fire at the
    measured link rate, so issue order cannot change numerics)."""
    m, params = setup
    base = dict(hi_slots=8, lo_slots=4)
    prompts = np.random.default_rng(21).integers(0, 256, (3, 5))

    def toks(ecfg):
        return generate(HobbitBackend(OffloadEngine(m, params, ecfg)),
                        prompts, 6, max_len=32).tokens

    t_ref = toks(EngineConfig(grouped=False, async_prefetch=False, **base))
    t_fifo = toks(EngineConfig(streams=1, ordered=True, **base))
    t_budg = toks(EngineConfig(**base))     # default: streams=2, budgeted
    np.testing.assert_array_equal(t_fifo, t_ref)
    np.testing.assert_array_equal(t_budg, t_ref)


# ----------------------------------------------- budgeted issue mechanics
def test_budget_preemption_downgrades_queued_hi_job():
    """A queued hi job whose bytes exceed the remaining link budget before
    its deadline is preempted: hi reservation cancelled, lo replacement
    reserved + staged, downgrade recorded for the compute path."""
    eng, cache, staged, committed = _unit_engine(
        link_bps=1e6, stage_sleep=0.25)
    # budget window for layer 1 = 1 layer * 3 ms * 1e6 B/s * 0.5 safety =
    # 1500 bytes; per-pump stream feed = 10 ms * 1e6 = 10000 bytes, so both
    # jobs reach the issue decision while job 0 is still in flight
    eng.set_deadline_clock(0, per_layer_s=3e-3, period_s=10e-3)
    n = eng.submit_prefetch(1, [0, 1], np.array([PREC_HI, PREC_HI]),
                            current_layer=0, gates=np.array([0.9, 0.8]))
    assert n == 2
    # job 0 fit the budget (1000 <= 1500); job 1 did not (1000+1000 > 1500)
    assert eng.precision_downgrades == 1
    assert (1, 1) in eng.downgraded
    assert cache.lookup((1, 1), True) is None       # hi reservation cancelled
    assert cache.is_inflight((1, 1), False)         # lo replacement in flight
    eng.wait(1)
    assert cache.lookup((1, 0), True) is not None   # hi copy landed
    assert cache.lookup((1, 1), False) is not None  # lo replacement landed
    assert eng.serves_lo_downgrade(1, 1)
    precs = sorted(t.precision for t, _, _ in committed)
    assert precs == sorted([PREC_HI, PREC_LO])
    eng.retire_layer(1)
    assert not eng.serves_lo_downgrade(1, 1)        # one-token decision
    eng.shutdown()


def test_biggest_gate_issues_first_within_layer():
    """Within one deadline layer a stream issues the biggest-gate job first,
    counting the FIFO inversion as an issue_reorder."""
    eng, cache, staged, _ = _unit_engine(streams=1)
    eng.submit_prefetch(2, [0, 1], np.array([PREC_HI, PREC_HI]),
                        current_layer=0, gates=np.array([0.1, 0.9]))
    eng.wait(2)
    assert [e for _, e, _ in staged] == [1, 0]      # gate 0.9 before 0.1
    assert eng.issue_reorders >= 1
    eng.shutdown()


def test_nearest_deadline_layer_issues_first():
    """Across deadline layers the nearest layer's job overtakes an older
    queued job for a later layer."""
    eng, cache, staged, _ = _unit_engine(streams=1, stage_sleep=0.05)
    eng.submit_prefetch(3, [0], np.array([PREC_HI]), current_layer=0)
    eng.submit_prefetch(3, [1], np.array([PREC_HI]), current_layer=0)
    eng.submit_prefetch(1, [2], np.array([PREC_HI]), current_layer=0)
    # job for layer 3/expert 0 is in flight; jobs (3,1) and (1,2) are queued:
    # once the stream frees, the layer-1 job must overtake the older (3,1)
    time.sleep(0.15)
    eng._pump()
    eng.wait_all()
    assert [(lay, e) for lay, e, _ in staged] == [(3, 0), (1, 2), (3, 1)]
    assert eng.issue_reorders >= 1
    eng.shutdown()


def test_cancel_inflight_returns_slot_and_keeps_other_precision():
    """cancel_inflight drops only the (key, precision) reservation it names:
    the slot returns to the free list and a lo copy of the same expert is
    untouched (precision-keyed reservations)."""
    c = MultidimensionalCache(4, hi_slots=1, lo_slots=1, weights=LRU)
    c.new_sequence()
    c.advance_token()
    s_lo, _ = c.admit((0, 7), False, 0)
    s_hi, _ = c.admit((0, 7), True, 0)
    c.begin_inflight((0, 7), True, s_hi)
    assert c.cancel_inflight((0, 7), True) == s_hi
    assert c.lookup((0, 7), True) is None
    assert s_hi in c.hi.free                        # slot reusable
    assert c.lookup((0, 7), False) == s_lo          # lo copy untouched
    assert c.cancel_inflight((0, 7), True) is None  # idempotent


# ------------------------------------------------------------- teardown
def test_engine_close_idempotent_and_step_raises(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    generate(HobbitBackend(eng), np.array([[1, 2, 3]]), 3, max_len=16)
    eng.close()
    eng.close()                                     # second close: no-op
    with pytest.raises(RuntimeError, match="closed"):
        eng.decode_step_batch(np.array([1], np.int32))
    with pytest.raises(RuntimeError, match="closed"):
        eng.start_batch(1, 8)


def test_batching_server_close_releases_staging_threads(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    rng = np.random.default_rng(3)
    with BatchingServer(HobbitBackend(eng), max_batch=2, max_len=32) as srv:
        for i in range(2):
            srv.submit(Request(rid=i, prompt=rng.integers(0, 256, 4),
                               max_new_tokens=3))
        srv.run()
        assert len(srv.completed) == 2
    # scope exit closed the backend -> engine closed, worker threads released
    assert eng._closed
    assert not eng.scheduler._finalizer.alive
    srv.close()                                     # idempotent


def test_dense_backend_close_is_noop(setup):
    cfg = smoke_variant(get_config("granite-3-2b"), layers=2, d_model=64,
                        vocab=128)
    cfg = dataclasses.replace(cfg, dtype="float32")
    m = build_model(cfg)
    be = DenseBackend(m, m.init(jax.random.PRNGKey(0)))
    be.close()
    be.close()
    be.start_batch(1, 8)                            # still usable


# ------------------------------------------------------- stats round-trip
def _roundtrip_same_keys(stats: dict) -> dict:
    """json round-trip must preserve the exact key set (serving contract)."""
    back = json.loads(json.dumps(stats))
    assert set(back) == set(stats)
    return back


NEW_FIELDS = ("per_stream_bytes", "issue_reorders", "precision_downgrades",
              "link_utilization")


def test_engine_stats_json_roundtrip_with_stream_fields(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    generate(HobbitBackend(eng), np.array([[1, 2, 3]]), 4, max_len=16)
    s = eng.stats()
    back = _roundtrip_same_keys(s)
    for f in NEW_FIELDS + ("streams", "link_gbps"):
        assert f in back, f
    assert back["streams"] == 2
    assert isinstance(back["per_stream_bytes"], list)
    assert len(back["per_stream_bytes"]) == 2
    assert sum(back["per_stream_bytes"]) > 0        # prefetch traffic issued
    eng.close()


def test_simulator_stats_json_roundtrip_with_stream_fields():
    rng = np.random.default_rng(5)
    trace = []
    for _ in range(6):
        token = []
        for _li in range(3):
            g = np.sort(rng.random(2))[::-1]
            token.append(TraceLayer(experts=rng.permutation(8)[:2].tolist(),
                                    gate_vals=g,
                                    pred_experts=rng.permutation(8)[:2].tolist(),
                                    pred_gate_vals=np.sort(rng.random(2))[::-1]))
        trace.append(token)
    cfg = HobbitSimConfig(hi_slots=4, lo_slots=2, hi_bytes=10_000_000,
                          lo_bytes=2_500_000, streams=2, ordered=False)
    res = OffloadSimulator("hobbit", 3, RTX4090, cfg).run(trace)
    ser = {k: v for k, v in res.items() if k != "stats"}   # CacheStats object
    back = _roundtrip_same_keys(ser)
    for f in NEW_FIELDS:
        assert f in back, f
    assert len(back["per_stream_bytes"]) == 2
    assert back["cache"]["hits"] == res["stats"].hits      # dict mirror


def test_simulator_single_stream_default_unchanged():
    """streams=1/ordered=True (the default) must reproduce the single-DMA
    timeline: one stream, all bytes on it, no downgrades or reorders."""
    rng = np.random.default_rng(6)
    trace = [[TraceLayer(experts=[0, 1], gate_vals=np.array([0.6, 0.3]),
                         pred_experts=[2, 3],
                         pred_gate_vals=np.array([0.5, 0.2]))
              for _ in range(2)] for _ in range(4)]
    cfg = HobbitSimConfig(hi_slots=4, lo_slots=2, hi_bytes=1_000_000,
                          lo_bytes=250_000)
    res = OffloadSimulator("hobbit", 2, RTX4090, cfg).run(trace)
    assert len(res["per_stream_bytes"]) == 1
    assert res["precision_downgrades"] == 0
    assert res["issue_reorders"] == 0


def test_server_stats_json_roundtrip_with_stream_fields(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    rng = np.random.default_rng(7)
    with BatchingServer(HobbitBackend(eng), max_batch=2, max_len=32) as srv:
        for i in range(2):
            srv.submit(Request(rid=i, prompt=rng.integers(0, 256, 4),
                               max_new_tokens=3))
        srv.run()
        s = srv.stats()
    back = _roundtrip_same_keys(s)
    for f in ("precision_downgrades", "issue_reorders", "link_utilization",
              "mean_precision_downgrades"):
        assert f in back, f
    for f in NEW_FIELDS:
        assert f in back["backend"], f
