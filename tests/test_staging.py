"""Multi-stream byte-budgeted staging engine tests: parity of the ordered
single-stream configuration against the per-expert reference path, issue-time
precision downgrades under a tight link budget, biggest-gate-first issue
reordering, in-flight reservation cancellation, idempotent engine/server
teardown, and the stats() JSON round-trip covering the new per-stream
fields (engine, simulator and BatchingServer)."""

import dataclasses
import json
import time

import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, LRU, MultidimensionalCache,
                        OffloadEngine, PREC_HI, PREC_LO, StagingEngine,
                        Thresholds)
from repro.core.loader import DynamicExpertLoader
from repro.core.simulator import (HobbitSimConfig, OffloadSimulator, RTX4090,
                                  TraceLayer)
from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.serving.api import DenseBackend, HobbitBackend, generate
from repro.serving.batching import BatchingServer, Request

HI_BYTES, LO_BYTES = 1000, 100


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("mixtral-8x7b"), layers=4, d_model=128,
                        vocab=256)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _unit_engine(*, streams=2, ordered=False, link_bps=None,
                 stage_sleep=0.0, hi_slots=4, lo_slots=4, upgrade=True):
    """A StagingEngine over a fake host store: stage_fn logs its call order
    (and optionally sleeps, keeping copies in flight while the test pumps);
    commit_fn collects landed entries."""
    cache = MultidimensionalCache(4, hi_slots, lo_slots, LRU)
    cache.new_sequence()
    cache.advance_token()
    loader = DynamicExpertLoader(
        cache, Thresholds(1.0, 1.0), lambda *a: None,
        lambda prec: HI_BYTES if prec == PREC_HI else LO_BYTES)
    staged_order, committed = [], []

    def stage_fn(layer, expert, precision):
        staged_order.append((layer, expert, precision))
        if stage_sleep:
            time.sleep(stage_sleep)
        return {"layer": layer, "expert": expert}

    eng = StagingEngine(loader, stage_fn, committed.extend,
                        streams=streams, ordered=ordered, link_bps=link_bps,
                        upgrade=upgrade)
    return eng, cache, staged_order, committed


def _downgrade_one(eng, cache):
    """Drive the unit engine into one issue-time downgrade: layer 1 expert 0
    fits the budget (hi issues), expert 1 does not (hi preempted to a lo
    replacement).  Returns after both copies landed."""
    eng.set_deadline_clock(0, per_layer_s=3e-3, period_s=10e-3)
    n = eng.submit_prefetch(1, [0, 1], np.array([PREC_HI, PREC_HI]),
                            current_layer=0, gates=np.array([0.9, 0.8]))
    assert n == 2
    assert eng.precision_downgrades == 1
    eng.wait(1)
    assert cache.lookup((1, 1), False) is not None  # lo stand-in resident


# ------------------------------------------------------------- parity
def test_ordered_single_stream_matches_reference_and_default(setup):
    """`EngineConfig(streams=1, ordered=True)` is the pre-PR FIFO scheduler:
    its tokens must equal the per-expert reference path's; the default
    multi-stream budgeted config must also agree (no downgrades fire at the
    measured link rate, so issue order cannot change numerics)."""
    m, params = setup
    base = dict(hi_slots=8, lo_slots=4)
    prompts = np.random.default_rng(21).integers(0, 256, (3, 5))

    def toks(ecfg):
        return generate(HobbitBackend(OffloadEngine(m, params, ecfg)),
                        prompts, 6, max_len=32).tokens

    t_ref = toks(EngineConfig(grouped=False, async_prefetch=False, **base))
    t_fifo = toks(EngineConfig(streams=1, ordered=True, **base))
    t_budg = toks(EngineConfig(**base))     # default: streams=2, budgeted
    np.testing.assert_array_equal(t_fifo, t_ref)
    np.testing.assert_array_equal(t_budg, t_ref)


# ----------------------------------------------- budgeted issue mechanics
def test_budget_preemption_downgrades_queued_hi_job():
    """A queued hi job whose bytes exceed the remaining link budget before
    its deadline is preempted: hi reservation cancelled, lo replacement
    reserved + staged, downgrade recorded for the compute path.  With the
    upgrade pass OFF this is the PR-4 per-token contract: the marker dies
    with retire_layer."""
    eng, cache, staged, committed = _unit_engine(
        link_bps=1e6, stage_sleep=0.25, upgrade=False)
    # budget window for layer 1 = 1 layer * 3 ms * 1e6 B/s * 0.5 safety =
    # 1500 bytes; per-pump stream feed = 10 ms * 1e6 = 10000 bytes, so both
    # jobs reach the issue decision while job 0 is still in flight
    eng.set_deadline_clock(0, per_layer_s=3e-3, period_s=10e-3)
    n = eng.submit_prefetch(1, [0, 1], np.array([PREC_HI, PREC_HI]),
                            current_layer=0, gates=np.array([0.9, 0.8]))
    assert n == 2
    # job 0 fit the budget (1000 <= 1500); job 1 did not (1000+1000 > 1500)
    assert eng.precision_downgrades == 1
    assert (1, 1) in eng.downgraded
    assert cache.lookup((1, 1), True) is None       # hi reservation cancelled
    assert cache.is_inflight((1, 1), False)         # lo replacement in flight
    eng.wait(1)
    assert cache.lookup((1, 0), True) is not None   # hi copy landed
    assert cache.lookup((1, 1), False) is not None  # lo replacement landed
    assert eng.serves_lo_downgrade(1, 1)
    precs = sorted(t.precision for t, _, _ in committed)
    assert precs == sorted([PREC_HI, PREC_LO])
    eng.retire_layer(1)
    assert not eng.serves_lo_downgrade(1, 1)        # one-token decision
    eng.shutdown()


# ------------------------------------------------- idle-link upgrade pass
def test_upgrade_promotes_downgraded_expert_in_place():
    """After a downgrade, the substitution persists across retire_layer
    (upgrade pass ON); once the link idles, a hi re-copy is issued for the
    lo-resident expert, lands beside the lo copy via the precision-keyed
    reservation, and serves_lo_downgrade flips off — compute switches to
    hi."""
    eng, cache, staged, committed = _unit_engine(link_bps=1e6,
                                                 stage_sleep=0.05)
    _downgrade_one(eng, cache)
    eng.retire_layer(1)
    assert eng.serves_lo_downgrade(1, 1)            # persistent substitution
    eng._pump()                                     # link idle: upgrade pass
    assert eng.upgrades == 1
    assert eng.upgrade_bytes == HI_BYTES
    assert cache.is_inflight((1, 1), True)
    assert eng.serves_lo_downgrade(1, 1)            # hi not landed yet
    eng.wait_all()
    assert cache.lookup((1, 1), True) is not None   # hi landed...
    assert cache.lookup((1, 1), False) is not None  # ...beside the lo copy
    assert not eng.serves_lo_downgrade(1, 1)        # compute now serves hi
    assert (1, 1) not in eng.lo_substituted
    precs = sorted(t.precision for t, _, _ in committed)
    assert precs == sorted([PREC_HI, PREC_HI, PREC_LO])
    eng.shutdown()


def test_upgrade_issues_only_on_idle_budget():
    """With queued deadline work pending, or a hi stream already fed to its
    budget, the upgrade pass must stay silent; it fires only once the
    pending queue drains and the stream has leftover budget."""
    eng, cache, staged, committed = _unit_engine(streams=1, stage_sleep=0.05,
                                                 link_bps=1e6, hi_slots=16)
    # feed = 10 ms * 1e6 B/s = 10000 B; deadline budget ample (no downgrades)
    eng.set_deadline_clock(0, per_layer_s=10e-3, period_s=10e-3)
    # hand-plant a landed downgrade substitution: lo resident, hi absent
    cache.admit((1, 1), False, 0)
    eng.lo_substituted.add((1, 1))
    # 12 hi jobs x 1000 B overfill the 10000 B feed: 2 stay queued
    eng.submit_prefetch(3, list(range(2, 14)), np.full(12, PREC_HI),
                        current_layer=0)
    assert eng._pending                             # deadline work queued
    assert eng.upgrades == 0                        # never on a busy link
    eng._pump()
    assert eng.upgrades == 0
    eng.wait_all()
    # hysteresis: the pass waits for TWO consecutive deadline-free pumps
    eng._pump()
    assert eng.upgrades == 0
    eng._pump()                                     # drained + idle: fires
    assert eng.upgrades == 1
    eng.wait_all()
    assert cache.lookup((1, 1), True) is not None
    eng.shutdown()


def test_upgrade_never_preempts_or_blocks_deadline_work():
    """A deadline prefetch competing with an upgrade candidate for the same
    pump is issued first (upgrades are created only after the pending queue
    empties), and wait(layer) never blocks on an in-flight upgrade
    targeting that layer."""
    from repro.core.loader import UPGRADE
    eng, cache, staged, committed = _unit_engine(link_bps=1e6,
                                                 stage_sleep=0.2)
    eng.set_deadline_clock(0, per_layer_s=3e-3, period_s=10e-3)
    cache.admit((1, 1), False, 0)
    eng.lo_substituted.add((1, 1))
    # deadline prefetch (2, 5) and the (1, 1) upgrade candidate hit the same
    # pump: the deadline job takes the hi stream and suppresses the upgrade
    # (hysteresis resets on any deadline work; a busy stream blocks it too)
    eng.submit_prefetch(2, [5], np.array([PREC_HI]), current_layer=1)
    assert eng.upgrades == 0
    eng._pump()
    eng._pump()
    assert eng.upgrades == 0                        # (2, 5) still in flight
    eng.wait(2)                                     # deadline copy lands
    eng._pump()                                     # second idle pump: fires
    assert eng.upgrades == 1
    time.sleep(0.05)                                # worker starts the copy
    hi_staged = [(lay, e) for lay, e, p in staged if p == PREC_HI]
    assert hi_staged[0] == (2, 5), hi_staged
    # wait(1) must not block on the in-flight upgrade targeting layer 1
    t0 = time.perf_counter()
    eng.wait(1)
    assert time.perf_counter() - t0 < 0.18          # no 0.2 s upgrade wait
    # structural proof wait(1) did not block: the upgrade is either still
    # in flight or was collected already-done (a loaded runner can finish
    # the 0.2 s copy before the barrier) — never waited on
    assert (any(j.task.reason == UPGRADE for j in eng._issued)
            or any(t.reason == UPGRADE for t, _, _ in committed))
    eng.wait_all()
    eng.shutdown()


def test_upgrade_fires_when_hi_copy_exceeds_layer_feed():
    """Regression: in the offload regime one hi copy often exceeds a whole
    layer-period of link bytes; the upgrade pass must still re-promote on a
    fully idle stream (the one-in-flight cap, not a feed veto, bounds its
    interference) — a feed veto would make downgrades permanent exactly
    when compute per layer << copy time, HOBBIT's own premise."""
    eng, cache, staged, committed = _unit_engine(link_bps=1e6,
                                                 stage_sleep=0.01)
    # feed = 1e6 B/s * 0.3 ms = 300 B < one hi copy (1000 B)
    eng.set_deadline_clock(0, per_layer_s=3e-4, period_s=3e-4)
    cache.admit((1, 1), False, 0)
    eng.lo_substituted.add((1, 1))
    eng._pump()
    eng._pump()                                 # two idle pumps: must fire
    assert eng.upgrades == 1
    eng.wait_all()
    assert cache.lookup((1, 1), True) is not None
    eng.shutdown()


def test_no_upgrade_keeps_pr4_per_token_semantics():
    """upgrade=False is the PR-4 parity switch: the downgrade marker dies
    with retire_layer, no hi re-copy is ever issued, and the stats counters
    stay zero."""
    eng, cache, staged, committed = _unit_engine(link_bps=1e6,
                                                 stage_sleep=0.05,
                                                 upgrade=False)
    _downgrade_one(eng, cache)
    assert eng.serves_lo_downgrade(1, 1)
    eng.retire_layer(1)
    assert not eng.serves_lo_downgrade(1, 1)        # one-token decision
    eng._pump()
    eng.wait_all()
    assert eng.upgrades == 0
    assert eng.upgrade_bytes == 0
    assert cache.lookup((1, 1), True) is None       # hi never re-issued
    eng.shutdown()


def test_ordered_engine_never_upgrades():
    """The ordered parity scheduler has no downgrades, hence nothing to
    upgrade — the flag is forced off."""
    eng, *_ = _unit_engine(streams=1, ordered=True)
    assert not eng.upgrade
    eng.shutdown()


def test_engine_upgrade_recovery_under_contention(setup):
    """Engine-level: an emulated slow link makes cold-start prefetch
    contention downgrade hi copies to lo; after the load drops (batch 4 ->
    1, stationary tokens) the idle-link pass re-issues hi copies and lands
    them beside the lo stand-ins, while --no-upgrade never upgrades."""
    from repro.quant.quantize import expert_nbytes
    m, params = setup
    d, f = m.cfg.d_model, m.cfg.moe.d_ff_expert
    link_gbps = expert_nbytes(d, f, 16) / 10e-3 / 1e9   # hi copy ~10 ms

    def serve(upgrade):
        eng = OffloadEngine(m, params, EngineConfig(
            hi_slots=8, lo_slots=6, link_gbps=link_gbps, upgrade=upgrade))
        be = HobbitBackend(eng)
        rng = np.random.default_rng(0)
        arr = rng.integers(0, 256, (4, 24))
        be.start_batch(4, 28)
        for r in range(4):
            be.join(r, arr[r, :1].astype(np.int32))
        for t in range(1, 9):                   # contention burst, batch 4
            be.step(arr[:, t].astype(np.int32))
        for r in range(1, 4):                   # load drops: idle phase
            be.release(r)
        for _t in range(9, 19):
            be.step(np.full(4, 7, np.int32))
        s = eng.stats()
        be.close()
        return s

    on = serve(True)
    off = serve(False)
    assert on["precision_downgrades"] > 0       # the burst actually contended
    assert on["upgrades"] > 0                   # idle-link recovery fired
    assert on["upgrade_bytes"] > 0
    assert off["upgrades"] == 0                 # --no-upgrade: never
    assert off["upgrade_bytes"] == 0


# ------------------------------------------------- satellite regressions
def test_drain_on_demand_empty_or_hit_only_adds_no_stall():
    """Regression: a layer with an empty (or fully-skipped) miss set must
    contribute exactly 0.0 stall — not a timer epsilon per layer."""
    from repro.core.loader import ON_DEMAND, LoadTask
    eng, cache, staged, committed = _unit_engine()
    assert eng.drain_on_demand([], 0) == []
    # resident task: skipped before the timer starts
    slot, _ = cache.admit((0, 3), True, 0)
    t = LoadTask(0, 3, PREC_HI, ON_DEMAND, HI_BYTES)
    assert eng.drain_on_demand([t], 0) == []
    assert eng.stall_s == 0.0
    eng.shutdown()


def test_pump_without_feed_estimate_issues_all_jobs():
    """Regression: before the first set_deadline_clock (or with an
    unmodeled link) there is no feed estimate; the pump must treat that as
    unlimited feed, not a one-byte threshold that serializes each stream to
    a single outstanding copy."""
    eng, cache, staged, committed = _unit_engine(streams=1, stage_sleep=0.2)
    eng.submit_prefetch(1, [0, 1, 2], np.full(3, PREC_HI), current_layer=0)
    assert len(eng._issued) == 3        # all in flight at once
    eng.wait_all()
    eng.shutdown()


def test_cancel_inflight_drops_stale_pins():
    """Regression: cancelling an in-flight (key, hi) reservation must also
    drop its pins — a downgraded-away hi key must not keep constraining
    _select_victim until the next advance_token."""
    c = MultidimensionalCache(4, hi_slots=1, lo_slots=1, weights=LRU)
    c.new_sequence()
    c.advance_token()
    s_hi, _ = c.admit((0, 7), True, 0)
    c.pin((0, 7), True, hard=True)
    c.begin_inflight((0, 7), True, s_hi)
    c.cancel_inflight((0, 7), True)
    assert ((0, 7), True) not in c.pinned
    assert ((0, 7), True) not in c.hard_pinned
    # the freed slot is immediately admittable again (no phantom hard pin)
    assert c.can_admit(True)
    s2, _ = c.admit((0, 8), True, 0)
    assert s2 == s_hi


def test_biggest_gate_issues_first_within_layer():
    """Within one deadline layer a stream issues the biggest-gate job first,
    counting the FIFO inversion as an issue_reorder."""
    eng, cache, staged, _ = _unit_engine(streams=1)
    eng.submit_prefetch(2, [0, 1], np.array([PREC_HI, PREC_HI]),
                        current_layer=0, gates=np.array([0.1, 0.9]))
    eng.wait(2)
    assert [e for _, e, _ in staged] == [1, 0]      # gate 0.9 before 0.1
    assert eng.issue_reorders >= 1
    eng.shutdown()


def test_nearest_deadline_layer_issues_first():
    """Across deadline layers the nearest layer's job overtakes an older
    queued job for a later layer.  (A modeled link with a tight feed keeps
    the later submissions queued — without a feed estimate every job now
    issues immediately, see test_pump_without_feed_estimate_issues_all_jobs.)"""
    eng, cache, staged, _ = _unit_engine(streams=1, stage_sleep=0.05,
                                         link_bps=1e4)
    # feed = 10 kB/s * 5 ms = 50 B < one lo copy (100 B): the stream is fed
    # by a single outstanding copy and later submissions stay reorderable
    eng.set_deadline_clock(0, per_layer_s=5e-3, period_s=5e-3)
    eng.submit_prefetch(3, [0], np.array([PREC_LO]), current_layer=0)
    eng.submit_prefetch(3, [1], np.array([PREC_LO]), current_layer=0)
    eng.submit_prefetch(1, [2], np.array([PREC_LO]), current_layer=0)
    # job for layer 3/expert 0 is in flight; jobs (3,1) and (1,2) are queued:
    # once the stream frees, the layer-1 job must overtake the older (3,1)
    time.sleep(0.15)
    eng._pump()
    eng.wait_all()
    assert [(lay, e) for lay, e, _ in staged] == [(3, 0), (1, 2), (3, 1)]
    assert eng.issue_reorders >= 1
    eng.shutdown()


def test_cancel_inflight_returns_slot_and_keeps_other_precision():
    """cancel_inflight drops only the (key, precision) reservation it names:
    the slot returns to the free list and a lo copy of the same expert is
    untouched (precision-keyed reservations)."""
    c = MultidimensionalCache(4, hi_slots=1, lo_slots=1, weights=LRU)
    c.new_sequence()
    c.advance_token()
    s_lo, _ = c.admit((0, 7), False, 0)
    s_hi, _ = c.admit((0, 7), True, 0)
    c.begin_inflight((0, 7), True, s_hi)
    assert c.cancel_inflight((0, 7), True) == s_hi
    assert c.lookup((0, 7), True) is None
    assert s_hi in c.hi.free                        # slot reusable
    assert c.lookup((0, 7), False) == s_lo          # lo copy untouched
    assert c.cancel_inflight((0, 7), True) is None  # idempotent


# ------------------------------------------------------------- teardown
def test_engine_close_idempotent_and_step_raises(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    generate(HobbitBackend(eng), np.array([[1, 2, 3]]), 3, max_len=16)
    eng.close()
    eng.close()                                     # second close: no-op
    with pytest.raises(RuntimeError, match="closed"):
        eng.decode_step_batch(np.array([1], np.int32))
    with pytest.raises(RuntimeError, match="closed"):
        eng.start_batch(1, 8)


def test_batching_server_close_releases_staging_threads(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    rng = np.random.default_rng(3)
    with BatchingServer(HobbitBackend(eng), max_batch=2, max_len=32) as srv:
        for i in range(2):
            srv.submit(Request(rid=i, prompt=rng.integers(0, 256, 4),
                               max_new_tokens=3))
        srv.run()
        assert len(srv.completed) == 2
    # scope exit closed the backend -> engine closed, worker threads released
    assert eng._closed
    assert not eng.scheduler._finalizer.alive
    srv.close()                                     # idempotent


def test_dense_backend_close_is_noop(setup):
    cfg = smoke_variant(get_config("granite-3-2b"), layers=2, d_model=64,
                        vocab=128)
    cfg = dataclasses.replace(cfg, dtype="float32")
    m = build_model(cfg)
    be = DenseBackend(m, m.init(jax.random.PRNGKey(0)))
    be.close()
    be.close()
    be.start_batch(1, 8)                            # still usable


# ------------------------------------------------------- stats round-trip
def _roundtrip_same_keys(stats: dict) -> dict:
    """json round-trip must preserve the exact key set (serving contract)."""
    back = json.loads(json.dumps(stats))
    assert set(back) == set(stats)
    return back


NEW_FIELDS = ("per_stream_bytes", "issue_reorders", "precision_downgrades",
              "upgrades", "upgrade_bytes", "served_lo_expert_steps",
              "link_utilization")


def test_engine_stats_json_roundtrip_with_stream_fields(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    generate(HobbitBackend(eng), np.array([[1, 2, 3]]), 4, max_len=16)
    s = eng.stats()
    back = _roundtrip_same_keys(s)
    for f in NEW_FIELDS + ("streams", "link_gbps"):
        assert f in back, f
    assert back["streams"] == 2
    assert isinstance(back["per_stream_bytes"], list)
    assert len(back["per_stream_bytes"]) == 2
    assert sum(back["per_stream_bytes"]) > 0        # prefetch traffic issued
    eng.close()


def test_simulator_stats_json_roundtrip_with_stream_fields():
    rng = np.random.default_rng(5)
    trace = []
    for _ in range(6):
        token = []
        for _li in range(3):
            g = np.sort(rng.random(2))[::-1]
            token.append(TraceLayer(experts=rng.permutation(8)[:2].tolist(),
                                    gate_vals=g,
                                    pred_experts=rng.permutation(8)[:2].tolist(),
                                    pred_gate_vals=np.sort(rng.random(2))[::-1]))
        trace.append(token)
    cfg = HobbitSimConfig(hi_slots=4, lo_slots=2, hi_bytes=10_000_000,
                          lo_bytes=2_500_000, streams=2, ordered=False)
    res = OffloadSimulator("hobbit", 3, RTX4090, cfg).run(trace)
    ser = {k: v for k, v in res.items() if k != "stats"}   # CacheStats object
    back = _roundtrip_same_keys(ser)
    for f in NEW_FIELDS:
        assert f in back, f
    assert len(back["per_stream_bytes"]) == 2
    assert back["cache"]["hits"] == res["stats"].hits      # dict mirror


def test_simulator_single_stream_default_unchanged():
    """streams=1/ordered=True (the default) must reproduce the single-DMA
    timeline: one stream, all bytes on it, no downgrades or reorders."""
    rng = np.random.default_rng(6)
    trace = [[TraceLayer(experts=[0, 1], gate_vals=np.array([0.6, 0.3]),
                         pred_experts=[2, 3],
                         pred_gate_vals=np.array([0.5, 0.2]))
              for _ in range(2)] for _ in range(4)]
    cfg = HobbitSimConfig(hi_slots=4, lo_slots=2, hi_bytes=1_000_000,
                          lo_bytes=250_000)
    res = OffloadSimulator("hobbit", 2, RTX4090, cfg).run(trace)
    assert len(res["per_stream_bytes"]) == 1
    assert res["precision_downgrades"] == 0
    assert res["issue_reorders"] == 0


def test_server_stats_json_roundtrip_with_stream_fields(setup):
    m, params = setup
    eng = OffloadEngine(m, params, EngineConfig(hi_slots=8, lo_slots=4))
    rng = np.random.default_rng(7)
    with BatchingServer(HobbitBackend(eng), max_batch=2, max_len=32) as srv:
        for i in range(2):
            srv.submit(Request(rid=i, prompt=rng.integers(0, 256, 4),
                               max_new_tokens=3))
        srv.run()
        s = srv.stats()
    back = _roundtrip_same_keys(s)
    for f in ("precision_downgrades", "issue_reorders", "link_utilization",
              "mean_precision_downgrades", "upgrades", "upgrade_bytes",
              "served_lo_expert_steps", "mean_served_lo"):
        assert f in back, f
    for f in NEW_FIELDS:
        assert f in back["backend"], f
