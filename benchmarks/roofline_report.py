"""§Roofline: render the dry-run matrix (results/dryrun.jsonl) as the
per-(arch x shape x mesh) roofline table — compute/memory/collective terms,
dominant bottleneck, and the MODEL_FLOPS / HLO_FLOPS usefulness ratio."""

from __future__ import annotations

import json
import os

_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
# prefer the latest matrix
RESULTS = next((os.path.join(_DIR, n) for n in
                ("dryrun_v3.jsonl", "dryrun_v2.jsonl", "dryrun.jsonl")
                if os.path.exists(os.path.join(_DIR, n))),
               os.path.join(_DIR, "dryrun.jsonl"))


def load(path=RESULTS):
    recs = []
    if not os.path.exists(path):
        return recs
    seen = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            seen[(r["arch"], r["shape"], r.get("mesh"))] = r
    return list(seen.values())


def run():
    rows = []
    recs = load()
    if not recs:
        return [("roofline_table", "MISSING",
                 "run: python -m repro.launch.dryrun --all --out results/dryrun.jsonl")]
    ok = [r for r in recs if r["status"] == "ok"]
    fail = [r for r in recs if r["status"] == "fail"]
    skip = [r for r in recs if r["status"] == "skip"]
    rows.append(("dryrun_matrix_ok/fail/skip",
                 f"{len(ok)}/{len(fail)}/{len(skip)}",
                 "every non-skip pair must compile"))
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        rl = r["roofline"]
        n_flops = r.get("model_flops", 0.0)
        ratio = n_flops / (r["flops_per_chip"] * r["chips"]) if r["flops_per_chip"] else 0
        rows.append((
            f"roofline[{r['arch']}][{r['shape']}][{r['mesh']}]",
            f"c={rl['compute_s']:.2e};m={rl['memory_s']:.2e};x={rl['collective_s']:.2e}",
            f"{rl['bottleneck']}-bound; model/hlo flops={ratio:.2f}; "
            f"peak_mem={r['memory']['peak_bytes_per_chip']/1e9:.1f}GB",
        ))
    for r in fail:
        rows.append((f"roofline_FAIL[{r['arch']}][{r['shape']}][{r.get('mesh')}]",
                     "FAIL", r.get("error", "?")[:120]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
