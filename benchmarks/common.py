"""Shared benchmark fixtures: small *trained* MoE models (routing structure
— expert preferences, layer-similarity — only emerges with training), cached
to disk so every benchmark reuses them.

Two model scales mirror the paper's pair:
  "mixtral-smoke": 4 layers x 8 experts top-2   (Mixtral-8x7B family)
  "phi-smoke":     4 layers x 16 experts top-2  (Phi-MoE family)
"""

from __future__ import annotations

import dataclasses
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, smoke_variant
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import init_state, train

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench_models_l8")

VOCAB = 512
SEQ = 64


def bench_config(kind: str = "mixtral-smoke") -> ModelConfig:
    base = get_config("mixtral-8x7b" if kind == "mixtral-smoke" else "phi-moe")
    cfg = smoke_variant(base, layers=8, d_model=128, vocab=VOCAB)
    moe = dataclasses.replace(
        cfg.moe, num_experts=8 if kind == "mixtral-smoke" else 16, top_k=2,
        router_aux_weight=0.02)
    return dataclasses.replace(cfg, name=kind, dtype="float32", moe=moe).validate()


def data_config(seed: int = 0) -> DataConfig:
    return DataConfig(vocab_size=VOCAB, seq_len=SEQ, batch_size=16, seed=seed)


def get_trained(kind: str = "mixtral-smoke", steps: int = 300, log=lambda *_: None):
    """Returns (model, params). Trains once, restores afterwards."""
    cfg = bench_config(kind)
    model = build_model(cfg)
    cdir = os.path.join(CACHE_DIR, kind)
    state = init_state(model, seed=0)
    if ckpt.latest_step(cdir) is not None:
        params, _ = ckpt.restore(cdir, state.params)
        return model, params
    it = batches(data_config())
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=30, total_steps=steps)
    state, hist = train(model, ocfg, it, steps, log_every=100, log=log)
    ckpt.save(cdir, state.params, step=steps)
    return model, state.params


def eval_token_stream(n_seqs: int = 8, seed: int = 77) -> list[np.ndarray]:
    """Held-out token sequences for trace collection / NLL scoring."""
    dc = dataclasses.replace(data_config(), seed=seed, batch_size=n_seqs)
    b = next(batches(dc))
    return [np.asarray(b.tokens[i]) for i in range(n_seqs)]


def collect_trace(engine, seqs, max_len: int = 128):
    """Run teacher-forced decoding over sequences, return the engine trace
    with per-sequence boundaries."""
    trace = []
    breaks = []
    for s in seqs:
        breaks.append(len(trace))
        engine.start_sequence(max_len)
        for t in s:
            engine.decode_token(int(t))
        trace.extend(engine.trace)
    return trace, breaks


def collect_trace_batched(engine, seqs, max_len: int = 128):
    """Teacher-forced trace collection through the unified serving API with
    all sequences decoding as one batch (equal-length seqs): each sequence
    joins a slot (real prefill of its first token), then every step feeds the
    next token column.  Token traces interleave across slots step-by-step."""
    from repro.serving.api import HobbitBackend

    backend = HobbitBackend(engine)
    arr = np.stack([np.asarray(s, np.int64) for s in seqs])
    b, s_len = arr.shape
    backend.start_batch(b, max_len)
    for r in range(b):
        backend.join(r, arr[r, :1].astype(np.int32))
    for t in range(1, s_len):
        backend.step(arr[:, t].astype(np.int32))
    return list(engine.trace)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.s * 1e6
