"""Fig. 5 reproduction: (a) Pearson correlation between the gate magnitude
||G(x)|| and the weighted expert-output magnitude ||G(x) E(x)|| — the paper
reports rho ~= 0.99 for Mixtral-8x7B; (b) the Eq. 2 unimportance-score
distribution and the T1/T2 calibration that splits selections into the
paper's ~67% hi / 30% lo / 3% skip groups."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core.scoring import (calibrate_thresholds, gate_output_correlation,
                                unimportance_scores)
from repro.models import unstack_layers
from repro.models import moe as moe_lib
from repro.models import layers as L


def _collect(model, params, tokens):
    """Per (token, layer, selected expert): gate val + ||w_e * E_e(x)||."""
    cfg = model.cfg
    flat = unstack_layers(cfg, params)
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    gate_norms, out_norms, scores, per_layer = [], [], [], []
    from repro.models.model import _layer_forward
    for _li, p in enumerate(flat):
        h = L.apply_norm(p["ffn_norm"], x, cfg)
        hf = h.reshape(-1, d)
        r = moe_lib.route(p["ffn"]["router"], hf, cfg.moe)
        # dense expert outputs for the selected experts
        wi, wo = p["ffn"]["experts"]["wi"], p["ffn"]["experts"]["wo"]
        hcur = jnp.einsum("td,edf->etf", hf, wi)
        g, u = jnp.split(hcur, 2, axis=-1)
        act = (g / (1 + jnp.exp(-g))) * u
        ye = jnp.einsum("etf,efd->etd", act, wo)        # (E, T, D)
        t = hf.shape[0]
        lg, lo_ = [], []
        for k in range(cfg.moe.top_k):
            e_idx = np.asarray(r.top_idx[:, k])
            w = np.asarray(r.top_w[:, k])
            out = np.asarray(ye)[e_idx, np.arange(t)]   # (T, D)
            lg.append(w)
            lo_.append(np.linalg.norm(w[:, None] * out, axis=-1))
        gate_norms.extend(lg)
        out_norms.extend(lo_)
        per_layer.append(gate_output_correlation(np.concatenate(lg),
                                                 np.concatenate(lo_)))
        _, sc = unimportance_scores(np.asarray(r.top_w))
        scores.append(sc.ravel())
        # advance x through the real layer
        x, _, _ = _layer_forward(p, x, positions, cfg, "attn", True)
    return (np.concatenate(gate_norms), np.concatenate(out_norms),
            np.concatenate(scores), per_layer)


def run():
    rows = []
    for kind in ("mixtral-smoke", "phi-smoke"):
        model, params = common.get_trained(kind)
        seqs = common.eval_token_stream(4)
        toks = jnp.asarray(np.stack(seqs))
        g, o, scores, per_layer = _collect(model, params, toks)
        rho = gate_output_correlation(g, o)
        th = calibrate_thresholds(scores)
        # resulting split under the calibrated thresholds (rank-0 scores are
        # exactly 0 <= T1, so the always-hi rule is already reflected)
        frac = [float((scores <= th.t1).mean()),
                float(((scores > th.t1) & (scores <= th.t2)).mean()),
                float((scores > th.t2).mean())]
        rows.append((f"fig5a_corr_gate_vs_output[{kind}]", round(rho, 4),
                     "paper: 0.99 (Mixtral-8x7B)"))
        rows.append((f"fig5a_corr_per_layer_mean[{kind}]",
                     round(float(np.mean(per_layer)), 4),
                     "per-layer Pearson, averaged"))
        rows.append((f"fig5b_thresholds[{kind}]",
                     f"T1={th.t1:.3f};T2={th.t2:.3f}",
                     "paper: T1=0.6 T2=0.9"))
        rows.append((f"fig5b_split_hi/lo/skip[{kind}]",
                     ";".join(f"{f:.2f}" for f in frac),
                     "paper: 0.67/0.30/0.03"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
