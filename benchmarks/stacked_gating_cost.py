"""Fig. 17a reproduction: the Stacking Computer.  Sequentially evaluating p
gate matmuls costs O(p); stacking them into one batched matmul is ~flat in p.
Measured wall-clock (jitted, CPU) and FLOP-model both reported."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer
from repro.kernels import ref


def run():
    rows = []
    d, e = 4096, 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
    gates = jnp.asarray(rng.normal(size=(4, d, e)), jnp.float32)

    def sequential(x, gates, p):
        outs = []
        for i in range(p):
            outs.append(x @ gates[i])
        return jnp.stack(outs)

    for p in (1, 2, 3, 4):
        seq_f = jax.jit(lambda x, g, p=p: sequential(x, g[:p], p))
        stk_f = jax.jit(lambda x, g, p=p: ref.stacked_gating_ref(x, g[:p]))
        seq_f(x, gates).block_until_ready()
        stk_f(x, gates).block_until_ready()
        n = 200
        with Timer() as t_seq:
            for _ in range(n):
                seq_f(x, gates).block_until_ready()
        with Timer() as t_stk:
            for _ in range(n):
                stk_f(x, gates).block_until_ready()
        rows.append((f"fig17a_sequential_gating_p{p}", round(t_seq.us / n, 1),
                     "us/call; cost grows ~linearly in p"))
        rows.append((f"fig17a_stacked_gating_p{p}", round(t_stk.us / n, 1),
                     "us/call; ~flat in p (paper Fig 17a)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
