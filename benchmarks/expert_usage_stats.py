"""Fig. 10 reproduction: expert-usage statistics that justify the cache
policies.  (a) temporal locality: P(the current token's top-1 expert is
selected again for the next token) vs the uniform-routing baseline k/E;
(b) sequence-level preference: different sequences prefer different experts
(mean total-variation distance between per-sequence expert histograms vs a
shuffled-token control)."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import EngineConfig, OffloadEngine


def run():
    rows = []
    for kind in ("mixtral-smoke", "phi-smoke"):
        model, params = common.get_trained(kind)
        seqs = common.eval_token_stream(6)
        eng = OffloadEngine(model, params, EngineConfig(hi_slots=64, lo_slots=8,
                                                        prefetch=False))
        e = model.cfg.moe.num_experts
        k = model.cfg.moe.top_k
        per_seq_traces = []
        for s in seqs:
            eng.start_sequence(len(s) + 1)
            for t in s:
                eng.decode_token(int(t))
            per_seq_traces.append(list(eng.trace))
            eng.trace = []

        # --- Fig 10a: temporal reuse of the top-1 expert
        reuse_top1, reuse_any, total = 0, 0, 0
        for tr in per_seq_traces:
            for t in range(len(tr) - 1):
                for li in range(len(tr[t])):
                    cur = tr[t][li].experts
                    nxt = tr[t + 1][li].experts
                    reuse_top1 += cur[0] in nxt
                    reuse_any += len(set(cur) & set(nxt)) > 0
                    total += 1
        theo_top1 = k / e
        theo_any = 1 - (1 - k / e) ** k  # approx for k draws
        rows.append((f"fig10a_p_top1_reused_next_token[{kind}]",
                     round(reuse_top1 / total, 3),
                     f"uniform baseline {theo_top1:.3f}; paper: well above"))
        rows.append((f"fig10a_p_any_reused_next_token[{kind}]",
                     round(reuse_any / total, 3),
                     f"uniform baseline ~{theo_any:.3f}"))

        # --- Fig 10b: per-sequence expert preference heterogeneity
        n_layers = len(per_seq_traces[0][0])
        hists = np.zeros((len(per_seq_traces), n_layers, e))
        for si, tr in enumerate(per_seq_traces):
            for tok in tr:
                for li, tl in enumerate(tok):
                    for ex in tl.experts:
                        hists[si, li, ex] += 1
        hists /= np.maximum(hists.sum(-1, keepdims=True), 1)
        # mean pairwise total-variation distance between sequences
        tvs = []
        ns = len(per_seq_traces)
        for i in range(ns):
            for j in range(i + 1, ns):
                tvs.append(0.5 * np.abs(hists[i] - hists[j]).sum(-1).mean())
        # control: pooled distribution (if sequences were iid the TV would
        # be sampling noise ~ sqrt(E / tokens))
        tokens_per_seq = sum(len(t) for t in per_seq_traces) / ns
        noise = float(np.sqrt(e / (4 * tokens_per_seq)))
        rows.append((f"fig10b_seq_expert_TV_distance[{kind}]",
                     round(float(np.mean(tvs)), 3),
                     f"sampling-noise floor ~{noise:.3f}; paper: sequences "
                     f"prefer different experts"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
