"""Fig. 14 reproduction: end-to-end decode throughput of HOBBIT vs the
paper's baseline systems, trace-driven (real routing traces from the trained
models; hardware cost models for the RTX 4090 and Jetson Orin groups).

System mapping (paper -> simulator):
  Llama.cpp (LL)        -> dense_layerwise (streams whole layers)
  MoE-Offloading (MO)   -> on_demand (LRU cache, fp16 on miss)
  MoE-Infinity (MI)     -> prefetch_lru (LRU + next-layer fp16 prefetch)
  HOBBIT (HB)           -> hobbit (mixed precision + adaptive prefetch +
                           multidimensional cache)

Expert byte sizes use the paper's full-scale models (Mixtral-8x7B /
Phi-MoE dims) so the simulated latencies are full-scale, while the routing
structure comes from the trained smoke models.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import (EngineConfig, HobbitSimConfig, OffloadEngine,
                        simulate_systems)
from repro.core.simulator import JETSON_ORIN, RTX4090
from repro.quant.quantize import expert_nbytes

FULL_DIMS = {
    "mixtral-smoke": (4096, 14336),   # Mixtral-8x7B expert dims
    "phi-smoke": (4096, 6400),        # Phi-MoE expert dims
}


def run():
    rows = []
    for kind in ("mixtral-smoke", "phi-smoke"):
        model, params = common.get_trained(kind)
        seqs = common.eval_token_stream(4)
        e = model.cfg.moe.num_experts
        n_entities = model.cfg.num_layers * e
        eng = OffloadEngine(model, params, EngineConfig(
            hi_slots=max(8, n_entities // 3), lo_slots=max(4, n_entities // 6),
            prefetch_p=2))
        # all 4 eval sequences decode as ONE batch through the serving API
        # (union-of-slots expert loading), matching the deployment scenario
        trace = common.collect_trace_batched(eng, seqs)
        d, f = FULL_DIMS[kind]
        cfg = HobbitSimConfig(
            hi_slots=max(8, n_entities // 3), lo_slots=max(4, n_entities // 6),
            hi_bytes=expert_nbytes(d, f, 16), lo_bytes=expert_nbytes(d, f, 4))
        import dataclasses as _dc
        for hw in (RTX4090, JETSON_ORIN):
            res = simulate_systems(trace, eng.num_moe_layers, hw, cfg)
            # beyond-paper: confidence-gated prefetch variant
            from repro.core import OffloadSimulator
            res["hobbit_confgate"] = OffloadSimulator(
                "hobbit", eng.num_moe_layers, hw,
                _dc.replace(cfg, prefetch_conf=0.6)).run(trace)
            base_mo = res["on_demand"]["tok_per_s"]
            base_mi = res["prefetch_lru"]["tok_per_s"]
            base_ll = res["dense_layerwise"]["tok_per_s"]
            hb = res["hobbit"]["tok_per_s"]
            for sysname, r in res.items():
                rows.append((f"fig14_decode_tok_s[{kind}][{hw.name}][{sysname}]",
                             round(r["tok_per_s"], 2), "tok/s (simulated)"))
            rows.append((f"fig14_speedup_vs_MoE-Offloading[{kind}][{hw.name}]",
                         round(hb / base_mo, 2), "paper: ~3.2x (4090)"))
            rows.append((f"fig14_speedup_vs_MoE-Infinity[{kind}][{hw.name}]",
                         round(hb / base_mi, 2),
                         "paper: 2.30-3.92x (4090), 3.64-9.93x (Orin)"))
            rows.append((f"fig14_speedup_vs_llama.cpp[{kind}][{hw.name}]",
                         round(hb / base_ll, 2), "paper: 13-19x (Orin)"))
            hbc = res["hobbit_confgate"]["tok_per_s"]
            rows.append((f"beyond_confgate_speedup_vs_MO[{kind}][{hw.name}]",
                         round(hbc / base_mo, 2),
                         "beyond-paper: confidence-gated prefetch"))
            rows.append((f"beyond_confgate_vs_paper_hobbit[{kind}][{hw.name}]",
                         round(hbc / hb, 2),
                         "gain over paper-faithful prefetch at 65% pred acc"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
